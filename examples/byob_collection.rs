//! BYOB: bring your own benchmark collection (DESIGN.md §15).
//!
//! Builds a small definition set **as data** — two apps, one machine,
//! one engine, written in the tomlite TOML subset — loads it through
//! the same loud parse/validate path as `exacb measure -d`, runs a
//! seeded three-day campaign over it, and prints the results table.
//! Also demonstrates what validation errors look like: every error
//! names its file, table, and key.
//!
//! Run with: `cargo run --example byob_collection`

use exacb::coordinator::postproc;
use exacb::defs::{self, MeasurePlan};

const COLLECTION: &str = r#"# A minimal BYOB collection: one file, one team.

[[engine]]
name = "simapp"
command = "simapp"
description = "parameterised scalable application"

[[machine]]
name = "toy-cluster"
version = "2026.1"
gpu = "gh200"
nodes = 16
gpus_per_node = 4
cores_per_node = 288
partitions = ["batch"]
stream_efficiency = 0.85
noise_sigma = 0.01
perf_factor = 1.2
network = "ndr400"
power = "gh200"

[[app]]
name = "ocean-sim"
domain = "climate"
maturity = "instrumentability"
engine = "simapp"
nodes = 4

[app.parameters]
gflops_total = 120000.0
serial_frac = 0.02
mem_bound = 0.6
comm_mb = 96.0
steps = 120
weak = false

[app.behavior]
failure_rate = 0.05

[app.metrics]
primary = "tts"
record = ["tts", "gflops_rate"]

[[app]]
name = "galaxy-merge"
domain = "astrophysics"
maturity = "reproducibility"
engine = "simapp"
nodes = 8

[app.parameters]
gflops_total = 340000.0
serial_frac = 0.01
mem_bound = 0.4
comm_mb = 48.0
steps = 200
weak = false

[app.behavior]
failure_rate = 0.01

[app.metrics]
primary = "tts"
record = ["tts"]
"#;

fn main() {
    // --- parse + validate the collection --------------------------------
    let files = vec![("collection.toml".to_string(), COLLECTION.to_string())];
    let set = defs::parse_files(&files).expect("collection must validate");
    println!(
        "loaded {} app(s), {} machine(s), {} engine(s)",
        set.apps.len(),
        set.machines.len(),
        set.engines.len()
    );
    for a in &set.apps {
        println!("  app {:<14} {:<14} {} nodes, {} steps", a.name, a.domain, a.nodes, a.steps);
    }

    // --- what a broken definition looks like -----------------------------
    let broken = vec![(
        "collection.toml".to_string(),
        COLLECTION.replace("steps = 120", "steps = 0"),
    )];
    let err = defs::parse_files(&broken).expect_err("steps = 0 must not validate");
    println!("\na broken collection fails loudly:\n  {err}");

    // --- run it: 2 apps x 3 days on the toy cluster ----------------------
    let plan = MeasurePlan {
        days: 3,
        queue: "batch".to_string(),
        seed: 7,
        ..MeasurePlan::default()
    };
    let (world, summaries) = defs::run_measure(&set, &plan).expect("campaign runs");
    let s = summaries.last().unwrap();
    println!(
        "\ncampaign: {} pipelines, {} succeeded, {} reports, {:.1} core-hours",
        s.pipelines_run, s.pipelines_succeeded, s.reports_recorded, s.core_hours
    );
    print!("\n{}", postproc::collection_results_table(&world, "tts").render());

    assert_eq!(set.apps.len(), 2);
    assert!(s.pipelines_run > 0);
    println!("\nbyob_collection OK");
}
