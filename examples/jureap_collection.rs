//! End-to-end driver for the paper's headline §VI-A demonstration at
//! full scale (campaign machinery: DESIGN.md §5; maturity ladder: §10).
//!
//! 72 applications across 8 scientific domains, onboarded at
//! heterogeneous *declared* maturity levels (runnability /
//! instrumentability / reproducibility), continuously benchmarked for
//! 14 simulated days of daily scheduled CI pipelines on the simulated
//! JUPITER system — roughly 1000 pipelines, each flowing repository →
//! CI components → Jacamar-like runner → batch scheduler → workload
//! models → protocol reports → `exacb.data` branches — followed by the
//! cross-application analyses the uniform protocol makes possible.
//!
//! For the campaign where levels are *earned* instead of declared, see
//! `examples/maturity_ladder.rs` and `exacb jureap`.
//!
//! Run with: `cargo run --release --example jureap_collection`

use exacb::analysis::ReportSet;
use exacb::coordinator::{collection, World};
use exacb::util::table::Table;
use exacb::workloads::portfolio;

fn main() {
    let t0 = std::time::Instant::now();
    let seed = 20260101;
    let mut world = World::new(seed);
    let engine = world.try_attach_engine();

    // --- onboard the portfolio -------------------------------------------
    let apps = portfolio::jureap();
    println!(
        "JUREAP-scale campaign: {} applications, PJRT engine: {}",
        apps.len(),
        if engine { "attached" } else { "unavailable" }
    );
    let mut by_domain = Table::new(&["domain", "apps"]);
    for domain in portfolio::DOMAINS {
        by_domain.push_row(vec![
            domain.to_string(),
            apps.iter().filter(|a| a.domain == domain).count().to_string(),
        ]);
    }
    print!("{}", by_domain.render());

    collection::onboard(&mut world, &apps, "jupiter", "all");

    // --- 14 simulated days of daily pipelines ----------------------------
    let days = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(14);
    println!("\nrunning {days} simulated days of daily scheduled pipelines…");
    let summary = collection::run_campaign(&mut world, &apps, days);

    // --- campaign results ---------------------------------------------------
    println!(
        "\npipelines: {}/{} succeeded ({:.1}%)",
        summary.pipelines_succeeded,
        summary.pipelines_run,
        100.0 * summary.pipelines_succeeded as f64 / summary.pipelines_run as f64
    );
    println!(
        "protocol reports recorded: {} ({} data entries, {} successful)",
        summary.reports_recorded, summary.entries_total, summary.entries_ok
    );
    println!("simulated core-hours consumed: {:.0}", summary.core_hours);

    println!("\nsuccess rate by maturity level (incremental adoption ladder):");
    print!("{}", summary.table().render());

    println!("\nmedian time-to-solution by domain:");
    let mut t = Table::new(&["domain", "apps", "median_tts_s"]);
    for (domain, n, tts) in &summary.by_domain {
        t.push_row(vec![
            domain.clone(),
            n.to_string(),
            format!("{tts:.1}"),
        ]);
    }
    print!("{}", t.render());

    // --- cross-application analysis: every report is protocol-uniform -----
    // regardless of app maturity, so collection-wide slicing "just works"
    let mut all = ReportSet::default();
    for app in &apps {
        let repo = world.repo(&app.name).unwrap();
        let (set, skipped) = ReportSet::load(&repo.store, "exacb.data", "");
        assert_eq!(skipped, 0, "all stored reports are protocol-valid");
        all.reports.extend(set.reports);
    }
    let (ok, total) = all.success_counts();
    println!(
        "\ncross-application dataset: {} reports, {}/{} entries successful",
        all.len(),
        ok,
        total
    );
    let tts: Vec<f64> = all.time_series("tts").iter().map(|(_, v)| *v).collect();
    let s = exacb::util::stats::summary(&tts);
    println!(
        "collection tts: n={} geomean={:.1}s median={:.1}s p95={:.1}s",
        s.n,
        exacb::util::stats::geomean(&tts),
        exacb::util::stats::median(&tts),
        exacb::util::stats::percentile(&tts, 95.0),
    );

    // sanity: the campaign really exercised the whole stack
    assert!(summary.pipelines_run as i64 >= 72 * days);
    assert!(summary.pipelines_succeeded > summary.pipelines_run / 2);
    assert!(summary.reports_recorded > 0);
    assert!(summary.core_hours > 0.0);
    println!(
        "\nend-to-end campaign OK in {:.1}s host wall-clock",
        t0.elapsed().as_secs_f64()
    );
}
