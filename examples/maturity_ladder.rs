//! Maturity ladder walkthrough (DESIGN.md §10): one application climbs
//! from *runnability* to *reproducibility*, earning every rung from
//! recorded evidence instead of declaring it.
//!
//! Day by day:
//!
//! 1. the app is onboarded claiming the **top** rung — the first
//!    judgeable assessment demotes it to what the evidence supports;
//! 2. three successful daily runs earn **runnability**;
//! 3. the team adds analysis instrumentation to the benchmark
//!    definition; three instrumented runs earn **instrumentability**;
//! 4. the team opts into the replay audit: a warm-cache run re-commits
//!    the report byte-identically, proving **reproducibility**.
//!
//! Run with: `cargo run --release --example maturity_ladder`

use exacb::coordinator::World;
use exacb::maturity::{self, campaign};
use exacb::workloads::onboarding::{OnboardingApp, OnboardingScenario};
use exacb::workloads::portfolio::{Maturity, PortfolioApp};
use exacb::workloads::scalable::AppModel;

fn main() {
    // --- one app that claims everything and has proven nothing --------
    let climber = OnboardingApp {
        app: PortfolioApp {
            name: "climber".to_string(),
            domain: "climate".to_string(),
            maturity: Maturity::Reproducibility, // the claim
            model: AppModel {
                name: "climber".to_string(),
                gflops_total: 30_000.0,
                steps: 20,
                ..AppModel::default()
            },
            failure_rate: 0.0,
            nodes: 2,
        },
        declared: Maturity::Reproducibility,
        instrument_from: Some(4), // the team instruments on day 4
        verify_from: Some(7),     // …and joins the replay audit on day 7
        break_day: None,
        fix_day: None,
    };
    let sc = OnboardingScenario {
        apps: vec![climber],
        days: 9,
        machines: vec!["jupiter".to_string()],
        queue: "all".to_string(),
        seed: 20260730,
        verify_every: 4, // audit days 3 and 7
        min_runs: 3,
        min_instrumented: 3,
        window_days: 0, // whole history: this walkthrough never decays
    };
    println!(
        "onboarding 'climber' declared at {}, with nothing recorded yet",
        sc.apps[0].declared
    );

    // --- run the campaign: daily pipelines through maturity-check@v1 --
    let mut world = World::new(sc.seed);
    let out = campaign::run_onboarding(&mut world, &sc);
    println!("\nday-by-day gate readings:");
    for r in &out.records {
        println!(
            "  day {:>2}: pipeline {} | verdict {:<22} | holds {}",
            r.day,
            if r.pipeline_ok { "ok " } else { "FAIL" },
            r.verdict,
            r.level
        );
    }

    println!("\nlevel transitions (all earned, none declared):");
    for t in &out.transitions {
        println!(
            "  day {:>2}: {} -> {} ({})",
            t.day,
            t.from,
            t.to,
            if t.to > t.from { "promotion" } else { "demotion" }
        );
    }

    // --- the evidence behind the final state --------------------------
    let state = maturity::assess_repo(
        world.repo("climber").unwrap(),
        &maturity::CriteriaConfig::default(),
    );
    println!(
        "\nfinal state: declared {}, earned {}",
        state.declared,
        state.earned.map(|l| l.name()).unwrap_or("none")
    );
    println!(
        "evidence: {} successful runs ({} instrumented), {} replay commit(s), \
         pinned stage on {} run(s), {} seeded",
        state.evidence.successful_runs,
        state.evidence.instrumented_runs,
        state.evidence.replay_commits,
        state.evidence.pinned_runs,
        state.evidence.seeded_runs
    );
    print!("\n{}", world.maturity_table().render());

    // the walkthrough's whole point, asserted:
    assert_eq!(
        out.transition_day("climber", Maturity::Instrumentability),
        Some(sc.expected_instrumentability_day(0).unwrap()),
        "instrumentation earns the middle rung on its exact day"
    );
    assert_eq!(
        out.transition_day("climber", Maturity::Reproducibility),
        Some(sc.expected_reproducibility_day(0).unwrap()),
        "the replay audit earns the top rung on its exact day"
    );
    assert_eq!(world.repo("climber").unwrap().maturity, Maturity::Reproducibility);
    println!("\nmaturity ladder walkthrough OK — every rung was earned");
}
