//! Energy study (paper §VI-B, Figs. 8–9): frequency sweeps through the
//! jpwr-like launcher, injected via the CI platform configuration —
//! *without modifying the benchmarks*.
//!
//! Two applications with different memory-boundedness are swept over the
//! GPU frequency range on simulated JEDI; per-GPU power traces are
//! sampled, measurement scopes placed, energy integrated, and sweet
//! spots identified.
//!
//! Run with: `cargo run --release --example energy_study`

use exacb::analysis::{EnergySweep, ReportSet};
use exacb::ci::Trigger;
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::util::table::Table;

fn repo_for(name: &str, membound: f64) -> BenchmarkRepo {
    let jube = format!(
        "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 1\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name {name} --flops 250000 --membound {membound} --comm-mb 16 --steps 40\n"
    );
    let ci = format!(
        r#"
include:
  - component: jureap/energy@v3
    inputs:
      prefix: "jedi.{name}"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/app.yml"
      frequencies: []
"#
    );
    BenchmarkRepo::new(name)
        .with_file("benchmark/jube/app.yml", &jube)
        .with_file(".gitlab-ci.yml", &ci)
}

fn main() {
    let mut world = World::new(99);
    world.add_repo(repo_for("compute-bound-app", 0.15));
    world.add_repo(repo_for("memory-bound-app", 0.85));

    let mut sweeps = Vec::new();
    for name in ["compute-bound-app", "memory-bound-app"] {
        let pid = world.run_pipeline(name, Trigger::Manual).unwrap();
        let pipeline = world.pipeline(pid).unwrap();
        let analysis = pipeline
            .jobs
            .iter()
            .find(|j| j.name.ends_with("energy-analysis"))
            .expect("energy analysis job");
        println!("pipeline {pid} [{name}]:");
        for l in &analysis.log {
            println!("  {l}");
        }
        let repo = world.repo(name).unwrap();
        let (set, _) = ReportSet::load(&repo.store, "exacb.data", "");
        // reports live under the execution prefix "jedi.{name}", which is
        // what from_set filters on (DESIGN.md §11)
        sweeps.push(EnergySweep::from_set(&set, &format!("jedi.{name}")).expect("sweep"));
    }

    println!("\nenergy vs frequency (Fig. 9 series):");
    let mut t = Table::new(&["freq_mhz", "compute-bound [J]", "memory-bound [J]"]);
    for (i, &(f, e)) in sweeps[0].points.iter().enumerate() {
        t.push_row(vec![
            format!("{f:.0}"),
            format!("{e:.0}"),
            format!("{:.0}", sweeps[1].points[i].1),
        ]);
    }
    print!("{}", t.render());

    for s in &sweeps {
        println!(
            "{}: sweet spot {:.0} MHz, {:.1}% energy saving vs nominal",
            s.app,
            s.sweet_spot_mhz,
            s.saving_vs_nominal * 100.0
        );
    }
    assert!(
        sweeps[1].sweet_spot_mhz < sweeps[0].sweet_spot_mhz,
        "memory-bound app throttles lower"
    );
    // write the Fig. 9 plot
    std::fs::create_dir_all("out").ok();
    std::fs::write(
        "out/energy_study.svg",
        exacb::analysis::energy_sweep_plot(&sweeps).render_svg(),
    )
    .ok();
    println!("\nplot written to out/energy_study.svg\nenergy study OK");
}
