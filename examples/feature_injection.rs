//! Feature injection (paper §V-A.3, Fig. 6): tune `UCX_RNDV_THRESH` for
//! the OSU bandwidth benchmark *without changing the benchmark
//! definition* — the `in_command` input of `feature-injection@v3`
//! prepends an `export` to every remote step.
//!
//! Run with: `cargo run --release --example feature_injection`

use exacb::analysis::ReportSet;
use exacb::ci::Trigger;
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::util::json::Json;
use exacb::util::table::Table;

fn main() {
    let mut world = World::new(7);
    // one immutable benchmark definition, shared by every experiment
    let jube = "name: osu\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: 2\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - osu_bw\n";

    let thresholds: [u64; 6] = [1024, 8192, 65536, 262144, 1048576, 4194304];
    let mut curves: Vec<(u64, Vec<(f64, f64)>)> = Vec::new();
    for &thresh in &thresholds {
        let name = format!("osu-t{thresh}");
        let ci = format!(
            r#"
include:
  - component: feature-injection@v3
    inputs:
      prefix: "jupiter.osu.t{thresh}"
      machine: "jupiter"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/osu.yml"
      in_command: "export UCX_RNDV_THRESH=intra:{thresh},inter:{thresh}"
"#
        );
        world.add_repo(
            BenchmarkRepo::new(&name)
                .with_file("benchmark/jube/osu.yml", jube)
                .with_file(".gitlab-ci.yml", &ci),
        );
        let pid = world.run_pipeline(&name, Trigger::Manual).unwrap();
        assert!(world.pipeline(pid).unwrap().succeeded());

        let repo = world.repo(&name).unwrap();
        let (set, _) = ReportSet::load(&repo.store, "exacb.data", "");
        let mut curve = Vec::new();
        for (_, r) in &set.reports {
            for e in &r.data {
                assert_eq!(e.metric("rndv_thresh"), Some(thresh as f64));
                if let Some(rows) = e.metrics.get("bw_mbs").and_then(Json::as_arr) {
                    for row in rows {
                        let p = row.as_arr().unwrap();
                        curve.push((p[0].as_f64().unwrap(), p[1].as_f64().unwrap()));
                    }
                }
            }
        }
        curves.push((thresh, curve));
    }

    println!("OSU pt2pt bandwidth vs message size (Fig. 6 series), MB/s:");
    let mut t = Table::new(&[
        "msg_bytes", "t=1k", "t=8k", "t=64k", "t=256k", "t=1M", "t=4M",
    ]);
    for (i, &(size, _)) in curves[0].1.iter().enumerate() {
        let mut row = vec![format!("{size:.0}")];
        for (_, c) in &curves {
            row.push(format!("{:.0}", c[i].1));
        }
        t.push_row(row);
    }
    print!("{}", t.render());

    // the crossover story: at 64 KiB, small thresholds already use
    // rendezvous while large thresholds still copy through eager buffers
    let at = |c: &[(f64, f64)], s: f64| c.iter().find(|(x, _)| *x == s).unwrap().1;
    let bw_small_thresh = at(&curves[0].1, 65536.0);
    let bw_large_thresh = at(&curves[5].1, 65536.0);
    println!(
        "\nat 64 KiB: thresh=1k -> {bw_small_thresh:.0} MB/s (rendezvous), \
         thresh=4M -> {bw_large_thresh:.0} MB/s (eager)"
    );
    println!("feature injection OK — benchmark definition never changed");
}
