//! Quickstart: the paper's §II walk-through (data flow: DESIGN.md §3).
//!
//! Onboards the `logmap` benchmark repository (JUBE-style script + CI
//! config), runs one CI pipeline on the simulated JEDI system — setup →
//! execute (through the batch scheduler, with real PJRT kernel execution
//! when artifacts are built) → record — and prints the Table-I
//! `results.csv` plus the protocol report. The same flow is reachable
//! as `exacb quickstart`.
//!
//! Run with: `cargo run --example quickstart`

use exacb::ci::Trigger;
use exacb::coordinator::{BenchmarkRepo, World};
use exacb::util::table::Table;

fn main() {
    let mut world = World::new(42);
    if world.try_attach_engine() {
        println!("PJRT engine attached: kernels execute for real\n");
    } else {
        println!("artifacts not built (`make artifacts`): analytic models only\n");
    }

    // --- onboard the §II logmap benchmark repository -------------------
    let repo = BenchmarkRepo::logmap_example("jedi", "all");
    println!("repository 'logmap' files:");
    for (path, _) in &repo.files {
        println!("  {path}");
    }
    world.add_repo(repo);

    // --- run the CI pipeline --------------------------------------------
    let pid = world
        .run_pipeline("logmap", Trigger::Manual)
        .expect("pipeline runs");
    let pipeline = world.pipeline(pid).unwrap();
    println!("\npipeline {pid}: succeeded={}", pipeline.succeeded());
    for job in &pipeline.jobs {
        println!("  CI job {:>6} {}", job.id, job.name);
        for line in &job.log {
            println!("           | {line}");
        }
    }

    // --- Table I ---------------------------------------------------------
    let execute = pipeline.job("jedi.logmap.execute").unwrap();
    let csv = execute.artifact("results.csv").unwrap();
    println!("\nresults.csv (Table I contract):");
    print!("{}", Table::from_csv(csv).unwrap().render());

    // --- the protocol report on the data branch --------------------------
    let repo = world.repo("logmap").unwrap();
    let paths = repo.store.list("exacb.data", "jedi.logmap/");
    println!("\nexacb.data branch contents: {paths:?}");
    let report_path = paths.iter().find(|p| p.ends_with("report.json")).unwrap();
    let doc = repo.store.read("exacb.data", report_path).unwrap();
    let report = exacb::protocol::Report::parse(doc).expect("protocol-valid");
    println!(
        "protocol report: tool={} v{} pipeline={} system={} entries={}",
        report.reporter.tool,
        report.reporter.tool_version,
        report.reporter.pipeline_id,
        report.experiment.system,
        report.data.len()
    );
    assert!(pipeline.succeeded());
    println!("\nquickstart OK");
}
