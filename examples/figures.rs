//! Regenerate every paper table and figure to `out/` (CSV + SVG) and
//! print the series. Thin wrapper over `exacb::experiments` (also
//! reachable as `exacb figures`).
//!
//! Run with: `cargo run --release --example figures [-- days]`

fn main() {
    let days = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(90);
    let out = std::path::Path::new("out");
    for result in exacb::experiments::run_all(days, 2026) {
        result.print();
        result.save(out).expect("write artifacts");
    }
    println!("\nall figures regenerated under out/");
}
