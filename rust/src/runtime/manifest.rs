//! AOT-artifact manifest (produced by `python/compile/aot.py`).
//!
//! `make artifacts` lowers the L2 JAX models (with embedded L1 Pallas
//! kernels) to HLO text and writes `artifacts/manifest.json`, indexing
//! each compiled variant with its shapes, FLOP count and HBM traffic.
//! The Rust side reads only this manifest + the `.hlo.txt` files; Python
//! is never invoked at runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum ManifestError {
    Missing(String),
    Parse(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Missing(p) => {
                write!(f, "artifacts not built: {p} (run `make artifacts`)")
            }
            ManifestError::Parse(e) => write!(f, "manifest parse error: {e}"),
        }
    }
}

impl std::error::Error for ManifestError {}

/// One tensor description.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "logmap" | "stream"
    pub kind: String,
    pub params: Json,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub flops: u64,
    pub bytes: u64,
}

impl ArtifactEntry {
    pub fn n(&self) -> usize {
        self.params.u64_of("n").unwrap_or(0) as usize
    }

    pub fn iters(&self) -> u64 {
        self.params.u64_of("iters").unwrap_or(0)
    }
}

/// The parsed manifest with its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| ManifestError::Missing(format!("{}: {e}", path.display())))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ManifestError> {
        let doc = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let mut entries = Vec::new();
        for (i, e) in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("missing 'artifacts'".into()))?
            .iter()
            .enumerate()
        {
            entries.push(parse_entry(e, i)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Pick the logmap variant closest to (iters, n).
    pub fn best_logmap(&self, iters: u64, n: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == "logmap")
            .min_by_key(|e| {
                let di = (e.iters() as i64 - iters as i64).unsigned_abs();
                let dn = (e.n() as i64 - n as i64).unsigned_abs();
                // prioritise iteration match, then size
                di * 1_000_000 + dn
            })
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Content fingerprint of the artifact set — part of the execution
    /// cache key, so a rebuilt engine invalidates cached runs.
    pub fn fingerprint(&self) -> String {
        let mut payload = String::new();
        for e in &self.entries {
            payload.push_str(&format!(
                "{}|{}|{}|{}|{}\n",
                e.name, e.file, e.kind, e.flops, e.bytes
            ));
        }
        crate::util::wide_hash(payload.as_bytes())
    }
}

fn parse_entry(e: &Json, i: usize) -> Result<ArtifactEntry, ManifestError> {
    let tensors = |key: &str| -> Result<Vec<TensorSpec>, ManifestError> {
        let mut out = Vec::new();
        for t in e.get(key).and_then(Json::as_arr).unwrap_or(&[]) {
            out.push(TensorSpec {
                name: t.str_of("name").unwrap_or("").to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_u64().map(|v| v as usize))
                    .collect(),
                dtype: t.str_of("dtype").unwrap_or("f32").to_string(),
            });
        }
        Ok(out)
    };
    Ok(ArtifactEntry {
        name: e
            .str_of("name")
            .ok_or_else(|| ManifestError::Parse(format!("artifacts[{i}]: missing name")))?
            .to_string(),
        file: e
            .str_of("file")
            .ok_or_else(|| ManifestError::Parse(format!("artifacts[{i}]: missing file")))?
            .to_string(),
        kind: e.str_of("kind").unwrap_or("unknown").to_string(),
        params: e.get("params").cloned().unwrap_or_else(Json::obj),
        inputs: tensors("inputs")?,
        outputs: tensors("outputs")?,
        flops: e.u64_of("flops").unwrap_or(0),
        bytes: e.u64_of("bytes").unwrap_or(0),
    })
}

/// Default artifacts directory: `$EXACB_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("EXACB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "logmap_i128_n16384", "file": "logmap_i128_n16384.hlo.txt",
         "kind": "logmap", "params": {"n": 16384, "iters": 128, "block": 16384},
         "inputs": [{"name": "x", "shape": [16384], "dtype": "f32"},
                     {"name": "r", "shape": [16384], "dtype": "f32"}],
         "outputs": [{"name": "out", "shape": [16384], "dtype": "f32"},
                      {"name": "summary", "shape": [4], "dtype": "f32"}],
         "flops": 6291456, "bytes": 196608},
        {"name": "logmap_i2048_n65536", "file": "logmap_i2048_n65536.hlo.txt",
         "kind": "logmap", "params": {"n": 65536, "iters": 2048, "block": 16384},
         "inputs": [], "outputs": [], "flops": 402653184, "bytes": 786432}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("logmap_i128_n16384").unwrap();
        assert_eq!(e.n(), 16384);
        assert_eq!(e.iters(), 128);
        assert_eq!(e.inputs[0].elements(), 16384);
        assert_eq!(e.flops, 6291456);
    }

    #[test]
    fn best_logmap_prefers_iteration_match() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.best_logmap(2048, 1000).unwrap().name, "logmap_i2048_n65536");
        assert_eq!(m.best_logmap(100, 16384).unwrap().name, "logmap_i128_n16384");
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Soft check: only when `make artifacts` has run.
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.iter().any(|e| e.kind == "logmap"));
            assert!(m.entries.iter().any(|e| e.kind == "stream"));
            for e in &m.entries {
                assert!(m.hlo_path(e).exists(), "{}", e.file);
            }
        }
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(matches!(
            Manifest::load(Path::new("/nonexistent-dir-xyz")),
            Err(ManifestError::Missing(_))
        ));
    }
}
