//! PJRT runtime (DESIGN.md §1 runtime layer, §2 substrate table): loads
//! the AOT-compiled HLO artifacts (L2 JAX models with embedded L1 Pallas
//! kernels) and executes them natively via the XLA PJRT C API. Python
//! only ever runs at `make artifacts` time.
//!
//! * [`manifest`] — the artifact index written by `python/compile/aot.py`.
//! * [`engine`] — PJRT CPU client + compile cache + typed entry points.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineError, ExecOutput};
pub use manifest::{ArtifactEntry, Manifest, ManifestError, TensorSpec};
