//! PJRT execution engine: load AOT HLO artifacts, compile once, execute
//! from the benchmarking hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto
//! -> XlaComputation -> PjRtClient::compile -> execute. Compiled
//! executables are cached per artifact, so a 90-day simulated campaign
//! pays compilation once per variant (see EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactEntry, Manifest};

/// Execution result of one artifact invocation.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// Flattened f32 outputs, in artifact output order.
    pub outputs: Vec<Vec<f32>>,
    /// Host wall-clock of the execute call (compile excluded).
    pub wall: Duration,
}

/// PJRT CPU engine with a compile cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative executions (introspection for perf benches).
    pub executions: u64,
    pub compilations: u64,
}

impl Engine {
    /// Load the engine from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!("{e}"))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            cache: HashMap::new(),
            executions: 0,
            compilations: 0,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(&super::manifest::default_dir())
    }

    fn executable(&mut self, entry: &ArtifactEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&entry.name) {
            let path = self.manifest.hlo_path(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", entry.name))?;
            self.compilations += 1;
            self.cache.insert(entry.name.clone(), exe);
        }
        Ok(&self.cache[&entry.name])
    }

    /// Execute an artifact on f32 input buffers (shapes per manifest).
    pub fn execute(&mut self, name: &str, inputs: &[&[f32]]) -> Result<ExecOutput> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' wants {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            ));
        }
        for (spec, buf) in entry.inputs.iter().zip(inputs) {
            if spec.elements() != buf.len() {
                return Err(anyhow!(
                    "artifact '{name}' input '{}' wants {} elements, got {}",
                    spec.name,
                    spec.elements(),
                    buf.len()
                ));
            }
        }
        let n_outputs = entry.outputs.len();
        let exe = self.executable(&entry)?;

        let literals: Vec<xla::Literal> = entry
            .inputs
            .iter()
            .zip(inputs)
            .map(|(spec, buf)| {
                let lit = xla::Literal::vec1(buf);
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("reshape input literal")
            })
            .collect::<Result<_>>()?;

        let start = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .context("PJRT execute")?;
        let wall = start.elapsed();

        let root = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("empty execution result"))?
            .to_literal_sync()
            .context("device->host transfer")?;
        // aot.py lowers with return_tuple=True: root is a tuple literal.
        let elements = root.to_tuple().context("untuple result")?;
        if elements.len() != n_outputs {
            return Err(anyhow!(
                "artifact '{name}': expected {n_outputs} outputs, got {}",
                elements.len()
            ));
        }
        let outputs = elements
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("output to f32 vec"))
            .collect::<Result<Vec<_>>>()?;
        self.executions += 1;
        Ok(ExecOutput { outputs, wall })
    }

    /// Run the logmap artifact: returns (out, summary, wall).
    pub fn run_logmap(
        &mut self,
        name: &str,
        x: &[f32],
        r: &[f32],
    ) -> Result<(Vec<f32>, [f32; 4], Duration)> {
        let out = self.execute(name, &[x, r])?;
        let summary: [f32; 4] = out.outputs[1]
            .as_slice()
            .try_into()
            .map_err(|_| anyhow!("summary must have 4 elements"))?;
        Ok((out.outputs.into_iter().next().unwrap(), summary, out.wall))
    }

    /// Run the stream artifact on a constant-initialised `a` array:
    /// returns ([copy, mul, add, triad, dot] checksums, wall). The
    /// initial b/c arrays are overwritten before first read by the
    /// BabelStream dataflow, so only `a` is an input (see model.py).
    pub fn run_stream(&mut self, name: &str, a0: f32) -> Result<([f32; 5], Duration)> {
        let n = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .n();
        let a = vec![a0; n];
        let out = self.execute(name, &[&a])?;
        let sums: [f32; 5] = out.outputs[0]
            .as_slice()
            .try_into()
            .map_err(|_| anyhow!("checksums must have 5 elements"))?;
        Ok((sums, out.wall))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;

    fn engine() -> Option<Engine> {
        if default_dir().join("manifest.json").exists() {
            Some(Engine::load_default().expect("engine loads"))
        } else {
            eprintln!("skipping PJRT test: artifacts not built");
            None
        }
    }

    /// Scalar reference implementation used to validate PJRT output.
    fn logmap_scalar(x: f32, r: f32, iters: u64) -> f32 {
        let mut v = x;
        for _ in 0..iters {
            v = r * v * (1.0 - v);
        }
        v
    }

    #[test]
    fn logmap_artifact_matches_scalar_reference() {
        let Some(mut eng) = engine() else { return };
        let entry = eng.manifest.best_logmap(128, 16384).unwrap().clone();
        let n = entry.n();
        let x: Vec<f32> = (0..n).map(|i| 0.1 + 0.8 * (i as f32 / n as f32)).collect();
        let r = vec![3.5f32; n];
        let (out, summary, wall) = eng.run_logmap(&entry.name, &x, &r).unwrap();
        assert_eq!(out.len(), n);
        assert!(wall.as_nanos() > 0);
        // spot-check against the scalar reference (f32 rounding differs
        // slightly between vectorised XLA and the scalar loop)
        for &i in &[0usize, 1, n / 2, n - 1] {
            let want = logmap_scalar(x[i], 3.5, entry.iters());
            let got = out[i];
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1e-3),
                "i={i} got={got} want={want}"
            );
        }
        // summary[3] = sum
        let sum: f32 = out.iter().sum();
        assert!((summary[3] - sum).abs() < 0.05 * sum.abs().max(1.0));
    }

    #[test]
    fn stream_artifact_matches_closed_form() {
        let Some(mut eng) = engine() else { return };
        let name = eng
            .manifest
            .entries
            .iter()
            .find(|e| e.kind == "stream")
            .unwrap()
            .name
            .clone();
        let n = eng.manifest.get(&name).unwrap().n() as f64;
        let (sums, wall) = eng.run_stream(&name, 0.1).unwrap();
        assert!(wall.as_nanos() > 0);
        // closed forms (see python model.stream_checksums_expected)
        let scalar = 0.4f64;
        let c1 = 0.1;
        let b1 = scalar * c1;
        let c2 = 0.1 + b1;
        let a1 = b1 + scalar * c2;
        let expect = [n * c1, n * b1, n * c2, n * a1, a1 * b1 * n];
        for (i, (&got, want)) in sums.iter().zip(expect).enumerate() {
            assert!(
                ((got as f64) - want).abs() < 1e-3 * want.abs(),
                "checksum {i}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn compile_cache_reused() {
        let Some(mut eng) = engine() else { return };
        let entry = eng.manifest.best_logmap(128, 16384).unwrap().clone();
        let n = entry.n();
        let x = vec![0.5f32; n];
        let r = vec![3.2f32; n];
        eng.run_logmap(&entry.name, &x, &r).unwrap();
        eng.run_logmap(&entry.name, &x, &r).unwrap();
        assert_eq!(eng.compilations, 1);
        assert_eq!(eng.executions, 2);
    }

    #[test]
    fn bad_inputs_rejected() {
        let Some(mut eng) = engine() else { return };
        let entry = eng.manifest.best_logmap(128, 16384).unwrap().clone();
        assert!(eng.execute(&entry.name, &[&[0.0f32]]).is_err());
        assert!(eng.execute("ghost", &[]).is_err());
    }
}
