//! PJRT execution engine: load AOT HLO artifacts, compile once, execute
//! from the benchmarking hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto
//! -> XlaComputation -> PjRtClient::compile -> execute. Compiled
//! executables are cached per artifact, so a 90-day simulated campaign
//! pays compilation once per variant (see EXPERIMENTS.md §Perf).
//!
//! The real backend needs the external `xla` PJRT bindings, which the
//! offline build does not vendor, so it is gated behind the `pjrt`
//! feature. The default build compiles a manifest-aware stub whose
//! `load` fails cleanly — every caller already treats an absent engine
//! as "use the analytic models" (`World::try_attach_engine`).

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;
#[cfg(feature = "pjrt")]
use std::time::Instant;

use super::manifest::{ArtifactEntry, Manifest};

/// Engine-layer error (load, compile, or execute).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineError(pub String);

impl EngineError {
    pub fn msg(m: impl Into<String>) -> EngineError {
        EngineError(m.into())
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine: {}", self.0)
    }
}

impl std::error::Error for EngineError {}

type Result<T> = std::result::Result<T, EngineError>;

/// Execution result of one artifact invocation.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// Flattened f32 outputs, in artifact output order.
    pub outputs: Vec<Vec<f32>>,
    /// Host wall-clock of the execute call (compile excluded).
    pub wall: Duration,
}

/// PJRT CPU engine with a compile cache.
pub struct Engine {
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative executions (introspection for perf benches).
    pub executions: u64,
    pub compilations: u64,
}

impl Engine {
    /// Load the engine from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir).map_err(|e| EngineError(e.to_string()))?;
        Self::with_manifest(manifest)
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(&super::manifest::default_dir())
    }

    #[cfg(feature = "pjrt")]
    fn with_manifest(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| EngineError(format!("create PJRT CPU client: {e}")))?;
        Ok(Engine {
            manifest,
            client,
            cache: HashMap::new(),
            executions: 0,
            compilations: 0,
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn with_manifest(_manifest: Manifest) -> Result<Engine> {
        Err(EngineError::msg(
            "PJRT backend not compiled in (rebuild with `--features pjrt` \
             and the xla bindings available); analytic models stay in effect",
        ))
    }

    #[cfg(feature = "pjrt")]
    fn executable(&mut self, entry: &ArtifactEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&entry.name) {
            let path = self.manifest.hlo_path(entry);
            let path_str = path
                .to_str()
                .ok_or_else(|| EngineError::msg("artifact path not utf-8"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| EngineError(format!("parse HLO text {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| EngineError(format!("compile {}: {e}", entry.name)))?;
            self.compilations += 1;
            self.cache.insert(entry.name.clone(), exe);
        }
        Ok(&self.cache[&entry.name])
    }

    /// Execute an artifact on f32 input buffers (shapes per manifest).
    pub fn execute(&mut self, name: &str, inputs: &[&[f32]]) -> Result<ExecOutput> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| EngineError(format!("unknown artifact '{name}'")))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            return Err(EngineError(format!(
                "artifact '{name}' wants {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            )));
        }
        for (spec, buf) in entry.inputs.iter().zip(inputs) {
            if spec.elements() != buf.len() {
                return Err(EngineError(format!(
                    "artifact '{name}' input '{}' wants {} elements, got {}",
                    spec.name,
                    spec.elements(),
                    buf.len()
                )));
            }
        }
        self.execute_checked(&entry, inputs)
    }

    #[cfg(feature = "pjrt")]
    fn execute_checked(&mut self, entry: &ArtifactEntry, inputs: &[&[f32]]) -> Result<ExecOutput> {
        let n_outputs = entry.outputs.len();
        let name = entry.name.clone();
        let exe = self.executable(entry)?;

        let literals: Vec<xla::Literal> = entry
            .inputs
            .iter()
            .zip(inputs)
            .map(|(spec, buf)| {
                let lit = xla::Literal::vec1(buf);
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .map_err(|e| EngineError(format!("reshape input literal: {e}")))
            })
            .collect::<Result<_>>()?;

        let start = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| EngineError(format!("PJRT execute: {e}")))?;
        let wall = start.elapsed();

        let root = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| EngineError::msg("empty execution result"))?
            .to_literal_sync()
            .map_err(|e| EngineError(format!("device->host transfer: {e}")))?;
        // aot.py lowers with return_tuple=True: root is a tuple literal.
        let elements = root
            .to_tuple()
            .map_err(|e| EngineError(format!("untuple result: {e}")))?;
        if elements.len() != n_outputs {
            return Err(EngineError(format!(
                "artifact '{name}': expected {n_outputs} outputs, got {}",
                elements.len()
            )));
        }
        let outputs = elements
            .into_iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .map_err(|e| EngineError(format!("output to f32 vec: {e}")))
            })
            .collect::<Result<Vec<_>>>()?;
        self.executions += 1;
        Ok(ExecOutput { outputs, wall })
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute_checked(&mut self, entry: &ArtifactEntry, _inputs: &[&[f32]]) -> Result<ExecOutput> {
        Err(EngineError(format!(
            "artifact '{}': PJRT backend not compiled in",
            entry.name
        )))
    }

    /// Run the logmap artifact: returns (out, summary, wall).
    pub fn run_logmap(
        &mut self,
        name: &str,
        x: &[f32],
        r: &[f32],
    ) -> Result<(Vec<f32>, [f32; 4], Duration)> {
        let out = self.execute(name, &[x, r])?;
        let summary: [f32; 4] = out.outputs[1]
            .as_slice()
            .try_into()
            .map_err(|_| EngineError::msg("summary must have 4 elements"))?;
        Ok((out.outputs.into_iter().next().unwrap(), summary, out.wall))
    }

    /// Run the stream artifact on a constant-initialised `a` array:
    /// returns ([copy, mul, add, triad, dot] checksums, wall). The
    /// initial b/c arrays are overwritten before first read by the
    /// BabelStream dataflow, so only `a` is an input (see model.py).
    pub fn run_stream(&mut self, name: &str, a0: f32) -> Result<([f32; 5], Duration)> {
        let n = self
            .manifest
            .get(name)
            .ok_or_else(|| EngineError(format!("unknown artifact '{name}'")))?
            .n();
        let a = vec![a0; n];
        let out = self.execute(name, &[&a])?;
        let sums: [f32; 5] = out.outputs[0]
            .as_slice()
            .try_into()
            .map_err(|_| EngineError::msg("checksums must have 5 elements"))?;
        Ok((sums, out.wall))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;

    fn engine() -> Option<Engine> {
        if default_dir().join("manifest.json").exists() {
            match Engine::load_default() {
                Ok(e) => Some(e),
                Err(e) => {
                    crate::obs_warn!("skipping PJRT test: {e}");
                    None
                }
            }
        } else {
            crate::obs_warn!("skipping PJRT test: artifacts not built");
            None
        }
    }

    /// Scalar reference implementation used to validate PJRT output.
    fn logmap_scalar(x: f32, r: f32, iters: u64) -> f32 {
        let mut v = x;
        for _ in 0..iters {
            v = r * v * (1.0 - v);
        }
        v
    }

    #[test]
    fn stub_load_fails_cleanly_without_artifacts() {
        let missing = std::path::Path::new("/nonexistent-artifacts-dir");
        let err = Engine::load(missing).unwrap_err();
        assert!(err.to_string().contains("engine:"), "{err}");
    }

    #[test]
    fn logmap_artifact_matches_scalar_reference() {
        let Some(mut eng) = engine() else { return };
        let entry = eng.manifest.best_logmap(128, 16384).unwrap().clone();
        let n = entry.n();
        let x: Vec<f32> = (0..n).map(|i| 0.1 + 0.8 * (i as f32 / n as f32)).collect();
        let r = vec![3.5f32; n];
        let (out, summary, wall) = eng.run_logmap(&entry.name, &x, &r).unwrap();
        assert_eq!(out.len(), n);
        assert!(wall.as_nanos() > 0);
        // spot-check against the scalar reference (f32 rounding differs
        // slightly between vectorised XLA and the scalar loop)
        for &i in &[0usize, 1, n / 2, n - 1] {
            let want = logmap_scalar(x[i], 3.5, entry.iters());
            let got = out[i];
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1e-3),
                "i={i} got={got} want={want}"
            );
        }
        // summary[3] = sum
        let sum: f32 = out.iter().sum();
        assert!((summary[3] - sum).abs() < 0.05 * sum.abs().max(1.0));
    }

    #[test]
    fn stream_artifact_matches_closed_form() {
        let Some(mut eng) = engine() else { return };
        let name = eng
            .manifest
            .entries
            .iter()
            .find(|e| e.kind == "stream")
            .unwrap()
            .name
            .clone();
        let n = eng.manifest.get(&name).unwrap().n() as f64;
        let (sums, wall) = eng.run_stream(&name, 0.1).unwrap();
        assert!(wall.as_nanos() > 0);
        // closed forms (see python model.stream_checksums_expected)
        let scalar = 0.4f64;
        let c1 = 0.1;
        let b1 = scalar * c1;
        let c2 = 0.1 + b1;
        let a1 = b1 + scalar * c2;
        let expect = [n * c1, n * b1, n * c2, n * a1, a1 * b1 * n];
        for (i, (&got, want)) in sums.iter().zip(expect).enumerate() {
            assert!(
                ((got as f64) - want).abs() < 1e-3 * want.abs(),
                "checksum {i}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn compile_cache_reused() {
        let Some(mut eng) = engine() else { return };
        let entry = eng.manifest.best_logmap(128, 16384).unwrap().clone();
        let n = entry.n();
        let x = vec![0.5f32; n];
        let r = vec![3.2f32; n];
        eng.run_logmap(&entry.name, &x, &r).unwrap();
        eng.run_logmap(&entry.name, &x, &r).unwrap();
        assert_eq!(eng.compilations, 1);
        assert_eq!(eng.executions, 2);
    }

    #[test]
    fn bad_inputs_rejected() {
        let Some(mut eng) = engine() else { return };
        let entry = eng.manifest.best_logmap(128, 16384).unwrap().clone();
        assert!(eng.execute(&entry.name, &[&[0.0f32]]).is_err());
        assert!(eng.execute("ghost", &[]).is_err());
    }
}
