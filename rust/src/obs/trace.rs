//! Deterministic sim-time span tracer (DESIGN.md §13).
//!
//! Every event is stamped with **simulated** time ([`SimTime`] seconds)
//! and content-derived identifiers (jobids, pipeline ids, app names) —
//! never wall clock, never memory addresses — so a trace of a campaign
//! is a pure function of the campaign's inputs: byte-identical across
//! replays and identical whether the indexed dispatcher
//! (`event_loop::drive`) or the reference scan (`drive_reference`)
//! drove it.
//!
//! Recording is thread-local and **off by default** (the
//! [`crate::obs::set_tracing`] arming switch, mirroring
//! `BatchSystem::set_event_log`): the disarmed emission path is a
//! single `Cell<bool>` read, adding zero allocations to the dispatch
//! hot path. Call sites guard span-argument construction behind
//! [`crate::obs::tracing`] so even the `format!` never runs disarmed.
//!
//! [`drain`] returns the recorded events in **canonical content order**
//! (ts, track, name, dur, args) rather than emission order — cross-track
//! emission interleaving is an implementation detail of the dispatcher,
//! and sorting by content is what makes the byte-identity contract hold
//! unconditionally. [`chrome_trace_json`] renders the canonical list as
//! Chrome trace-event JSON (`trace.json`), loadable in Perfetto or
//! `chrome://tracing`, with one synthetic thread per track and
//! timestamps in sim-time microseconds.

use std::cell::RefCell;

use crate::util::json::Json;
use crate::util::timeutil::SimTime;

/// One recorded span (`dur >= 0`, seconds) or instant (`dur == -1`).
/// Field order is the canonical sort order — the derived `Ord` is the
/// content order [`drain`] returns.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct TraceEvent {
    /// Sim-time start, seconds since the epoch.
    pub ts: i64,
    /// Rendering lane: a machine name or a subsystem name.
    pub track: String,
    /// Event name (e.g. `queue-wait`, `run`, `pipeline`).
    pub name: String,
    /// Duration in sim-time seconds; `-1` marks an instant event.
    pub dur: i64,
    /// Content-derived key/value labels (jobid, pipeline, state, ...).
    pub args: Vec<(String, String)>,
}

/// Marker duration of an instant event.
pub const INSTANT: i64 = -1;

thread_local! {
    static EVENTS: RefCell<Vec<TraceEvent>> = const { RefCell::new(Vec::new()) };
}

/// Record a completed span `[start, end]` on `track`. No-op when
/// tracing is disarmed; callers should still guard argument
/// construction with [`crate::obs::tracing`].
pub fn span(track: &str, name: &str, start: SimTime, end: SimTime, args: Vec<(String, String)>) {
    if !crate::obs::tracing() {
        return;
    }
    EVENTS.with(|e| {
        e.borrow_mut().push(TraceEvent {
            ts: start.0,
            track: track.to_string(),
            name: name.to_string(),
            dur: (end.0 - start.0).max(0),
            args,
        })
    });
}

/// Record an instant event at `ts` on `track`. No-op when disarmed.
pub fn instant(track: &str, name: &str, ts: SimTime, args: Vec<(String, String)>) {
    if !crate::obs::tracing() {
        return;
    }
    EVENTS.with(|e| {
        e.borrow_mut().push(TraceEvent {
            ts: ts.0,
            track: track.to_string(),
            name: name.to_string(),
            dur: INSTANT,
            args,
        })
    });
}

/// Number of events recorded so far on this thread.
pub fn event_count() -> usize {
    EVENTS.with(|e| e.borrow().len())
}

/// Take every recorded event, leaving the recorder empty, in canonical
/// content order (see module docs).
pub fn drain() -> Vec<TraceEvent> {
    let mut out = EVENTS.with(|e| std::mem::take(&mut *e.borrow_mut()));
    out.sort();
    out
}

/// Helper for call sites: build the owned `args` vector from borrowed
/// keys. Only call under a [`crate::obs::tracing`] guard — this is the
/// allocating half the guard exists to skip.
pub fn args(pairs: &[(&str, String)]) -> Vec<(String, String)> {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.clone()))
        .collect()
}

/// Render events (already canonically ordered) as Chrome trace-event
/// JSON: pid 1, one tid per distinct track (in sorted track order, so
/// the lane layout is content-stable), `ph: "X"` complete events for
/// spans and `ph: "i"` instants, timestamps and durations in sim-time
/// **microseconds**.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut tracks: Vec<&str> = events.iter().map(|e| e.track.as_str()).collect();
    tracks.sort();
    tracks.dedup();
    let mut arr = Json::arr();
    for (i, t) in tracks.iter().enumerate() {
        arr.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "thread_name")
                .set("pid", 1u64)
                .set("tid", (i + 1) as u64)
                .set("args", Json::obj().set("name", *t)),
        );
    }
    for e in events {
        let tid = tracks
            .binary_search(&e.track.as_str())
            .map(|i| i + 1)
            .unwrap_or(0);
        let mut o = Json::obj()
            .set("name", e.name.as_str())
            .set("pid", 1u64)
            .set("tid", tid as u64)
            .set("ts", (e.ts as f64) * 1e6);
        if e.dur >= 0 {
            o.insert("ph", "X");
            o.insert("dur", (e.dur as f64) * 1e6);
        } else {
            o.insert("ph", "i");
            o.insert("s", "t");
        }
        if !e.args.is_empty() {
            let mut a = Json::obj();
            for (k, v) in &e.args {
                a.insert(k, v.as_str());
            }
            o.insert("args", a);
        }
        arr.push(o);
    }
    Json::obj()
        .set("traceEvents", arr)
        .set("displayTimeUnit", "ms")
        .pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_recorder_stays_empty() {
        drain();
        span("jedi", "run", SimTime(0), SimTime(10), Vec::new());
        instant("jedi", "tick", SimTime(5), Vec::new());
        assert_eq!(event_count(), 0);
    }

    #[test]
    fn armed_events_drain_in_canonical_order() {
        drain();
        let prior = crate::obs::set_tracing(true);
        // emitted out of content order on purpose
        span("zeta", "run", SimTime(20), SimTime(25), Vec::new());
        instant("alpha", "run", SimTime(5), args(&[("k", "v".to_string())]));
        span("alpha", "run", SimTime(5), SimTime(9), Vec::new());
        let evs = drain();
        crate::obs::set_tracing(prior);
        assert_eq!(evs.len(), 3);
        assert_eq!((evs[0].ts, evs[0].track.as_str()), (5, "alpha"));
        // same ts: instants (dur -1) sort before spans via the dur key
        assert_eq!(evs[0].dur, INSTANT);
        assert_eq!(evs[1].dur, 4);
        assert_eq!(evs[2].track, "zeta");
        assert_eq!(event_count(), 0, "drain empties the recorder");
    }

    #[test]
    fn chrome_json_shape() {
        let evs = vec![
            TraceEvent {
                ts: 3,
                track: "jedi".into(),
                name: "run".into(),
                dur: 7,
                args: vec![("jobid".into(), "7700001".into())],
            },
            TraceEvent {
                ts: 4,
                track: "jupiter".into(),
                name: "tick".into(),
                dur: INSTANT,
                args: Vec::new(),
            },
        ];
        let doc = Json::parse(&chrome_trace_json(&evs)).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 2 thread_name metadata + 2 events
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].str_of("ph"), Some("M"));
        let run = events.iter().find(|e| e.str_of("ph") == Some("X")).unwrap();
        assert_eq!(run.f64_of("ts"), Some(3e6));
        assert_eq!(run.f64_of("dur"), Some(7e6));
        assert_eq!(run.get("args").unwrap().str_of("jobid"), Some("7700001"));
        let tick = events.iter().find(|e| e.str_of("ph") == Some("i")).unwrap();
        assert_eq!(tick.str_of("s"), Some("t"));
    }
}
