//! Deterministic observability: sim-time span tracing, an
//! allocation-flat metrics registry, and a leveled narration facade
//! (DESIGN.md §13).
//!
//! The layer answers *why* a campaign behaved the way it did — queue
//! contention, backfill decisions, cache-hit timing, gate
//! re-measurement storms — without perturbing *what* it produced:
//!
//! * **Sim-time only.** Every span and instant is stamped from
//!   content-derived simulated clocks ([`crate::util::timeutil::SimTime`]):
//!   job records' submit/start/end times, machine-local `BatchSystem`
//!   clocks at deterministic wake points, pipeline creation times.
//!   Never wall clock, never `World::now()` mid-drive (the
//!   max-over-machines clock is dispatch-order sensitive). Subsystems
//!   without a clock of their own (cache, snapshots, maturity) emit
//!   counters, not spans.
//! * **Off by default, nest-safe arming.** [`set_tracing`] /
//!   [`set_metrics`] mirror `BatchSystem::set_event_log`: they return
//!   the prior state so instrumented scopes can restore it. Disarmed,
//!   every emission site is a single thread-local flag read and the
//!   span-argument `format!`s are skipped at the call site — the
//!   dispatch hot path gains zero allocations (asserted by
//!   `benches/perf_obs.rs`).
//! * **Byte-reproducible.** Traces drain in canonical content order, so
//!   a campaign's trace is identical across replays and across the
//!   indexed dispatcher / reference scan (pinned by
//!   `tests/integration_obs.rs`).
//! * **Sidecars only.** Exports land in `trace.json` (Chrome
//!   trace-event JSON) and `obs.json` — the same contract as
//!   `cache.json` / `energy.json`: never inside `report.json`, sacct
//!   records, or the data store.

pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{count, count_app, count_machine, observe, Ctr, Hist, MetricsSnapshot};
pub use trace::TraceEvent;

use std::cell::Cell;

thread_local! {
    static TRACING: Cell<bool> = const { Cell::new(false) };
    static METRICS: Cell<bool> = const { Cell::new(false) };
}

/// Is span tracing armed on this thread? Call sites use this to guard
/// span-argument construction so the disarmed path never allocates.
#[inline]
pub fn tracing() -> bool {
    TRACING.with(|c| c.get())
}

/// Is the metrics registry armed on this thread?
#[inline]
pub fn metrics_on() -> bool {
    METRICS.with(|c| c.get())
}

/// Arm or disarm span tracing; returns the prior state (nest-safe, like
/// `BatchSystem::set_event_log`).
pub fn set_tracing(on: bool) -> bool {
    TRACING.with(|c| c.replace(on))
}

/// Arm or disarm the metrics registry; returns the prior state.
pub fn set_metrics(on: bool) -> bool {
    METRICS.with(|c| c.replace(on))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arming_is_nest_safe() {
        assert!(!tracing());
        assert!(!metrics_on());
        let outer = set_tracing(true);
        assert!(!outer);
        let inner = set_tracing(true);
        assert!(inner, "inner scope sees the outer arming");
        set_tracing(inner);
        assert!(tracing(), "restoring the inner state keeps the outer scope armed");
        set_tracing(outer);
        assert!(!tracing());
        let m = set_metrics(true);
        assert!(metrics_on());
        set_metrics(m);
        assert!(!metrics_on());
    }
}
