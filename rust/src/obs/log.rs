//! Leveled narration facade (DESIGN.md §13).
//!
//! All ad-hoc `println!`/`eprintln!` narration in the crate routes
//! through here so one switch governs it: the `EXACB_LOG` environment
//! variable (`off`, `error`, `warn`, `info`, `debug`) or the CLI's
//! `--quiet` flag. Narration always goes to **stderr**; CLI result
//! tables stay on stdout untouched, so piping `exacb ... | tool` keeps
//! working however chatty the run is.
//!
//! The level is resolved once (lazily) and cached in an atomic, so the
//! disabled path is a single relaxed load — cheap enough for test
//! helpers and workload narration alike. The [`crate::obs_info!`]-style
//! macros check [`enabled`] *before* formatting, so a suppressed line
//! never allocates its message.

use std::sync::atomic::{AtomicU8, Ordering};

/// Narration severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Something went wrong and the run's output may be incomplete.
    Error = 1,
    /// Something was skipped or degraded (e.g. a missing backend).
    Warn = 2,
    /// Progress narration (the default verbosity).
    Info = 3,
    /// Tracing-adjacent detail, off by default.
    Debug = 4,
}

impl Level {
    /// The stderr line prefix.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Verbosity threshold: 0 = off, 1..=4 = show levels up to that
/// severity rank. `UNSET` defers to `EXACB_LOG` on first use.
static THRESHOLD: AtomicU8 = AtomicU8::new(UNSET);
const UNSET: u8 = u8::MAX;
const DEFAULT: u8 = Level::Info as u8;

fn parse_level(s: &str) -> u8 {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "none" | "quiet" | "0" => 0,
        "error" => Level::Error as u8,
        "warn" | "warning" => Level::Warn as u8,
        "info" => Level::Info as u8,
        "debug" | "trace" => Level::Debug as u8,
        _ => DEFAULT,
    }
}

fn threshold() -> u8 {
    let v = THRESHOLD.load(Ordering::Relaxed);
    if v != UNSET {
        return v;
    }
    let v = std::env::var("EXACB_LOG")
        .map(|s| parse_level(&s))
        .unwrap_or(DEFAULT);
    THRESHOLD.store(v, Ordering::Relaxed);
    v
}

/// Set the verbosity threshold explicitly (overrides `EXACB_LOG`).
/// Returns the previous effective threshold rank.
pub fn set_level(level: Level) -> u8 {
    let prev = threshold();
    THRESHOLD.store(level as u8, Ordering::Relaxed);
    prev
}

/// Silence everything below [`Level::Error`] — the `--quiet` switch.
pub fn set_quiet() {
    THRESHOLD.store(Level::Error as u8, Ordering::Relaxed);
}

/// Restore a threshold rank previously returned by [`set_level`].
pub fn restore_level(rank: u8) {
    THRESHOLD.store(rank, Ordering::Relaxed);
}

/// Would a message at `level` be shown? Checked by the macros before
/// the message is formatted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= threshold()
}

/// Emit one narration line to stderr. Call through the macros, which
/// gate on [`enabled`] first.
pub fn write_line(level: Level, msg: &str) {
    eprintln!("{}: {msg}", level.tag());
}

/// Log at an explicit [`Level`]; the message is only formatted when the
/// level is enabled.
#[macro_export]
macro_rules! obs_log {
    ($lvl:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($lvl) {
            $crate::obs::log::write_line($lvl, &format!($($arg)*));
        }
    };
}

/// Narrate an error (shown unless `EXACB_LOG=off`).
#[macro_export]
macro_rules! obs_error {
    ($($arg:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Error, $($arg)*) };
}

/// Narrate a degraded/skipped condition.
#[macro_export]
macro_rules! obs_warn {
    ($($arg:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Warn, $($arg)*) };
}

/// Narrate progress (default verbosity).
#[macro_export]
macro_rules! obs_info {
    ($($arg:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Info, $($arg)*) };
}

/// Narrate detail hidden by default (`EXACB_LOG=debug`).
#[macro_export]
macro_rules! obs_debug {
    ($($arg:tt)*) => { $crate::obs_log!($crate::obs::log::Level::Debug, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_gate() {
        let prev = set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_quiet();
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        restore_level(prev);
    }

    #[test]
    fn env_strings_parse() {
        assert_eq!(parse_level("off"), 0);
        assert_eq!(parse_level("ERROR"), Level::Error as u8);
        assert_eq!(parse_level("warn"), Level::Warn as u8);
        assert_eq!(parse_level("debug"), Level::Debug as u8);
        assert_eq!(parse_level("unknown"), Level::Info as u8);
    }
}
