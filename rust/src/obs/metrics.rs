//! Preallocated, allocation-flat metrics registry (DESIGN.md §13).
//!
//! Counters are an enum indexing a fixed `u64` array — incrementing one
//! is an array add, never a hash or an allocation. Three planes exist:
//! the **global** plane, a **per-machine** plane, and a **per-app**
//! plane; the named planes are linear-probed `Vec<(String, [u64; N])>`
//! rows (machine and app cardinality is small — tens, not thousands),
//! so after a plane row's first touch every further increment is
//! allocation-free. Histograms use fixed bucket edges chosen for
//! scheduler latencies (seconds → a simulated day), so observation is a
//! scan over a dozen bounds.
//!
//! Like the tracer, the registry is thread-local and **off by default**
//! ([`crate::obs::set_metrics`]); the disarmed path is one `Cell<bool>`
//! read. [`drain`] snapshots and resets; [`MetricsSnapshot::to_json`]
//! renders the `obs.json` sidecar — planes sorted by name and
//! zero-valued counters skipped, so the document is a pure function of
//! what was counted, not of arming or interleaving incidentals.

use std::cell::RefCell;

use crate::util::json::Json;

/// Every counter the instrumented subsystems bump. Declaration order is
/// the export order of `obs.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ctr {
    // scheduler (per machine)
    JobsSubmitted,
    JobsRejected,
    JobsStarted,
    JobsBackfilled,
    JobsCompleted,
    JobsFailed,
    JobsTimeout,
    HeadHolds,
    // event loop
    TaskWakes,
    PipelinesRun,
    PipelinesSucceeded,
    PipelinesFailed,
    // execution cache
    CacheHits,
    CacheMisses,
    CacheInvalidated,
    CacheInserts,
    // store snapshots
    SnapshotRefreshes,
    SnapshotRebuilds,
    SnapshotCommitsConsumed,
    // gates
    GateRounds,
    GateReps,
    MaturityChecks,
    MaturityPromotions,
    MaturityDemotions,
    EnergySweeps,
    EnergyPoints,
    // fault model (per machine; DESIGN.md §14)
    JobsNodeFailed,
    JobsPreempted,
    JobsRequeued,
}

impl Ctr {
    /// All counters, in declaration (= export) order.
    pub const ALL: [Ctr; CTR_COUNT] = [
        Ctr::JobsSubmitted,
        Ctr::JobsRejected,
        Ctr::JobsStarted,
        Ctr::JobsBackfilled,
        Ctr::JobsCompleted,
        Ctr::JobsFailed,
        Ctr::JobsTimeout,
        Ctr::HeadHolds,
        Ctr::TaskWakes,
        Ctr::PipelinesRun,
        Ctr::PipelinesSucceeded,
        Ctr::PipelinesFailed,
        Ctr::CacheHits,
        Ctr::CacheMisses,
        Ctr::CacheInvalidated,
        Ctr::CacheInserts,
        Ctr::SnapshotRefreshes,
        Ctr::SnapshotRebuilds,
        Ctr::SnapshotCommitsConsumed,
        Ctr::GateRounds,
        Ctr::GateReps,
        Ctr::MaturityChecks,
        Ctr::MaturityPromotions,
        Ctr::MaturityDemotions,
        Ctr::EnergySweeps,
        Ctr::EnergyPoints,
        Ctr::JobsNodeFailed,
        Ctr::JobsPreempted,
        Ctr::JobsRequeued,
    ];

    /// Stable export name (snake_case).
    pub fn name(self) -> &'static str {
        match self {
            Ctr::JobsSubmitted => "jobs_submitted",
            Ctr::JobsRejected => "jobs_rejected",
            Ctr::JobsStarted => "jobs_started",
            Ctr::JobsBackfilled => "jobs_backfilled",
            Ctr::JobsCompleted => "jobs_completed",
            Ctr::JobsFailed => "jobs_failed",
            Ctr::JobsTimeout => "jobs_timeout",
            Ctr::HeadHolds => "head_holds",
            Ctr::TaskWakes => "task_wakes",
            Ctr::PipelinesRun => "pipelines_run",
            Ctr::PipelinesSucceeded => "pipelines_succeeded",
            Ctr::PipelinesFailed => "pipelines_failed",
            Ctr::CacheHits => "cache_hits",
            Ctr::CacheMisses => "cache_misses",
            Ctr::CacheInvalidated => "cache_invalidated",
            Ctr::CacheInserts => "cache_inserts",
            Ctr::SnapshotRefreshes => "snapshot_refreshes",
            Ctr::SnapshotRebuilds => "snapshot_rebuilds",
            Ctr::SnapshotCommitsConsumed => "snapshot_commits_consumed",
            Ctr::GateRounds => "gate_rounds",
            Ctr::GateReps => "gate_reps",
            Ctr::MaturityChecks => "maturity_checks",
            Ctr::MaturityPromotions => "maturity_promotions",
            Ctr::MaturityDemotions => "maturity_demotions",
            Ctr::EnergySweeps => "energy_sweeps",
            Ctr::EnergyPoints => "energy_points",
            Ctr::JobsNodeFailed => "jobs_node_failed",
            Ctr::JobsPreempted => "jobs_preempted",
            Ctr::JobsRequeued => "jobs_requeued",
        }
    }
}

/// Number of counters (array size of every plane row).
pub const CTR_COUNT: usize = 29;

/// Fixed-bucket histograms over sim-time seconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    /// Queue wait (submit → start), per started job.
    QueueWaitS,
    /// Run time (start → end), per started job.
    RunTimeS,
}

impl Hist {
    pub const ALL: [Hist; HIST_COUNT] = [Hist::QueueWaitS, Hist::RunTimeS];

    pub fn name(self) -> &'static str {
        match self {
            Hist::QueueWaitS => "queue_wait_s",
            Hist::RunTimeS => "run_time_s",
        }
    }
}

/// Number of histograms.
pub const HIST_COUNT: usize = 2;

/// Inclusive upper bucket edges [s]; the last bucket is unbounded.
pub const BUCKET_EDGES: [i64; 12] = [
    1, 5, 15, 60, 300, 900, 3600, 7200, 14_400, 28_800, 57_600, 86_400,
];

/// Buckets per histogram (edges + one overflow bucket).
pub const BUCKET_COUNT: usize = BUCKET_EDGES.len() + 1;

fn bucket_of(value_s: i64) -> usize {
    BUCKET_EDGES
        .iter()
        .position(|&edge| value_s <= edge)
        .unwrap_or(BUCKET_EDGES.len())
}

/// One counter plane row: all counters of one named entity.
type Plane = [u64; CTR_COUNT];

/// The registry: global counters + named planes + histograms. The
/// dispatch-path cost of an increment is an array add plus (for named
/// planes) a short linear probe — no hashing, no allocation after the
/// row's first touch.
#[derive(Debug, Clone)]
struct Registry {
    global: Plane,
    machines: Vec<(String, Plane)>,
    apps: Vec<(String, Plane)>,
    hists: [[u64; BUCKET_COUNT]; HIST_COUNT],
}

impl Registry {
    const fn new() -> Registry {
        Registry {
            global: [0; CTR_COUNT],
            machines: Vec::new(),
            apps: Vec::new(),
            hists: [[0; BUCKET_COUNT]; HIST_COUNT],
        }
    }
}

thread_local! {
    static REGISTRY: RefCell<Registry> = const { RefCell::new(Registry::new()) };
}

fn plane_add(rows: &mut Vec<(String, Plane)>, name: &str, c: Ctr, n: u64) {
    if let Some(row) = rows.iter_mut().find(|(k, _)| k == name) {
        row.1[c as usize] += n;
        return;
    }
    let mut fresh: Plane = [0; CTR_COUNT];
    fresh[c as usize] = n;
    rows.push((name.to_string(), fresh));
}

/// Bump a global counter. No-op when metrics are disarmed.
pub fn count(c: Ctr, n: u64) {
    if !crate::obs::metrics_on() {
        return;
    }
    REGISTRY.with(|r| r.borrow_mut().global[c as usize] += n);
}

/// Bump a counter on the global plane **and** the named machine plane.
pub fn count_machine(machine: &str, c: Ctr, n: u64) {
    if !crate::obs::metrics_on() {
        return;
    }
    REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        reg.global[c as usize] += n;
        plane_add(&mut reg.machines, machine, c, n);
    });
}

/// Bump a counter on the global plane **and** the named app plane.
pub fn count_app(app: &str, c: Ctr, n: u64) {
    if !crate::obs::metrics_on() {
        return;
    }
    REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        reg.global[c as usize] += n;
        plane_add(&mut reg.apps, app, c, n);
    });
}

/// Record one observation [s] into a fixed-bucket histogram.
pub fn observe(h: Hist, value_s: i64) {
    if !crate::obs::metrics_on() {
        return;
    }
    REGISTRY.with(|r| r.borrow_mut().hists[h as usize][bucket_of(value_s)] += 1);
}

/// Immutable snapshot of the registry, planes sorted by name.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    global: Plane,
    machines: Vec<(String, Plane)>,
    apps: Vec<(String, Plane)>,
    hists: [[u64; BUCKET_COUNT]; HIST_COUNT],
}

impl MetricsSnapshot {
    /// A global counter's value.
    pub fn counter(&self, c: Ctr) -> u64 {
        self.global[c as usize]
    }

    /// A machine-plane counter's value (0 for unknown machines).
    pub fn machine_counter(&self, machine: &str, c: Ctr) -> u64 {
        self.machines
            .iter()
            .find(|(k, _)| k == machine)
            .map(|(_, p)| p[c as usize])
            .unwrap_or(0)
    }

    /// An app-plane counter's value (0 for unknown apps).
    pub fn app_counter(&self, app: &str, c: Ctr) -> u64 {
        self.apps
            .iter()
            .find(|(k, _)| k == app)
            .map(|(_, p)| p[c as usize])
            .unwrap_or(0)
    }

    /// App names present on the app plane (sorted).
    pub fn apps(&self) -> Vec<&str> {
        self.apps.iter().map(|(k, _)| k.as_str()).collect()
    }

    /// Total observations recorded in a histogram.
    pub fn hist_total(&self, h: Hist) -> u64 {
        self.hists[h as usize].iter().sum()
    }

    fn plane_json(plane: &Plane) -> Json {
        let mut o = Json::obj();
        for c in Ctr::ALL {
            let v = plane[c as usize];
            if v > 0 {
                o.insert(c.name(), v);
            }
        }
        o
    }

    /// The `obs.json` sidecar document: counters (zero values skipped),
    /// per-machine and per-app planes sorted by name, and histograms
    /// with their bucket edges.
    pub fn to_json(&self) -> Json {
        let mut machines = Json::obj();
        for (name, plane) in &self.machines {
            machines.insert(name, Self::plane_json(plane));
        }
        let mut apps = Json::obj();
        for (name, plane) in &self.apps {
            apps.insert(name, Self::plane_json(plane));
        }
        let mut hists = Json::obj();
        for h in Hist::ALL {
            let mut edges = Json::arr();
            for e in BUCKET_EDGES {
                edges.push(e as u64);
            }
            let mut counts = Json::arr();
            for b in self.hists[h as usize] {
                counts.push(b);
            }
            hists.insert(
                h.name(),
                Json::obj().set("le_edges_s", edges).set("counts", counts),
            );
        }
        Json::obj()
            .set("component", "obs")
            .set("counters", Self::plane_json(&self.global))
            .set("machines", machines)
            .set("apps", apps)
            .set("histograms", hists)
    }
}

fn snapshot_of(reg: &Registry) -> MetricsSnapshot {
    let mut machines = reg.machines.clone();
    machines.sort_by(|a, b| a.0.cmp(&b.0));
    let mut apps = reg.apps.clone();
    apps.sort_by(|a, b| a.0.cmp(&b.0));
    MetricsSnapshot {
        global: reg.global,
        machines,
        apps,
        hists: reg.hists,
    }
}

/// Snapshot the registry without resetting it.
pub fn snapshot() -> MetricsSnapshot {
    REGISTRY.with(|r| snapshot_of(&r.borrow()))
}

/// Snapshot the registry and reset every counter and histogram.
pub fn drain() -> MetricsSnapshot {
    REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        let snap = snapshot_of(&reg);
        *reg = Registry::new();
        snap
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctr_table_is_consistent() {
        assert_eq!(Ctr::ALL.len(), CTR_COUNT);
        for (i, c) in Ctr::ALL.iter().enumerate() {
            assert_eq!(*c as usize, i, "{}", c.name());
        }
        let mut names: Vec<&str> = Ctr::ALL.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), CTR_COUNT, "duplicate counter name");
    }

    #[test]
    fn disarmed_counts_are_dropped() {
        drain();
        count(Ctr::JobsSubmitted, 3);
        observe(Hist::QueueWaitS, 10);
        let snap = drain();
        assert_eq!(snap.counter(Ctr::JobsSubmitted), 0);
        assert_eq!(snap.hist_total(Hist::QueueWaitS), 0);
    }

    #[test]
    fn armed_planes_and_histograms_accumulate() {
        drain();
        let prior = crate::obs::set_metrics(true);
        count(Ctr::TaskWakes, 2);
        count_machine("jedi", Ctr::JobsSubmitted, 1);
        count_machine("jedi", Ctr::JobsSubmitted, 1);
        count_machine("jupiter", Ctr::JobsSubmitted, 1);
        count_app("logmap", Ctr::GateReps, 5);
        observe(Hist::QueueWaitS, 0);
        observe(Hist::QueueWaitS, 100);
        observe(Hist::QueueWaitS, 1_000_000); // overflow bucket
        let snap = drain();
        crate::obs::set_metrics(prior);
        assert_eq!(snap.counter(Ctr::TaskWakes), 2);
        assert_eq!(snap.counter(Ctr::JobsSubmitted), 3, "planes add to global");
        assert_eq!(snap.machine_counter("jedi", Ctr::JobsSubmitted), 2);
        assert_eq!(snap.machine_counter("jupiter", Ctr::JobsSubmitted), 1);
        assert_eq!(snap.app_counter("logmap", Ctr::GateReps), 5);
        assert_eq!(snap.hist_total(Hist::QueueWaitS), 3);
        let doc = snap.to_json();
        assert_eq!(doc.str_of("component"), Some("obs"));
        assert_eq!(
            doc.get("counters").unwrap().u64_of("jobs_submitted"),
            Some(3)
        );
        assert!(doc.get("counters").unwrap().get("jobs_rejected").is_none());
        let hist = doc.get("histograms").unwrap().get("queue_wait_s").unwrap();
        let counts = hist.get("counts").and_then(Json::as_arr).unwrap();
        assert_eq!(counts.len(), BUCKET_COUNT);
        assert_eq!(counts.last().unwrap().as_u64(), Some(1));
    }

    #[test]
    fn buckets_cover_the_range() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(86_400), BUCKET_EDGES.len() - 1);
        assert_eq!(bucket_of(86_401), BUCKET_EDGES.len());
    }
}
