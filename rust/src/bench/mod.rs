//! Mini-criterion: a small benchmarking harness (substrate; DESIGN.md §2
//! — no `criterion` crate vendored offline).
//!
//! Provides warm-up, timed iterations, mean/p50/p95 statistics, and
//! throughput reporting, with text output similar to criterion's.
//! `cargo bench` targets use `harness = false` and drive this directly.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional throughput: (units-per-iteration, unit label).
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} {:>10} iters  mean {:>11}  p50 {:>11}  p95 {:>11}",
            self.name,
            self.iterations,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
        );
        if let Some((units, label)) = self.throughput {
            let per_sec = units / self.mean.as_secs_f64();
            line.push_str(&format!("  {:>12.3} {label}/s", per_sec));
        }
        line
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Bench runner with a global time budget per case.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        Bench::default()
    }

    /// Quick mode for CI (shorter budgets).
    pub fn quick() -> Bench {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(250),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Run one case; `f` is the measured body (return value is consumed
    /// through `std::hint::black_box`).
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.case_with_throughput(name, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Run one case reporting throughput in `units` per iteration.
    pub fn throughput_case<T>(
        &mut self,
        name: &str,
        units: f64,
        label: &'static str,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.case_with_throughput(name, Some((units, label)), move || {
            std::hint::black_box(f());
        })
    }

    fn case_with_throughput(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // measurement: batched timing to amortise clock reads for fast fns
        let mut samples: Vec<Duration> = Vec::new();
        let mut total_iters = 0u64;
        let t0 = Instant::now();
        // estimate batch size from one probe
        let probe = Instant::now();
        f();
        let probe_t = probe.elapsed();
        let batch = (Duration::from_micros(200).as_nanos() / probe_t.as_nanos().max(1))
            .clamp(1, 10_000) as u64;
        // always collect at least one sample, even when a single
        // iteration blows the measurement budget
        loop {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(s.elapsed() / batch as u32);
            total_iters += batch;
            if t0.elapsed() >= self.measure || total_iters >= self.max_iters {
                break;
            }
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len().max(1) as u32;
        let result = BenchResult {
            name: name.to_string(),
            iterations: total_iters,
            mean,
            p50: samples[samples.len() / 2],
            p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
            min: samples[0],
            throughput,
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the standard report block.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        for r in &self.results {
            println!("{}", r.report());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick();
        let r = b.case("noop-ish", || 1 + 1).clone();
        assert!(r.iterations > 0);
        assert!(r.p95 >= r.p50);
        assert!(r.min <= r.mean * 2);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bench::quick();
        let data = vec![1u8; 64 * 1024];
        let r = b
            .throughput_case("hash-64k", data.len() as f64, "B", || {
                crate::util::fnv1a(&data)
            })
            .clone();
        assert!(r.throughput.is_some());
        assert!(r.report().contains("B/s"));
    }

    #[test]
    fn report_formats() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
