//! # exaCB — Reproducible Continuous Benchmark Collections at Scale
//!
//! Library reproduction of *exaCB* (Badwaik, Bode, Rajski, Herten; JSC,
//! CS.DC 2026): a continuous-benchmarking framework where independently
//! owned benchmark repositories are strongly coupled to a shared protocol
//! and orchestrated through CI/CD pipelines on HPC systems.
//!
//! The crate contains the framework itself (`protocol`, `ci`,
//! `coordinator`, `harness`, `analysis`, `energy`, `store`), the
//! decision layers on top (`tracking` regression gates, the `maturity`
//! evidence ladder), **and** every substrate the paper depends on,
//! simulated where the real thing is hardware- or site-gated
//! (`cluster`, `scheduler`, `workloads`): see DESIGN.md for the
//! substitution table.
//!
//! Compute hot paths (the logmap and STREAM benchmark kernels) are
//! AOT-compiled from JAX/Pallas to HLO at build time (`make artifacts`)
//! and executed natively through the PJRT C API (`runtime`); Python never
//! runs on the benchmarking path.

pub mod util;
pub mod obs;
pub mod protocol;
pub mod cluster;
pub mod scheduler;
pub mod harness;
pub mod ci;
pub mod runtime;
pub mod workloads;
pub mod energy;
pub mod analysis;
pub mod coordinator;
pub mod defs;
pub mod tracking;
pub mod maturity;
pub mod query;
pub mod experiments;
pub mod bench;
pub mod cli;
pub mod store;
