//! S3-like object store: buckets of key -> blob, with prefix listing.
//!
//! The alternative persistent back end the paper mentions for recorded
//! results (§IV-E). Unlike [`super::git::DataStore`], objects are mutable
//! (a PUT overwrites), which is why the chain of trust for externally
//! injected data "is not guaranteed" — reflected in the `injected` flag.

use std::collections::BTreeMap;

/// A stored object with minimal metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredObject {
    pub content: String,
    /// True when placed via the external-injection hook rather than by an
    /// exaCB orchestrator (§IV-E: trust is not guaranteed for these).
    pub injected: bool,
}

#[derive(Debug, Clone, Default)]
pub struct ObjectStore {
    buckets: BTreeMap<String, BTreeMap<String, StoredObject>>,
}

impl ObjectStore {
    pub fn new() -> ObjectStore {
        ObjectStore::default()
    }

    pub fn put(&mut self, bucket: &str, key: &str, content: &str) {
        self.buckets
            .entry(bucket.to_string())
            .or_default()
            .insert(
                key.to_string(),
                StoredObject {
                    content: content.to_string(),
                    injected: false,
                },
            );
    }

    /// The external-data injection hook (§IV-E).
    pub fn inject(&mut self, bucket: &str, key: &str, content: &str) {
        self.buckets
            .entry(bucket.to_string())
            .or_default()
            .insert(
                key.to_string(),
                StoredObject {
                    content: content.to_string(),
                    injected: true,
                },
            );
    }

    pub fn get(&self, bucket: &str, key: &str) -> Option<&StoredObject> {
        self.buckets.get(bucket)?.get(key)
    }

    pub fn list(&self, bucket: &str, prefix: &str) -> Vec<String> {
        self.buckets
            .get(bucket)
            .map(|b| {
                b.keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn delete(&mut self, bucket: &str, key: &str) -> bool {
        self.buckets
            .get_mut(bucket)
            .map(|b| b.remove(key).is_some())
            .unwrap_or(false)
    }

    pub fn len(&self, bucket: &str) -> usize {
        self.buckets.get(bucket).map(BTreeMap::len).unwrap_or(0)
    }

    pub fn is_empty(&self, bucket: &str) -> bool {
        self.len(bucket) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_overwrite() {
        let mut s = ObjectStore::new();
        s.put("results", "a/1", "v1");
        s.put("results", "a/1", "v2");
        assert_eq!(s.get("results", "a/1").unwrap().content, "v2");
        assert!(!s.get("results", "a/1").unwrap().injected);
        assert!(s.get("results", "nope").is_none());
        assert!(s.get("nobucket", "a/1").is_none());
    }

    #[test]
    fn injected_flag_tracks_provenance() {
        let mut s = ObjectStore::new();
        s.inject("results", "ext/x", "third-party");
        assert!(s.get("results", "ext/x").unwrap().injected);
    }

    #[test]
    fn prefix_list_and_delete() {
        let mut s = ObjectStore::new();
        s.put("b", "p/1", "x");
        s.put("b", "p/2", "y");
        s.put("b", "q/1", "z");
        assert_eq!(s.list("b", "p/"), vec!["p/1", "p/2"]);
        assert!(s.delete("b", "p/1"));
        assert!(!s.delete("b", "p/1"));
        assert_eq!(s.len("b"), 2);
    }
}
