//! Content-addressed execution cache — the crate's incremental-execution
//! core (the paper's namesake "incremental approach" applied to
//! *execution*, not just adoption).
//!
//! A **cache key** is a canonical digest over everything that determines
//! a step's outcome: the resolved commands (the benchmark definition
//! after parameter substitution), the parameter point, machine +
//! software stage + resolved environment factors, launcher, injected
//! features, scheduler account context, and the engine artifact
//! fingerprint. Two layers are cached:
//!
//! * `"step"` — one serialized [`crate::harness::StepOutcome`] per
//!   resolved remote step (partial replay when only some inputs change);
//! * `"report"` / `"csv"` — the assembled protocol report + Table-I CSV
//!   of a whole run (full replay: byte-identical artifacts, zero batch
//!   submissions).
//!
//! Entries are layered on [`super::object::ObjectStore`] (the S3-like
//! back end of §IV-E), addressed by digest, so the cache shares the
//! persistence semantics of recorded results. Only *successful*
//! outcomes are cached — failures always re-execute.
//!
//! Invalidation is implicit: a changed input changes the digest, so the
//! stale entry is simply never addressed again. The `slots` index maps a
//! step's stable identity (benchmark, step, point, machine) to its last
//! digest purely to *classify* a re-execution as `invalidated` (same
//! slot, new key) versus `miss` (never seen) for provenance reporting.
//! See DESIGN.md §4 for the full key composition table.

use std::collections::BTreeMap;

use crate::protocol::CacheOutcome;
use crate::util::wide_hash;

use super::object::ObjectStore;

/// A fully-composed cache key: `slot` identifies *what* is being
/// executed, `digest` additionally pins *under which inputs*.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheKey {
    pub slot: String,
    pub digest: String,
}

/// Builder for canonical cache keys. Parts are length-prefix encoded
/// (no separator ambiguity) and sorted by name (no insertion-order
/// dependence), so the digest is stable across `BTreeMap` iteration,
/// re-serialization, or call-site reordering.
#[derive(Debug, Clone, Default)]
pub struct CacheKeyBuilder {
    ident: Vec<(String, String)>,
    fields: Vec<(String, String)>,
}

fn encode(parts: &[(String, String)]) -> String {
    let mut sorted: Vec<&(String, String)> = parts.iter().collect();
    sorted.sort();
    let mut out = String::new();
    for (k, v) in sorted {
        out.push_str(&format!("{}|{}|{}{}", k.len(), v.len(), k, v));
    }
    out
}

impl CacheKeyBuilder {
    pub fn new(benchmark: &str, step: &str) -> CacheKeyBuilder {
        CacheKeyBuilder::default()
            .ident("benchmark", benchmark)
            .ident("step", step)
    }

    /// An identity part: contributes to the slot *and* the digest.
    pub fn ident(mut self, name: &str, value: impl AsRef<str>) -> CacheKeyBuilder {
        self.ident
            .push((name.to_string(), value.as_ref().to_string()));
        self
    }

    /// An input part: contributes to the digest only — changing it
    /// *invalidates* the slot rather than creating a new one.
    pub fn field(mut self, name: &str, value: impl AsRef<str>) -> CacheKeyBuilder {
        self.fields
            .push((name.to_string(), value.as_ref().to_string()));
        self
    }

    pub fn build(self) -> CacheKey {
        let ident_enc = encode(&self.ident);
        let full_enc = format!("{}#{}", ident_enc, encode(&self.fields));
        CacheKey {
            slot: wide_hash(ident_enc.as_bytes()),
            digest: wide_hash(full_enc.as_bytes()),
        }
    }
}

/// Cumulative cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub invalidated: u64,
    pub inserts: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses + self.invalidated
    }
}

/// The execution cache: digest-addressed documents + slot index + stats.
#[derive(Debug, Clone, Default)]
pub struct ExecutionCache {
    store: ObjectStore,
    /// slot -> digest of the entry currently considered live.
    slots: BTreeMap<String, String>,
    pub stats: CacheStats,
}

impl ExecutionCache {
    pub fn new() -> ExecutionCache {
        ExecutionCache::default()
    }

    /// Look up `key` among `kind` entries, recording hit/miss/invalidated
    /// statistics. Returns the classification and, on a hit, the stored
    /// document.
    pub fn lookup(&mut self, key: &CacheKey, kind: &str) -> (CacheOutcome, Option<String>) {
        if let Some(doc) = self.store.get(kind, &key.digest) {
            self.stats.hits += 1;
            crate::obs::count(crate::obs::Ctr::CacheHits, 1);
            return (CacheOutcome::Hit, Some(doc.content.clone()));
        }
        match self.slots.get(&key.slot) {
            Some(live) if live != &key.digest => {
                self.stats.invalidated += 1;
                crate::obs::count(crate::obs::Ctr::CacheInvalidated, 1);
                (CacheOutcome::Invalidated, None)
            }
            _ => {
                self.stats.misses += 1;
                crate::obs::count(crate::obs::Ctr::CacheMisses, 1);
                (CacheOutcome::Miss, None)
            }
        }
    }

    /// Insert a document under `key`, re-pointing the slot.
    pub fn insert(&mut self, key: &CacheKey, kind: &str, doc: &str) {
        self.store.put(kind, &key.digest, doc);
        self.slots.insert(key.slot.clone(), key.digest.clone());
        self.stats.inserts += 1;
        crate::obs::count(crate::obs::Ctr::CacheInserts, 1);
    }

    /// Insert an auxiliary document sharing another entry's digest (e.g.
    /// the `csv` companion of a `report`). No slot/stats bookkeeping.
    pub fn insert_aux(&mut self, kind: &str, digest: &str, doc: &str) {
        self.store.put(kind, digest, doc);
    }

    /// Raw digest-addressed read without statistics.
    pub fn get(&self, kind: &str, digest: &str) -> Option<&str> {
        self.store.get(kind, digest).map(|o| o.content.as_str())
    }

    /// Number of entries of one kind.
    pub fn len(&self, kind: &str) -> usize {
        self.store.len(kind)
    }

    pub fn is_empty(&self, kind: &str) -> bool {
        self.store.is_empty(kind)
    }

    /// Forget everything (stats survive — they describe the session).
    pub fn clear(&mut self) {
        self.store = ObjectStore::new();
        self.slots.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(step: &str, cmd: &str) -> CacheKey {
        CacheKeyBuilder::new("logmap", step)
            .ident("machine", "jedi")
            .ident("point", "workload=2")
            .field("commands", cmd)
            .field("stage", "2026")
            .build()
    }

    #[test]
    fn builder_is_order_and_iteration_independent() {
        let a = CacheKeyBuilder::new("b", "s")
            .field("x", "1")
            .field("y", "2")
            .ident("machine", "m")
            .build();
        let b = CacheKeyBuilder::new("b", "s")
            .ident("machine", "m")
            .field("y", "2")
            .field("x", "1")
            .build();
        assert_eq!(a, b);
    }

    #[test]
    fn no_separator_ambiguity() {
        // ("ab","c") must not collide with ("a","bc")
        let a = CacheKeyBuilder::new("b", "s").field("ab", "c").build();
        let b = CacheKeyBuilder::new("b", "s").field("a", "bc").build();
        assert_ne!(a.digest, b.digest);
    }

    #[test]
    fn lookup_classifies_miss_hit_invalidated() {
        let mut c = ExecutionCache::new();
        let k1 = key("execute", "logmap --workload 2");

        let (st, doc) = c.lookup(&k1, "step");
        assert_eq!(st, CacheOutcome::Miss);
        assert!(doc.is_none());

        c.insert(&k1, "step", "{\"ok\":true}");
        let (st, doc) = c.lookup(&k1, "step");
        assert_eq!(st, CacheOutcome::Hit);
        assert_eq!(doc.unwrap(), "{\"ok\":true}");

        // same slot (same step+point+machine), changed command
        let k2 = key("execute", "logmap --workload 2 --fast");
        let (st, _) = c.lookup(&k2, "step");
        assert_eq!(st, CacheOutcome::Invalidated);

        // a different step is a miss, not an invalidation
        let k3 = key("compile", "cmake --build build");
        let (st, _) = c.lookup(&k3, "step");
        assert_eq!(st, CacheOutcome::Miss);

        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 2);
        assert_eq!(c.stats.invalidated, 1);
        assert_eq!(c.stats.lookups(), 4);
    }

    #[test]
    fn reinsert_repoints_slot() {
        let mut c = ExecutionCache::new();
        let k1 = key("execute", "v1");
        let k2 = key("execute", "v2");
        c.insert(&k1, "step", "one");
        c.insert(&k2, "step", "two");
        // old digest still addressable (content-addressed, immutable use)
        assert_eq!(c.get("step", &k1.digest), Some("one"));
        // but the slot now lives at k2: looking up k1 hits its stored
        // entry directly, a *third* digest classifies as invalidated
        let k3 = key("execute", "v3");
        let (st, _) = c.lookup(&k3, "step");
        assert_eq!(st, CacheOutcome::Invalidated);
    }

    #[test]
    fn aux_documents_share_digest() {
        let mut c = ExecutionCache::new();
        let k = key("run", "all");
        c.insert(&k, "report", "{}");
        c.insert_aux("csv", &k.digest, "a,b\n");
        assert_eq!(c.get("csv", &k.digest), Some("a,b\n"));
        assert_eq!(c.len("report"), 1);
        assert_eq!(c.len("csv"), 1);
    }

    #[test]
    fn clear_drops_entries_keeps_stats() {
        let mut c = ExecutionCache::new();
        let k = key("execute", "x");
        c.insert(&k, "step", "doc");
        c.lookup(&k, "step");
        c.clear();
        assert!(c.is_empty("step"));
        assert_eq!(c.stats.hits, 1);
        let (st, _) = c.lookup(&k, "step");
        assert_eq!(st, CacheOutcome::Miss);
    }
}
