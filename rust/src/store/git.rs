//! Content-addressed commit store: the `exacb.data` orphan branch.
//!
//! Model: blobs are content-addressed by hash; a commit records a
//! **delta** (path -> blob id) on a branch, chaining to its parent; the
//! full tree is materialized only at each branch head. Appending a
//! report is therefore O(delta), not O(tree) — the property the daily
//! campaign workload needs (EXPERIMENTS.md §Perf, store iterations).
//! Historic trees are reconstructed on demand by replaying deltas —
//! from the nearest memoized ancestor tree when one is cached, falling
//! back to the orphan root (a-posteriori analyses are rare; appends are
//! not). Long replays leave checkpoint trees behind, so near-head
//! history stays O(checkpoint distance) after the first walk.
//!
//! Retrieval is by branch + path prefix, which is exactly how the
//! post-processing orchestrators pull "results from the exacb.data
//! branch of the benchmark repositories" (paper §V-A.2).

use std::collections::BTreeMap;

use crate::util::short_hash;
use crate::util::timeutil::SimTime;

#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    UnknownBranch(String),
    UnknownObject(String),
    PathNotFound(String),
    Io(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::UnknownBranch(b) => write!(f, "unknown branch '{b}'"),
            StoreError::UnknownObject(o) => write!(f, "unknown object '{o}'"),
            StoreError::PathNotFound(p) => write!(f, "path '{p}' not found"),
            StoreError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// One commit on a branch (delta-based).
#[derive(Debug, Clone, PartialEq)]
pub struct Commit {
    pub id: String,
    pub parent: Option<String>,
    pub branch: String,
    pub message: String,
    pub time: SimTime,
    /// Paths written by this commit: path -> blob id.
    pub delta: BTreeMap<String, String>,
}

/// Capacity of the materialized-tree memo in [`DataStore::tree_at`].
const TREE_CACHE_CAP: usize = 8;

/// During a long replay, memoize a checkpoint tree every this many
/// applied commits so later [`DataStore::tree_at`] calls near the head
/// never walk back to the orphan root.
const TREE_CHECKPOINT_EVERY: usize = 64;

/// The data store: blobs + branches of commit chains with materialized
/// head trees.
#[derive(Debug, Clone, Default)]
pub struct DataStore {
    blobs: BTreeMap<String, String>,
    commits: BTreeMap<String, Commit>,
    /// branch -> (head commit id, materialized tree path -> blob id)
    heads: BTreeMap<String, (String, BTreeMap<String, String>)>,
    /// Memoized materialized trees for [`DataStore::tree_at`], keyed by
    /// commit id, most-recently-used at the back. Commits are immutable
    /// and content-addressed, so a cached tree never goes stale.
    tree_cache: std::cell::RefCell<Vec<(String, BTreeMap<String, String>)>>,
}

impl DataStore {
    pub fn new() -> DataStore {
        DataStore::default()
    }

    fn put_blob(&mut self, content: &str) -> String {
        let id = short_hash(content.as_bytes());
        self.blobs
            .entry(id.clone())
            .or_insert_with(|| content.to_string());
        id
    }

    /// Commit `files` onto `branch` (created on first commit,
    /// orphan-style). Unchanged paths from the previous head remain
    /// visible — the head tree is updated in place, O(delta).
    pub fn commit(
        &mut self,
        branch: &str,
        files: &[(String, String)],
        message: &str,
        time: SimTime,
    ) -> String {
        let parent = self.heads.get(branch).map(|(id, _)| id.clone());
        let mut delta = BTreeMap::new();
        // Commit id: hash of (branch, parent, message, time, delta).
        // The parent id already summarizes the prior tree, so hashing
        // only the delta keeps append O(delta).
        let mut payload = format!("{branch}|{:?}|{message}|{}", parent, time.0);
        for (path, content) in files {
            let blob = self.put_blob(content);
            payload.push('|');
            payload.push_str(path);
            payload.push(':');
            payload.push_str(&blob);
            delta.insert(path.clone(), blob);
        }
        let id = short_hash(payload.as_bytes());
        let commit = Commit {
            id: id.clone(),
            parent,
            branch: branch.to_string(),
            message: message.to_string(),
            time,
            delta: delta.clone(),
        };
        self.commits.insert(id.clone(), commit);
        let entry = self
            .heads
            .entry(branch.to_string())
            .or_insert_with(|| (id.clone(), BTreeMap::new()));
        entry.0 = id.clone();
        for (p, b) in delta {
            entry.1.insert(p, b);
        }
        id
    }

    pub fn head(&self, branch: &str) -> Option<&Commit> {
        self.heads
            .get(branch)
            .and_then(|(id, _)| self.commits.get(id))
    }

    /// The materialized tree at the branch head.
    pub fn head_tree(&self, branch: &str) -> Option<&BTreeMap<String, String>> {
        self.heads.get(branch).map(|(_, t)| t)
    }

    /// Reconstruct the full tree at an arbitrary commit by replaying
    /// deltas from the nearest memoized ancestor tree (the orphan root
    /// on a cold cache). The first long walk leaves checkpoints behind,
    /// so subsequent near-head queries are O(checkpoint distance), not
    /// O(full history).
    pub fn tree_at(&self, commit_id: &str) -> Option<BTreeMap<String, String>> {
        self.tree_at_traced(commit_id).map(|(tree, _)| tree)
    }

    /// [`DataStore::tree_at`] plus the number of commits actually
    /// replayed — the observable the memoization tests pin down.
    fn tree_at_traced(&self, commit_id: &str) -> Option<(BTreeMap<String, String>, usize)> {
        // walk back until a memoized ancestor (or the orphan root)
        let mut chain = Vec::new();
        let mut base: Option<BTreeMap<String, String>> = None;
        let mut cur = Some(commit_id.to_string());
        while let Some(id) = cur {
            if let Some(tree) = self.cached_tree(&id) {
                base = Some(tree);
                break;
            }
            let c = self.commits.get(&id)?;
            cur = c.parent.clone();
            chain.push(c);
        }
        let replayed = chain.len();
        let mut tree = base.unwrap_or_default();
        for (i, c) in chain.iter().rev().enumerate() {
            for (p, b) in &c.delta {
                tree.insert(p.clone(), b.clone());
            }
            if (i + 1) % TREE_CHECKPOINT_EVERY == 0 {
                self.cache_tree(&c.id, &tree);
            }
        }
        self.cache_tree(commit_id, &tree);
        Some((tree, replayed))
    }

    /// LRU lookup: a hit moves the entry to the most-recent slot.
    fn cached_tree(&self, id: &str) -> Option<BTreeMap<String, String>> {
        let mut cache = self.tree_cache.borrow_mut();
        let pos = cache.iter().position(|(cid, _)| cid == id)?;
        let hit = cache.remove(pos);
        let tree = hit.1.clone();
        cache.push(hit);
        Some(tree)
    }

    fn cache_tree(&self, id: &str, tree: &BTreeMap<String, String>) {
        let mut cache = self.tree_cache.borrow_mut();
        if let Some(pos) = cache.iter().position(|(cid, _)| cid == id) {
            cache.remove(pos);
        }
        cache.push((id.to_string(), tree.clone()));
        if cache.len() > TREE_CACHE_CAP {
            cache.remove(0);
        }
    }

    pub fn branch_exists(&self, branch: &str) -> bool {
        self.heads.contains_key(branch)
    }

    pub fn branches(&self) -> Vec<&str> {
        self.heads.keys().map(String::as_str).collect()
    }

    /// Resolve a blob's content by id.
    pub fn blob(&self, id: &str) -> Option<&str> {
        self.blobs.get(id).map(String::as_str)
    }

    /// Read a file at the branch head.
    pub fn read(&self, branch: &str, path: &str) -> Result<&str, StoreError> {
        let tree = self
            .head_tree(branch)
            .ok_or_else(|| StoreError::UnknownBranch(branch.to_string()))?;
        let blob = tree
            .get(path)
            .ok_or_else(|| StoreError::PathNotFound(path.to_string()))?;
        self.blobs
            .get(blob)
            .map(String::as_str)
            .ok_or_else(|| StoreError::UnknownObject(blob.clone()))
    }

    /// All paths at the head matching a prefix (the selector mechanism of
    /// the post-processing orchestrators).
    pub fn list(&self, branch: &str, prefix: &str) -> Vec<String> {
        self.head_tree(branch)
            .map(|t| {
                t.keys()
                    .filter(|p| p.starts_with(prefix))
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Borrowing walk over every prefix-matching `(path, content)` pair
    /// at the branch head. Unlike [`DataStore::read_all`] this clones
    /// nothing — the snapshot builder and other whole-store readers pay
    /// O(tree) in references, not copies.
    pub fn read_all_iter<'a>(
        &'a self,
        branch: &str,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a str)> + 'a {
        self.head_tree(branch)
            .into_iter()
            .flat_map(|t| t.iter())
            .filter(move |(p, _)| p.starts_with(prefix))
            .filter_map(|(p, b)| self.blobs.get(b).map(|c| (p.as_str(), c.as_str())))
    }

    /// Read every prefix-matching file at the head (owned; a thin
    /// collect over [`DataStore::read_all_iter`]).
    pub fn read_all(&self, branch: &str, prefix: &str) -> Vec<(String, String)> {
        self.read_all_iter(branch, prefix)
            .map(|(p, c)| (p.to_string(), c.to_string()))
            .collect()
    }

    /// Commit history of a branch, newest first.
    pub fn history(&self, branch: &str) -> Vec<&Commit> {
        let mut out = Vec::new();
        let mut cur = self.heads.get(branch).map(|(id, _)| id.clone());
        while let Some(id) = cur {
            if let Some(c) = self.commits.get(&id) {
                cur = c.parent.clone();
                out.push(c);
            } else {
                break;
            }
        }
        out
    }

    pub fn commit_by_id(&self, id: &str) -> Option<&Commit> {
        self.commits.get(id)
    }

    /// Persist to a directory (one file per blob + a JSON index).
    pub fn persist(&self, dir: &std::path::Path) -> Result<(), StoreError> {
        use crate::util::json::Json;
        std::fs::create_dir_all(dir.join("blobs")).map_err(|e| StoreError::Io(e.to_string()))?;
        for (id, content) in &self.blobs {
            let path = dir.join("blobs").join(id);
            // blobs are content-addressed: a file that already exists
            // holds the right bytes, so an incremental persist skips it
            if path.exists() {
                continue;
            }
            std::fs::write(path, content).map_err(|e| StoreError::Io(e.to_string()))?;
        }
        let mut commits = Json::arr();
        for c in self.commits.values() {
            let mut delta = Json::obj();
            for (p, b) in &c.delta {
                delta.insert(p, b.as_str());
            }
            commits.push(
                Json::obj()
                    .set("id", c.id.as_str())
                    .set(
                        "parent",
                        c.parent
                            .as_ref()
                            .map(|p| Json::Str(p.clone()))
                            .unwrap_or(Json::Null),
                    )
                    .set("branch", c.branch.as_str())
                    .set("message", c.message.as_str())
                    .set("time", c.time.0)
                    .set("delta", delta),
            );
        }
        let mut heads = Json::obj();
        for (b, (id, _)) in &self.heads {
            heads.insert(b, id.as_str());
        }
        let index = Json::obj().set("commits", commits).set("heads", heads);
        std::fs::write(dir.join("index.json"), index.pretty())
            .map_err(|e| StoreError::Io(e.to_string()))
    }

    /// Load a persisted store (head trees rebuilt by delta replay).
    pub fn load(dir: &std::path::Path) -> Result<DataStore, StoreError> {
        use crate::util::json::Json;
        let text = std::fs::read_to_string(dir.join("index.json"))
            .map_err(|e| StoreError::Io(e.to_string()))?;
        let index = Json::parse(&text).map_err(|e| StoreError::Io(e.to_string()))?;
        let mut store = DataStore::new();
        for c in index.get("commits").and_then(Json::as_arr).unwrap_or(&[]) {
            let mut delta = BTreeMap::new();
            for (p, b) in c.get("delta").and_then(Json::as_obj).unwrap_or(&[]) {
                delta.insert(p.clone(), b.as_str().unwrap_or("").to_string());
            }
            let commit = Commit {
                id: c.str_of("id").unwrap_or("").to_string(),
                parent: c.str_of("parent").map(str::to_string),
                branch: c.str_of("branch").unwrap_or("").to_string(),
                message: c.str_of("message").unwrap_or("").to_string(),
                time: SimTime(c.get("time").and_then(Json::as_i64).unwrap_or(0)),
                delta,
            };
            store.commits.insert(commit.id.clone(), commit);
        }
        for (b, id) in index.get("heads").and_then(Json::as_obj).unwrap_or(&[]) {
            let id = id.as_str().unwrap_or("").to_string();
            let tree = store.tree_at(&id).unwrap_or_default();
            store.heads.insert(b.clone(), (id, tree));
        }
        if let Ok(entries) = std::fs::read_dir(dir.join("blobs")) {
            for e in entries.flatten() {
                if let (Some(name), Ok(content)) = (
                    e.file_name().to_str().map(str::to_string),
                    std::fs::read_to_string(e.path()),
                ) {
                    store.blobs.insert(name, content);
                }
            }
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_read_roundtrip() {
        let mut s = DataStore::new();
        s.commit(
            "exacb.data",
            &[("a/report.json".into(), "{\"x\":1}".into())],
            "first",
            SimTime(0),
        );
        assert_eq!(s.read("exacb.data", "a/report.json").unwrap(), "{\"x\":1}");
        assert!(matches!(
            s.read("exacb.data", "missing"),
            Err(StoreError::PathNotFound(_))
        ));
        assert!(matches!(
            s.read("other", "a"),
            Err(StoreError::UnknownBranch(_))
        ));
    }

    #[test]
    fn history_is_immutable_chain() {
        let mut s = DataStore::new();
        let c1 = s.commit("b", &[("f".into(), "v1".into())], "one", SimTime(1));
        let c2 = s.commit("b", &[("f".into(), "v2".into())], "two", SimTime(2));
        assert_ne!(c1, c2);
        // head sees v2, but the old commit's tree still resolves v1
        assert_eq!(s.read("b", "f").unwrap(), "v2");
        let old_tree = s.tree_at(&c1).unwrap();
        assert_eq!(s.blob(&old_tree["f"]).unwrap(), "v1");
        let hist = s.history("b");
        assert_eq!(hist.len(), 2);
        assert_eq!(hist[0].id, c2);
        assert_eq!(hist[1].id, c1);
        assert_eq!(hist[0].parent.as_deref(), Some(c1.as_str()));
    }

    #[test]
    fn unchanged_paths_carry_forward() {
        let mut s = DataStore::new();
        s.commit("b", &[("keep".into(), "k".into())], "one", SimTime(1));
        s.commit("b", &[("new".into(), "n".into())], "two", SimTime(2));
        assert_eq!(s.read("b", "keep").unwrap(), "k");
        assert_eq!(s.read("b", "new").unwrap(), "n");
        // historic tree at head matches materialized head tree
        let head_id = s.head("b").unwrap().id.clone();
        assert_eq!(&s.tree_at(&head_id).unwrap(), s.head_tree("b").unwrap());
    }

    #[test]
    fn prefix_listing() {
        let mut s = DataStore::new();
        s.commit(
            "b",
            &[
                ("jedi.strong/1.json".into(), "{}".into()),
                ("jedi.strong/2.json".into(), "{}".into()),
                ("jureca.single/1.json".into(), "{}".into()),
            ],
            "m",
            SimTime(0),
        );
        assert_eq!(s.list("b", "jedi.strong/").len(), 2);
        assert_eq!(s.read_all("b", "jureca").len(), 1);
        assert!(s.list("b", "zzz").is_empty());
        assert!(s.list("nobranch", "").is_empty());
    }

    #[test]
    fn identical_content_dedupes() {
        let mut s = DataStore::new();
        s.commit(
            "b",
            &[("a".into(), "same".into()), ("b".into(), "same".into())],
            "m",
            SimTime(0),
        );
        assert_eq!(s.blobs.len(), 1);
    }

    #[test]
    fn persist_load_roundtrip() {
        let mut s = DataStore::new();
        s.commit("exacb.data", &[("p/r.json".into(), "content".into())], "m", SimTime(5));
        s.commit("exacb.data", &[("p/s.json".into(), "more".into())], "n", SimTime(6));
        let dir = std::env::temp_dir().join(format!("exacb-store-{}", std::process::id()));
        s.persist(&dir).unwrap();
        let loaded = DataStore::load(&dir).unwrap();
        assert_eq!(loaded.read("exacb.data", "p/r.json").unwrap(), "content");
        assert_eq!(loaded.read("exacb.data", "p/s.json").unwrap(), "more");
        assert_eq!(loaded.history("exacb.data").len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_all_iter_matches_owned_read_all() {
        let mut s = DataStore::new();
        s.commit(
            "b",
            &[
                ("jedi.a/1/report.json".into(), "{\"a\":1}".into()),
                ("jedi.a/1/results.csv".into(), "status\nsuccess".into()),
                ("jedi.b/1/report.json".into(), "{\"b\":2}".into()),
            ],
            "m",
            SimTime(0),
        );
        let borrowed: Vec<(String, String)> = s
            .read_all_iter("b", "jedi.a/")
            .map(|(p, c)| (p.to_string(), c.to_string()))
            .collect();
        assert_eq!(borrowed, s.read_all("b", "jedi.a/"));
        assert_eq!(borrowed.len(), 2);
        assert_eq!(s.read_all_iter("b", "").count(), 3);
        assert_eq!(s.read_all_iter("nobranch", "").count(), 0);
    }

    #[test]
    fn tree_cache_resolves_near_head_without_root_replay() {
        let mut s = DataStore::new();
        let mut ids = Vec::new();
        for i in 0..1000i64 {
            ids.push(s.commit(
                "b",
                &[(format!("f{}", i % 7), format!("v{i}"))],
                &format!("c{i}"),
                SimTime(i),
            ));
        }
        // cold: resolving the head replays the full chain once, leaving
        // checkpoint trees behind
        let (head_tree, replayed_cold) = s.tree_at_traced(ids.last().unwrap()).unwrap();
        assert_eq!(replayed_cold, 1000);
        assert_eq!(&head_tree, s.head_tree("b").unwrap());
        // warm: a near-head commit resolves from the nearest checkpoint
        // without ever touching the orphan root
        let near = &ids[997];
        let (near_tree, replayed_warm) = s.tree_at_traced(near).unwrap();
        assert!(
            replayed_warm <= TREE_CHECKPOINT_EVERY,
            "near-head resolve replayed {replayed_warm} commits"
        );
        // and the memoized answer is byte-identical to a cold replay
        s.tree_cache.borrow_mut().clear();
        let (reference, replayed_ref) = s.tree_at_traced(near).unwrap();
        assert_eq!(replayed_ref, 998);
        assert_eq!(near_tree, reference);
    }

    #[test]
    fn second_persist_skips_existing_blobs() {
        let mut s = DataStore::new();
        s.commit(
            "b",
            &[("p/r.json".into(), "payload-a".into())],
            "m",
            SimTime(1),
        );
        let dir = std::env::temp_dir().join(format!("exacb-persist-skip-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        s.persist(&dir).unwrap();
        // clobber the blob on disk: a second persist of an unchanged
        // store must write zero new blob bytes, so the sentinel survives
        let blob_id = s.blobs.keys().next().unwrap().clone();
        let blob_path = dir.join("blobs").join(&blob_id);
        std::fs::write(&blob_path, "SENTINEL").unwrap();
        s.persist(&dir).unwrap();
        assert_eq!(std::fs::read_to_string(&blob_path).unwrap(), "SENTINEL");
        // a genuinely new blob still lands on disk
        s.commit(
            "b",
            &[("p/s.json".into(), "payload-b".into())],
            "n",
            SimTime(2),
        );
        s.persist(&dir).unwrap();
        assert_eq!(s.blobs.len(), 2);
        for id in s.blobs.keys() {
            assert!(dir.join("blobs").join(id).exists());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn commit_immutability_property() {
        use crate::prop_assert;
        use crate::util::prop::check;
        check("store commits are immutable", 30, |g| {
            let mut s = DataStore::new();
            let n = g.usize(1, 8);
            let mut snapshots = Vec::new();
            for i in 0..n {
                let content = format!("v{}", g.u64(0, 1000));
                let id = s.commit(
                    "b",
                    &[(format!("f{}", g.usize(0, 3)), content)],
                    &format!("c{i}"),
                    SimTime(i as i64),
                );
                snapshots.push((id, s.head_tree("b").unwrap().clone()));
            }
            // every recorded snapshot is still reconstructible
            for (id, tree) in &snapshots {
                let got = s.tree_at(id);
                prop_assert!(got.is_some(), "commit {id} vanished");
                prop_assert!(&got.unwrap() == tree, "tree for {id} changed");
            }
            Ok(())
        });
    }
}
