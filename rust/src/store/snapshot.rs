//! Incremental digest-indexed snapshots over the report store
//! (DESIGN.md §12).
//!
//! Every analysis consumer used to rebuild its view by re-walking and
//! re-parsing the whole `exacb.data` branch per invocation — with PR 6's
//! O(log n) dispatch core, that full-store walk became the fleet-scale
//! bottleneck (a gate firing through the event loop paid O(history) per
//! firing). A [`Snapshot`] is the read-side answer:
//!
//! * **parsed once** — every `.json` blob is parsed into a
//!   [`Report`](crate::protocol::Report) exactly once, keyed by its
//!   content digest; `.csv` blobs get their Table-I verdict
//!   ([`crate::protocol::csv_honours_contract`]) once;
//! * **interned** — app, machine, metric, and commit strings are
//!   deduplicated into small ids, so the observation index stays
//!   compact at fleet scale;
//! * **indexed** — successful data entries land in a
//!   (app, machine, metric, nodes) index with per-commit provenance,
//!   each observation keyed by the same digest scheme as
//!   [`crate::tracking::History`] (so warm-cache replays dedupe
//!   identically);
//! * **incremental** — [`Snapshot::build`] is O(history) once;
//!   [`Snapshot::refresh`] consumes only commits newer than the
//!   snapshot's recorded head id, mirroring the crate's
//!   incremental-cache discipline. *Refreshed == rebuilt-from-scratch*
//!   is the core property test, pinned via [`Snapshot::fingerprint`].
//!
//! The snapshot is immutable-after-build, which makes it safe to fan
//! query aggregation across OS threads: [`fan_shards`] / [`fan_chunks`]
//! are the `std::thread::scope`-based evaluators the
//! [`crate::query`] layer (`exacb cmp` / `exacb rank`) shards its
//! grouping and interval work with — deterministically, so sharded and
//! sequential runs are byte-identical.
//!
//! Exactly one escape hatch exists: `exacb.data` paths are keyed by
//! pipeline id and therefore append-only in practice. If a delta ever
//! *overwrites* an existing path with different content, `refresh`
//! falls back to a full rebuild (counted in [`Snapshot::rebuilds`])
//! instead of attempting incremental retraction — the fallback is the
//! scratch build, so the equivalence property holds unconditionally.

use std::collections::BTreeMap;

use crate::protocol::{csv_honours_contract, Report};
use crate::store::DataStore;
use crate::util::timeutil::SimTime;
use crate::util::wide_hash;

/// String interner: app / machine / metric / commit names occur once
/// per *name*, not once per observation.
#[derive(Debug, Clone, Default)]
struct Interner {
    ids: BTreeMap<String, u32>,
    strings: Vec<String>,
}

impl Interner {
    fn intern(&mut self, s: &str) -> u32 {
        if let Some(id) = self.ids.get(s) {
            return *id;
        }
        let id = self.strings.len() as u32;
        self.ids.insert(s.to_string(), id);
        self.strings.push(s.to_string());
        id
    }

    fn resolve(&self, id: u32) -> &str {
        &self.strings[id as usize]
    }
}

/// Index key of one observation series. Intern ids are assignment-order
/// dependent, so equality across snapshots is always judged on
/// *resolved* strings ([`Snapshot::rows`] / [`Snapshot::fingerprint`]),
/// never on raw ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct EntryKey {
    app: u32,
    machine: u32,
    metric: u32,
    nodes: u64,
}

/// One indexed observation (digest-keyed under its [`EntryKey`]).
#[derive(Debug, Clone, Copy)]
struct Obs {
    time: SimTime,
    pipeline_id: u64,
    commit: u32,
    seed: u64,
    value: f64,
}

/// A parsed `.json` document, keyed in [`Snapshot`] by content digest.
/// `report` is `None` when the blob did not parse as a protocol report
/// (consumers count those as skipped, exactly like the legacy walk).
#[derive(Debug, Clone)]
pub struct ParsedDoc {
    /// The parse result, computed once per distinct document.
    pub report: Option<Report>,
}

/// One fully-resolved observation — the row type the [`crate::query`]
/// layer aggregates and exports.
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    /// Store prefix first segment (`machine.app` by the execution
    /// component's convention).
    pub app: String,
    /// Recording system (`experiment.system`).
    pub machine: String,
    /// Metric name (`runtime` or an additional-metrics key).
    pub metric: String,
    /// Node count of the data entry.
    pub nodes: u64,
    /// Experiment timestamp.
    pub time: SimTime,
    /// Recording pipeline id.
    pub pipeline_id: u64,
    /// Source-commit SHA provenance (`reporter.commit`).
    pub commit: String,
    /// Reproduction seed (`reporter.seed`).
    pub seed: u64,
    /// Observation digest — `wide_hash(doc_digest|entry_idx|metric)`,
    /// identical to the [`crate::tracking::History`] point digest, so
    /// byte-identical replays dedupe the same way everywhere.
    pub digest: String,
    /// The measured value.
    pub value: f64,
}

/// Compacted, digest-indexed view of one branch head of a [`DataStore`].
#[derive(Debug, Clone)]
pub struct Snapshot {
    branch: String,
    head: Option<String>,
    /// path → content digest (wide).
    paths: BTreeMap<String, String>,
    /// content digest → parsed document (every `.json` path).
    docs: BTreeMap<String, ParsedDoc>,
    /// content digest → Table-I verdict (every `.csv` path).
    csv: BTreeMap<String, bool>,
    intern: Interner,
    /// (app, machine, metric, nodes) → observation digest → observation.
    entries: BTreeMap<EntryKey, BTreeMap<String, Obs>>,
    rebuilds: usize,
    commits_consumed: usize,
}

impl Snapshot {
    /// Build a snapshot of `branch`'s head from scratch: O(history) —
    /// one walk over the materialized head tree, one parse per distinct
    /// blob.
    pub fn build(store: &DataStore, branch: &str) -> Snapshot {
        let mut snap = Snapshot {
            branch: branch.to_string(),
            head: store.head(branch).map(|c| c.id.clone()),
            paths: BTreeMap::new(),
            docs: BTreeMap::new(),
            csv: BTreeMap::new(),
            intern: Interner::default(),
            entries: BTreeMap::new(),
            rebuilds: 1,
            commits_consumed: 0,
        };
        for (path, content) in store.read_all_iter(branch, "") {
            snap.add_path(path, content);
        }
        snap
    }

    /// Catch up with commits newer than the snapshot's recorded head.
    /// Returns the number of fresh commits consumed (0 when the head is
    /// unchanged). O(delta): only the fresh commits' blobs are hashed,
    /// parsed, and indexed. A delta that overwrites an existing path
    /// with different content — or a head the recorded anchor cannot
    /// reach — degrades to a full rebuild (see module docs).
    pub fn refresh(&mut self, store: &DataStore) -> usize {
        let head_now = store.head(&self.branch).map(|c| c.id.clone());
        if head_now == self.head {
            return 0;
        }
        // walk the new head's ancestry back to the recorded anchor
        let mut fresh = Vec::new();
        let mut cur = head_now.clone();
        let mut anchored = false;
        while let Some(id) = cur {
            if Some(&id) == self.head.as_ref() {
                anchored = true;
                break;
            }
            match store.commit_by_id(&id) {
                Some(c) => {
                    cur = c.parent.clone();
                    fresh.push(c);
                }
                None => break,
            }
        }
        if cur.is_none() {
            // reached the orphan root: only anchored if the snapshot
            // was built on an empty branch
            anchored = self.head.is_none();
        }
        let consumed = fresh.len();
        if !anchored {
            return self.rebuild(store, consumed);
        }
        for c in fresh.iter().rev() {
            for (path, blob_id) in &c.delta {
                let Some(content) = store.blob(blob_id) else {
                    continue;
                };
                if !self.add_path(path, content) {
                    // overwrite with different content: fall back
                    return self.rebuild(store, consumed);
                }
            }
        }
        self.head = head_now;
        self.commits_consumed += consumed;
        crate::obs::count(crate::obs::Ctr::SnapshotRefreshes, 1);
        crate::obs::count(crate::obs::Ctr::SnapshotCommitsConsumed, consumed as u64);
        consumed
    }

    /// Full rebuild preserving the incrementality counters — the
    /// overwrite / unreachable-anchor fallback of [`Snapshot::refresh`].
    fn rebuild(&mut self, store: &DataStore, consumed: usize) -> usize {
        let rebuilds = self.rebuilds;
        let commits = self.commits_consumed;
        *self = Snapshot::build(store, &self.branch);
        self.rebuilds += rebuilds;
        self.commits_consumed = commits + consumed;
        crate::obs::count(crate::obs::Ctr::SnapshotRebuilds, 1);
        crate::obs::count(crate::obs::Ctr::SnapshotCommitsConsumed, consumed as u64);
        consumed
    }

    /// Ingest one `(path, content)` pair. Returns `false` — leaving the
    /// snapshot untouched — when `path` already exists with *different*
    /// content (the overwrite case refresh must escalate on); a
    /// byte-identical re-commit is a `true` no-op.
    fn add_path(&mut self, path: &str, content: &str) -> bool {
        let digest = wide_hash(content.as_bytes());
        if let Some(old) = self.paths.get(path) {
            return *old == digest;
        }
        self.paths.insert(path.to_string(), digest.clone());
        if path.ends_with(".json") && !self.docs.contains_key(&digest) {
            self.docs.insert(
                digest.clone(),
                ParsedDoc {
                    report: Report::parse(content).ok(),
                },
            );
        }
        if path.ends_with(".csv") && !self.csv.contains_key(&digest) {
            self.csv.insert(digest.clone(), csv_honours_contract(content));
        }
        if path.ends_with("report.json") {
            let app = path.split('/').next().unwrap_or("").to_string();
            if let Some(report) = self.docs.get(&digest).and_then(|d| d.report.as_ref()) {
                index_report(&app, &digest, report, &mut self.intern, &mut self.entries);
            }
        }
        true
    }

    /// The branch this snapshot views.
    pub fn branch(&self) -> &str {
        &self.branch
    }

    /// The head commit id the snapshot is current with.
    pub fn head_id(&self) -> Option<&str> {
        self.head.as_deref()
    }

    /// How many times the snapshot was built from scratch (1 after
    /// [`Snapshot::build`]; each refresh fallback adds one). The
    /// O(delta) assertions pin this at 1 over append-only histories.
    pub fn rebuilds(&self) -> usize {
        self.rebuilds
    }

    /// Total fresh commits consumed by [`Snapshot::refresh`] calls.
    pub fn commits_consumed(&self) -> usize {
        self.commits_consumed
    }

    /// Number of paths at the snapshotted head.
    pub fn path_count(&self) -> usize {
        self.paths.len()
    }

    /// Number of distinct parsed `.json` documents.
    pub fn doc_count(&self) -> usize {
        self.docs.len()
    }

    /// Number of distinct interned strings.
    pub fn interned_strings(&self) -> usize {
        self.intern.strings.len()
    }

    /// Number of indexed observations (digest-deduped).
    pub fn obs_count(&self) -> usize {
        self.entries.values().map(|m| m.len()).sum()
    }

    /// All `(path, content digest)` pairs under `prefix`, in path order
    /// (an O(log n + matches) range scan, not a full-tree filter).
    pub fn paths_under<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a str)> + 'a {
        self.paths
            .range(prefix.to_string()..)
            .take_while(move |(p, _)| p.starts_with(prefix))
            .map(|(p, d)| (p.as_str(), d.as_str()))
    }

    /// Paths under `prefix` (the [`DataStore::list`] shape).
    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.paths_under(prefix).map(|(p, _)| p.to_string()).collect()
    }

    /// The parsed document for a content digest, if any `.json` path
    /// carries it.
    pub fn doc(&self, digest: &str) -> Option<&ParsedDoc> {
        self.docs.get(digest)
    }

    /// The content digest recorded for `path`.
    pub fn digest_at(&self, path: &str) -> Option<&str> {
        self.paths.get(path).map(String::as_str)
    }

    /// The parsed report at `path` (`None` for absent paths and
    /// unparseable documents alike).
    pub fn report_at(&self, path: &str) -> Option<&Report> {
        self.paths
            .get(path)
            .and_then(|d| self.docs.get(d))
            .and_then(|d| d.report.as_ref())
    }

    /// Table-I verdict of the `.csv` file at `path`; `false` when the
    /// path is absent (matching the legacy walk, which treats a missing
    /// sibling CSV as not honouring the contract).
    pub fn csv_ok_at(&self, path: &str) -> bool {
        self.paths
            .get(path)
            .and_then(|d| self.csv.get(d))
            .copied()
            .unwrap_or(false)
    }

    /// Every indexed observation, fully resolved and canonically sorted
    /// by (app, machine, metric, nodes, time, pipeline, digest) — the
    /// order is a pure function of content, never of ingestion order or
    /// intern-id assignment.
    pub fn rows(&self) -> Vec<Row> {
        let mut out = Vec::new();
        for (key, obs) in &self.entries {
            for (digest, o) in obs {
                out.push(Row {
                    app: self.intern.resolve(key.app).to_string(),
                    machine: self.intern.resolve(key.machine).to_string(),
                    metric: self.intern.resolve(key.metric).to_string(),
                    nodes: key.nodes,
                    time: o.time,
                    pipeline_id: o.pipeline_id,
                    commit: self.intern.resolve(o.commit).to_string(),
                    seed: o.seed,
                    digest: digest.clone(),
                    value: o.value,
                });
            }
        }
        sort_rows(&mut out);
        out
    }

    /// Canonical content hash of the whole snapshot — resolved strings
    /// only, so two snapshots of the same head hash identically no
    /// matter how they got there (scratch build, any refresh
    /// interleaving, any intern order). The refreshed == rebuilt
    /// property tests compare exactly this.
    pub fn fingerprint(&self) -> String {
        let mut text = String::new();
        text.push_str(&format!("branch={}|head={:?}\n", self.branch, self.head));
        for (p, d) in &self.paths {
            text.push_str(&format!("P|{p}|{d}\n"));
        }
        for (d, doc) in &self.docs {
            text.push_str(&format!("D|{d}|{}\n", doc.report.is_some()));
        }
        for (d, ok) in &self.csv {
            text.push_str(&format!("C|{d}|{ok}\n"));
        }
        for r in self.rows() {
            text.push_str(&format!(
                "R|{}|{}|{}|{}|{}|{}|{}|{}|{}|{:?}\n",
                r.app,
                r.machine,
                r.metric,
                r.nodes,
                r.time.0,
                r.pipeline_id,
                r.commit,
                r.seed,
                r.digest,
                r.value
            ));
        }
        wide_hash(text.as_bytes())
    }
}

/// Canonical row order (see [`Snapshot::rows`]).
pub fn sort_rows(rows: &mut [Row]) {
    rows.sort_by(|a, b| {
        (&a.app, &a.machine, &a.metric, a.nodes, a.time, a.pipeline_id, &a.digest).cmp(&(
            &b.app,
            &b.machine,
            &b.metric,
            b.nodes,
            b.time,
            b.pipeline_id,
            &b.digest,
        ))
    });
}

/// Index every successful, finite observation of one parsed report.
/// Free function so the docs borrow and the intern/entries borrows stay
/// disjoint.
fn index_report(
    app: &str,
    doc_digest: &str,
    report: &Report,
    intern: &mut Interner,
    entries: &mut BTreeMap<EntryKey, BTreeMap<String, Obs>>,
) {
    let time = report.experiment.time().unwrap_or_default();
    let app_id = intern.intern(app);
    let machine_id = intern.intern(&report.experiment.system);
    let commit_id = intern.intern(&report.reporter.commit);
    for (idx, e) in report.data.iter().enumerate() {
        if !e.success {
            continue;
        }
        let mut metrics: Vec<(&str, f64)> = vec![("runtime", e.runtime)];
        if let Some(obj) = e.metrics.as_obj() {
            for (name, v) in obj {
                // "runtime" always means the entry field, never an
                // additional-metrics key (History's precedence rule)
                if name == "runtime" {
                    continue;
                }
                if let Some(v) = v.as_f64() {
                    metrics.push((name.as_str(), v));
                }
            }
        }
        for (metric, value) in metrics {
            if !value.is_finite() {
                continue;
            }
            let key = EntryKey {
                app: app_id,
                machine: machine_id,
                metric: intern.intern(metric),
                nodes: e.nodes,
            };
            let obs_digest = wide_hash(format!("{doc_digest}|{idx}|{metric}").as_bytes());
            entries.entry(key).or_default().insert(
                obs_digest,
                Obs {
                    time,
                    pipeline_id: report.reporter.pipeline_id,
                    commit: commit_id,
                    seed: report.reporter.seed,
                    value,
                },
            );
        }
    }
}

/// Fan `f` over contiguous chunks of `items` across up to `shards` OS
/// threads (`std::thread::scope`; the crate stays dependency-free).
/// Results come back in chunk order, so the output is identical to a
/// sequential run — parallelism never changes bytes.
pub fn fan_chunks<T: Sync, R: Send>(
    items: &[T],
    shards: usize,
    f: impl Fn(&[T]) -> R + Sync,
) -> Vec<R> {
    let shards = shards.clamp(1, items.len().max(1));
    if shards == 1 {
        return if items.is_empty() {
            Vec::new()
        } else {
            vec![f(items)]
        };
    }
    let chunk = items.len().div_ceil(shards);
    let fref = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|slice| scope.spawn(move || fref(slice)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("query shard panicked"))
            .collect()
    })
}

/// Fan a per-item map over up to `shards` OS threads; results keep item
/// order (deterministic, byte-identical to `items.iter().map(f)`).
pub fn fan_shards<T: Sync, R: Send>(
    items: &[T],
    shards: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let fref = &f;
    fan_chunks(items, shards, move |slice| {
        slice.iter().map(fref).collect::<Vec<R>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{DataEntry, Experiment, Report, Reporter};
    use crate::util::json::Json;
    use crate::util::prng::Prng;

    /// A minimal but fully-formed protocol report document.
    fn doc(app_seed: u64, day: i64, pipeline: u64, value: f64) -> String {
        Report {
            reporter: Reporter {
                tool: "exacb".into(),
                tool_version: "1".into(),
                pipeline_id: pipeline,
                ci_job_id: pipeline * 10,
                commit: format!("c{:08x}", app_seed ^ (day as u64)),
                user: "exa".into(),
                system: "jedi".into(),
                system_version: "v1".into(),
                timestamp: SimTime::from_days(day).iso8601(),
                seed: app_seed,
            },
            parameter: Json::obj(),
            experiment: Experiment {
                system: "jedi".into(),
                software_version: "v1".into(),
                variant: "base".into(),
                usecase: "bench".into(),
                timestamp: SimTime::from_days(day).iso8601(),
            },
            data: vec![DataEntry {
                success: true,
                runtime: value,
                nodes: 4,
                taskspernode: 4,
                threadspertask: 8,
                jobid: pipeline,
                queue: "all".into(),
                metrics: Json::obj().set("tts", value * 2.0),
            }],
        }
        .to_document()
    }

    fn commit_report(store: &mut DataStore, app: &str, day: i64, pipeline: u64, value: f64) {
        let path = format!("{app}/{pipeline}/report.json");
        store.commit(
            "exacb.data",
            &[(path, doc(7, day, pipeline, value))],
            &format!("record {app} day {day}"),
            SimTime::from_days(day),
        );
    }

    #[test]
    fn build_indexes_reports_and_dedupes_replays() {
        let mut store = DataStore::new();
        commit_report(&mut store, "jedi.app", 0, 1, 10.0);
        commit_report(&mut store, "jedi.app", 1, 2, 11.0);
        // byte-identical replay under a new path: new path, same digest,
        // no new observation
        let replay = store.read("exacb.data", "jedi.app/1/report.json").unwrap().to_string();
        store.commit(
            "exacb.data",
            &[("jedi.app/3/report.json".into(), replay)],
            "replay",
            SimTime::from_days(2),
        );
        let snap = Snapshot::build(&store, "exacb.data");
        assert_eq!(snap.path_count(), 3);
        assert_eq!(snap.doc_count(), 2);
        // runtime + tts per report, replay deduped
        assert_eq!(snap.obs_count(), 4);
        let rows = snap.rows();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.app == "jedi.app" && r.machine == "jedi"));
        assert!(rows.windows(2).all(|w| w[0] != w[1]));
        assert_eq!(snap.head_id(), store.head("exacb.data").map(|c| c.id.as_str()));
    }

    #[test]
    fn refresh_consumes_only_the_delta() {
        let mut store = DataStore::new();
        for day in 0..5 {
            commit_report(&mut store, "jedi.app", day, day as u64 + 1, 10.0 + day as f64);
        }
        let mut snap = Snapshot::build(&store, "exacb.data");
        assert_eq!(snap.refresh(&store), 0, "unchanged head refreshes for free");
        commit_report(&mut store, "jedi.app", 5, 6, 15.0);
        commit_report(&mut store, "jedi.app", 6, 7, 16.0);
        assert_eq!(snap.refresh(&store), 2);
        assert_eq!(snap.rebuilds(), 1, "append-only history never rebuilds");
        assert_eq!(snap.commits_consumed(), 2);
        assert_eq!(snap.fingerprint(), Snapshot::build(&store, "exacb.data").fingerprint());
    }

    #[test]
    fn refresh_from_empty_branch_matches_scratch() {
        let mut store = DataStore::new();
        let mut snap = Snapshot::build(&store, "exacb.data");
        assert!(snap.head_id().is_none());
        commit_report(&mut store, "jedi.app", 0, 1, 10.0);
        snap.refresh(&store);
        assert_eq!(snap.rebuilds(), 1);
        assert_eq!(snap.fingerprint(), Snapshot::build(&store, "exacb.data").fingerprint());
    }

    #[test]
    fn overwrite_falls_back_to_rebuild_and_stays_identical() {
        let mut store = DataStore::new();
        commit_report(&mut store, "jedi.app", 0, 1, 10.0);
        let mut snap = Snapshot::build(&store, "exacb.data");
        // overwrite an existing path with different content
        store.commit(
            "exacb.data",
            &[("jedi.app/1/report.json".into(), doc(7, 3, 1, 99.0))],
            "amend",
            SimTime::from_days(3),
        );
        snap.refresh(&store);
        assert_eq!(snap.rebuilds(), 2, "overwrite must escalate to rebuild");
        assert_eq!(snap.fingerprint(), Snapshot::build(&store, "exacb.data").fingerprint());
    }

    #[test]
    fn interleaved_commit_refresh_is_byte_identical_to_scratch() {
        use crate::prop_assert;
        use crate::util::prop::check;
        check("snapshot refresh == scratch build", 25, |g| {
            let mut store = DataStore::new();
            let mut snap = Snapshot::build(&store, "exacb.data");
            let steps = g.usize(1, 12);
            let mut pipeline = 0u64;
            for day in 0..steps as i64 {
                let burst = g.usize(1, 3);
                for _ in 0..burst {
                    pipeline += 1;
                    let app = format!("jedi.app-{}", g.usize(0, 2));
                    if g.usize(0, 9) == 0 && pipeline > 1 {
                        // occasional overwrite of an old path: must
                        // trigger the rebuild fallback, not corruption
                        let path = format!("{app}/1/report.json");
                        store.commit(
                            "exacb.data",
                            &[(path, doc(g.u64(0, 50), day, pipeline, g.f64(1.0, 9.0)))],
                            "amend",
                            SimTime::from_days(day),
                        );
                    } else {
                        commit_report(&mut store, &app, day, pipeline, g.f64(1.0, 9.0));
                    }
                }
                if g.usize(0, 1) == 0 {
                    snap.refresh(&store);
                }
            }
            snap.refresh(&store);
            let scratch = Snapshot::build(&store, "exacb.data");
            prop_assert!(
                snap.fingerprint() == scratch.fingerprint(),
                "refreshed snapshot diverged from scratch build"
            );
            prop_assert!(
                snap.rows() == scratch.rows(),
                "refreshed rows diverged from scratch rows"
            );
            Ok(())
        });
    }

    #[test]
    fn rows_are_ingestion_order_independent() {
        let mut fwd = DataStore::new();
        let mut rev = DataStore::new();
        let mut specs = Vec::new();
        let mut g = Prng::new(42);
        for day in 0..6i64 {
            specs.push(("jedi.a", day, day as u64 + 1, g.range_f64(1.0, 5.0)));
            specs.push(("jedi.b", day, day as u64 + 100, g.range_f64(1.0, 5.0)));
        }
        for (app, day, pipeline, v) in &specs {
            commit_report(&mut fwd, app, *day, *pipeline, *v);
        }
        for (app, day, pipeline, v) in specs.iter().rev() {
            commit_report(&mut rev, app, *day, *pipeline, *v);
        }
        assert_eq!(
            Snapshot::build(&fwd, "exacb.data").rows(),
            Snapshot::build(&rev, "exacb.data").rows()
        );
    }

    #[test]
    fn fan_helpers_match_sequential_for_any_shard_count() {
        let items: Vec<u64> = (0..103).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        for shards in [0, 1, 2, 4, 7, 64, 1000] {
            assert_eq!(fan_shards(&items, shards, |x| x * 3 + 1), expect);
        }
        let sums: Vec<u64> = fan_chunks(&items, 4, |slice| slice.iter().sum());
        assert_eq!(sums.iter().sum::<u64>(), items.iter().sum::<u64>());
        assert_eq!(fan_shards(&[] as &[u64], 4, |x| *x), Vec::<u64>::new());
    }

    #[test]
    fn csv_and_parse_failures_are_visible() {
        let mut store = DataStore::new();
        store.commit(
            "exacb.data",
            &[
                (
                    "jedi.app/1/results.csv".into(),
                    format!("{}\n", crate::protocol::BASE_COLUMNS.join(",")),
                ),
                ("jedi.app/2/results.csv".into(), "not,a,contract\n".into()),
                ("jedi.app/2/report.json".into(), "{broken".into()),
            ],
            "mixed",
            SimTime(0),
        );
        let snap = Snapshot::build(&store, "exacb.data");
        assert!(snap.csv_ok_at("jedi.app/1/results.csv"));
        assert!(!snap.csv_ok_at("jedi.app/2/results.csv"));
        assert!(!snap.csv_ok_at("jedi.app/absent.csv"));
        assert!(snap.report_at("jedi.app/2/report.json").is_none());
        let digest = snap.digest_at("jedi.app/2/report.json").unwrap();
        assert!(snap.doc(digest).unwrap().report.is_none());
        assert_eq!(snap.obs_count(), 0);
    }
}
