//! Result storage (paper §IV-E; DESIGN.md §1 framework layer, §4 cache
//! design): "collected data are associated with the corresponding CI
//! jobs as artifacts and may additionally be stored in persistent
//! locations, such as orphaned Git branches or dedicated object storage
//! (e.g., S3-based back ends)".
//!
//! * [`git`] — a content-addressed commit store with branch semantics:
//!   the `exacb.data` orphan branch each benchmark repository carries.
//! * [`object`] — a flat S3-like bucket/key blob store.
//! * [`cache`] — the content-addressed execution cache layered on the
//!   object store: digest-keyed step outcomes + whole-run reports that
//!   make repeat collection sweeps incremental.
//! * [`snapshot`] — the read side (DESIGN.md §12): a compacted,
//!   digest-indexed view of the `exacb.data` head, built O(history)
//!   once, refreshed O(delta) from commit deltas, fanned across threads
//!   by the query layer.
//!
//! All are deterministic and in-memory with optional directory
//! persistence; immutability of committed history is a tested invariant
//! (a-posteriori time-series analyses depend on it, §IV-F).

pub mod cache;
pub mod git;
pub mod object;
pub mod snapshot;

pub use cache::{CacheKey, CacheKeyBuilder, CacheStats, ExecutionCache};
pub use git::{Commit, DataStore, StoreError};
pub use object::ObjectStore;
pub use snapshot::{fan_chunks, fan_shards, sort_rows, ParsedDoc, Row, Snapshot};
