//! exaCB leader entrypoint: the command-line interface over the library.
//!
//! See `exacb --help` (or [`exacb::cli::USAGE`]) for commands. The binary
//! is self-contained after `make artifacts`: Python is never invoked.

fn main() {
    let code = exacb::cli::run(std::env::args().skip(1).collect());
    std::process::exit(code);
}
