//! Parameter-space expansion and `$var` substitution.
//!
//! JUBE "resolves dependencies between individual commands and expands
//! parameters, allowing for parameter space explorations through multiple
//! definition of explored parameters" (paper §II-B). Expansion is the
//! full cross product of every active multi-valued parameter; activity is
//! controlled by tags.

use std::collections::BTreeMap;

use super::spec::{BenchmarkSpec, ParameterSet};

/// One resolved point of the parameter space (name -> value).
pub type ParamPoint = BTreeMap<String, String>;

/// Expand the cross product of all active parameters in the given sets.
/// A parameter with a `tag` participates only when that tag is passed;
/// multiple definitions of the *same* parameter name are overridden by
/// the later (more specific, tag-activated) definition, matching JUBE's
/// script-inheritance behaviour.
pub fn expand(sets: &[&ParameterSet], tags: &[String]) -> Vec<ParamPoint> {
    // Collect active parameters; later definitions override earlier ones.
    let mut axes: Vec<(String, Vec<String>)> = Vec::new();
    for set in sets {
        for p in &set.parameters {
            let active = match &p.tag {
                None => true,
                Some(t) => tags.iter().any(|x| x == t),
            };
            if !active {
                continue;
            }
            if let Some(slot) = axes.iter_mut().find(|(n, _)| n == &p.name) {
                slot.1 = p.values.clone();
            } else {
                axes.push((p.name.clone(), p.values.clone()));
            }
        }
    }
    let mut points: Vec<ParamPoint> = vec![ParamPoint::new()];
    for (name, values) in &axes {
        let mut next = Vec::with_capacity(points.len() * values.len());
        for point in &points {
            for v in values {
                let mut p = point.clone();
                p.insert(name.clone(), v.clone());
                next.push(p);
            }
        }
        points = next;
    }
    points
}

/// Expand the parameter sets used by a named step of a spec.
pub fn expand_for_step(
    spec: &BenchmarkSpec,
    step_name: &str,
    tags: &[String],
) -> Vec<ParamPoint> {
    let step = match spec.steps.iter().find(|s| s.name == step_name) {
        Some(s) => s,
        None => return vec![ParamPoint::new()],
    };
    let sets: Vec<&ParameterSet> = spec
        .parametersets
        .iter()
        .filter(|ps| step.uses.iter().any(|u| u == &ps.name))
        .collect();
    expand(&sets, tags)
}

/// Substitute `$name` / `${name}` occurrences with parameter values.
/// Unknown variables are left untouched (they may be environment-level,
/// resolved later by the executor). `$$` escapes a literal `$`.
pub fn substitute(template: &str, point: &ParamPoint) -> String {
    let mut out = String::with_capacity(template.len());
    let bytes = template.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'$' {
            if i + 1 < bytes.len() && bytes[i + 1] == b'$' {
                out.push('$');
                i += 2;
                continue;
            }
            let (name, consumed) = if i + 1 < bytes.len() && bytes[i + 1] == b'{' {
                match template[i + 2..].find('}') {
                    Some(end) => (template[i + 2..i + 2 + end].to_string(), end + 3),
                    None => {
                        out.push('$');
                        i += 1;
                        continue;
                    }
                }
            } else {
                let rest = &template[i + 1..];
                let len = rest
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .map(char::len_utf8)
                    .sum::<usize>();
                (rest[..len].to_string(), len + 1)
            };
            if !name.is_empty() {
                if let Some(v) = point.get(&name) {
                    out.push_str(v);
                    i += consumed;
                    continue;
                }
            }
            // unknown or empty: keep as-is
            out.push('$');
            i += 1;
        } else {
            let c = template[i..].chars().next().unwrap();
            out.push(c);
            i += c.len_utf8();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::spec::BenchmarkSpec;
    use super::*;

    fn pt(pairs: &[(&str, &str)]) -> ParamPoint {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    #[test]
    fn cross_product_size() {
        let spec = BenchmarkSpec::parse(super::super::spec::LOGMAP_SPEC).unwrap();
        // without the scaling tag: workload in {4,6} x intensity -> 2 points
        let pts = expand_for_step(&spec, "execute", &[]);
        assert_eq!(pts.len(), 2);
        // with the scaling tag: x nodes in {1,2} -> 4 points
        let pts = expand_for_step(&spec, "execute", &["scaling".to_string()]);
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().all(|p| p.contains_key("nodes")));
    }

    #[test]
    fn full_cross_product_property() {
        use crate::prop_assert;
        use crate::util::prop::check;
        check("expansion is a full cross product", 50, |g| {
            let n_axes = g.usize(1, 4);
            let mut sizes = Vec::new();
            let mut params = Vec::new();
            for a in 0..n_axes {
                let k = g.usize(1, 4);
                sizes.push(k);
                params.push(super::super::spec::Parameter {
                    name: format!("p{a}"),
                    values: (0..k).map(|v| v.to_string()).collect(),
                    tag: None,
                });
            }
            let set = ParameterSet {
                name: "s".into(),
                parameters: params,
            };
            let pts = expand(&[&set], &[]);
            let expect: usize = sizes.iter().product();
            prop_assert!(
                pts.len() == expect,
                "got {} points, expected {expect}",
                pts.len()
            );
            // all points distinct
            let mut seen = std::collections::HashSet::new();
            for p in &pts {
                prop_assert!(seen.insert(format!("{p:?}")), "duplicate point {p:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn tagged_override_wins() {
        let a = ParameterSet {
            name: "a".into(),
            parameters: vec![
                super::super::spec::Parameter {
                    name: "queue".into(),
                    values: vec!["default".into()],
                    tag: None,
                },
                super::super::spec::Parameter {
                    name: "queue".into(),
                    values: vec!["dc-gpu".into()],
                    tag: Some("jureca".into()),
                },
            ],
        };
        let pts = expand(&[&a], &[]);
        assert_eq!(pts[0]["queue"], "default");
        let pts = expand(&[&a], &["jureca".to_string()]);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0]["queue"], "dc-gpu");
    }

    #[test]
    fn substitution_forms() {
        let p = pt(&[("workload", "6"), ("intensity", "2.4")]);
        assert_eq!(
            substitute("logmap --workload $workload --intensity ${intensity}", &p),
            "logmap --workload 6 --intensity 2.4"
        );
        assert_eq!(substitute("cost: $$5 for $workload", &p), "cost: $5 for 6");
        assert_eq!(substitute("$unknown stays", &p), "$unknown stays");
        assert_eq!(substitute("${unclosed", &p), "${unclosed");
        assert_eq!(substitute("a$workload_x", &p), "a$workload_x"); // _x extends the name
    }

    #[test]
    fn empty_sets_give_single_empty_point() {
        let pts = expand(&[], &[]);
        assert_eq!(pts.len(), 1);
        assert!(pts[0].is_empty());
    }
}
