//! Benchmark-script model (the JUBE-like harness front end, paper §II-B).
//!
//! A benchmark definition is a YAML document ("JUBE script") with
//! parameter sets, a step DAG, and analysis patterns:
//!
//! ```yaml
//! name: logmap
//! parametersets:
//!   - name: run
//!     parameters:
//!       - name: workload
//!         values: [4, 6]            # expands the study
//!       - name: intensity
//!         value: 2.4
//!       - name: nodes
//!         values: [1, 2, 4]
//!         tag: scaling              # only active when tag set
//! steps:
//!   - name: compile
//!     do:
//!       - cmake -S . -B build -DPROJECT_FEATURE=feature
//!       - cmake --build build
//!   - name: execute
//!     depends: [compile]
//!     use: [run]
//!     remote: true                  # submitted to the batch system
//!     do:
//!       - logmap --workload $workload --intensity $intensity
//! analysis:
//!   - name: runtime
//!     file: logmap.out
//!     regex: "time: ([0-9.eE+-]+)"
//!     type: float
//! ```
//!
//! Tags (paper §II-B) select system- and variant-specific definitions at
//! launch: parameters and steps carry an optional `tag`, active only when
//! that tag is passed (`jube run logmap.yml --tags juwels-booster
//! large-intensity`).

use crate::util::json::Json;
use crate::util::yamlite;

#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    Yaml(String),
    Invalid(String),
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Yaml(e) => write!(f, "yaml: {e}"),
            SpecError::Invalid(e) => write!(f, "spec: {e}"),
        }
    }
}

impl std::error::Error for SpecError {}

fn invalid(msg: impl Into<String>) -> SpecError {
    SpecError::Invalid(msg.into())
}

/// A parameter definition: fixed value or a study axis.
#[derive(Debug, Clone, PartialEq)]
pub struct Parameter {
    pub name: String,
    /// One or more values; >1 values expand the parameter space.
    pub values: Vec<String>,
    /// Active only when this tag is passed (None = always active).
    pub tag: Option<String>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParameterSet {
    pub name: String,
    pub parameters: Vec<Parameter>,
}

/// One step of the benchmark workflow.
#[derive(Debug, Clone, PartialEq)]
pub struct Step {
    pub name: String,
    pub depends: Vec<String>,
    /// Parameter sets this step consumes.
    pub uses: Vec<String>,
    /// Shell-like command lines (interpreted by the executor).
    pub commands: Vec<String>,
    /// Submitted to the batch system instead of running on the login node.
    pub remote: bool,
    pub tag: Option<String>,
}

/// A regex extraction applied to an output file after execution.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisPattern {
    pub name: String,
    pub file: String,
    pub regex: String,
    /// "float" | "int" | "string"
    pub dtype: String,
}

/// The parsed benchmark definition.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchmarkSpec {
    pub name: String,
    pub parametersets: Vec<ParameterSet>,
    pub steps: Vec<Step>,
    pub analysis: Vec<AnalysisPattern>,
}

impl BenchmarkSpec {
    pub fn parse(yaml_text: &str) -> Result<BenchmarkSpec, SpecError> {
        let doc = yamlite::parse(yaml_text).map_err(|e| SpecError::Yaml(e.to_string()))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<BenchmarkSpec, SpecError> {
        let name = doc
            .str_of("name")
            .ok_or_else(|| invalid("missing 'name'"))?
            .to_string();

        let mut parametersets = Vec::new();
        if let Some(sets) = doc.get("parametersets").and_then(Json::as_arr) {
            for (i, set) in sets.iter().enumerate() {
                parametersets.push(parse_parameterset(set, i)?);
            }
        }

        let mut steps = Vec::new();
        for (i, s) in doc
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| invalid("missing 'steps' list"))?
            .iter()
            .enumerate()
        {
            steps.push(parse_step(s, i)?);
        }
        if steps.is_empty() {
            return Err(invalid("'steps' must not be empty"));
        }

        let mut analysis = Vec::new();
        if let Some(pats) = doc.get("analysis").and_then(Json::as_arr) {
            for (i, p) in pats.iter().enumerate() {
                analysis.push(parse_pattern(p, i)?);
            }
        }

        let spec = BenchmarkSpec {
            name,
            parametersets,
            steps,
            analysis,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<(), SpecError> {
        // unique step names, known dependencies and parameter sets
        for (i, s) in self.steps.iter().enumerate() {
            if self.steps[..i].iter().any(|o| o.name == s.name) {
                return Err(invalid(format!("duplicate step '{}'", s.name)));
            }
            for d in &s.depends {
                if !self.steps.iter().any(|o| &o.name == d) {
                    return Err(invalid(format!(
                        "step '{}' depends on unknown step '{d}'",
                        s.name
                    )));
                }
            }
            for u in &s.uses {
                if !self.parametersets.iter().any(|p| &p.name == u) {
                    return Err(invalid(format!(
                        "step '{}' uses unknown parameterset '{u}'",
                        s.name
                    )));
                }
            }
        }
        // regexes must compile
        for p in &self.analysis {
            crate::util::rex::Rex::new(&p.regex)
                .map_err(|e| invalid(format!("pattern '{}': {e}", p.name)))?;
            if !["float", "int", "string"].contains(&p.dtype.as_str()) {
                return Err(invalid(format!(
                    "pattern '{}': unknown type '{}'",
                    p.name, p.dtype
                )));
            }
        }
        Ok(())
    }

    /// Steps in dependency order; error on cycles.
    pub fn step_order(&self) -> Result<Vec<&Step>, SpecError> {
        let mut order: Vec<&Step> = Vec::new();
        let mut done: Vec<&str> = Vec::new();
        let mut remaining: Vec<&Step> = self.steps.iter().collect();
        while !remaining.is_empty() {
            let ready: Vec<usize> = remaining
                .iter()
                .enumerate()
                .filter(|(_, s)| s.depends.iter().all(|d| done.contains(&d.as_str())))
                .map(|(i, _)| i)
                .collect();
            if ready.is_empty() {
                return Err(invalid(format!(
                    "dependency cycle among steps: {}",
                    remaining
                        .iter()
                        .map(|s| s.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )));
            }
            for i in ready.into_iter().rev() {
                let s = remaining.remove(i);
                done.push(&s.name);
                order.push(s);
            }
        }
        Ok(order)
    }
}

fn parse_parameterset(v: &Json, i: usize) -> Result<ParameterSet, SpecError> {
    let name = v
        .str_of("name")
        .ok_or_else(|| invalid(format!("parameterset[{i}]: missing 'name'")))?
        .to_string();
    let mut parameters = Vec::new();
    for (j, p) in v
        .get("parameters")
        .and_then(Json::as_arr)
        .ok_or_else(|| invalid(format!("parameterset '{name}': missing 'parameters'")))?
        .iter()
        .enumerate()
    {
        let pname = p
            .str_of("name")
            .ok_or_else(|| invalid(format!("parameterset '{name}'[{j}]: missing 'name'")))?
            .to_string();
        let values: Vec<String> = if let Some(vals) = p.get("values").and_then(Json::as_arr) {
            vals.iter().map(json_scalar_string).collect()
        } else if let Some(v1) = p.get("value") {
            vec![json_scalar_string(v1)]
        } else {
            return Err(invalid(format!(
                "parameter '{pname}': needs 'value' or 'values'"
            )));
        };
        if values.is_empty() {
            return Err(invalid(format!("parameter '{pname}': empty 'values'")));
        }
        parameters.push(Parameter {
            name: pname,
            values,
            tag: p.str_of("tag").map(str::to_string),
        });
    }
    Ok(ParameterSet { name, parameters })
}

fn parse_step(v: &Json, i: usize) -> Result<Step, SpecError> {
    let name = v
        .str_of("name")
        .ok_or_else(|| invalid(format!("steps[{i}]: missing 'name'")))?
        .to_string();
    let strings = |key: &str| -> Vec<String> {
        v.get(key)
            .and_then(Json::as_arr)
            .map(|a| a.iter().map(json_scalar_string).collect())
            .unwrap_or_default()
    };
    let commands = match v.get("do") {
        Some(Json::Arr(_)) => strings("do"),
        Some(Json::Str(s)) => s.lines().map(str::to_string).collect(),
        _ => {
            return Err(invalid(format!("step '{name}': missing 'do'")));
        }
    };
    Ok(Step {
        name,
        depends: strings("depends"),
        uses: strings("use"),
        commands,
        remote: v.bool_of("remote").unwrap_or(false)
            || v.str_of("remote") == Some("true"),
        tag: v.str_of("tag").map(str::to_string),
    })
}

fn parse_pattern(v: &Json, i: usize) -> Result<AnalysisPattern, SpecError> {
    Ok(AnalysisPattern {
        name: v
            .str_of("name")
            .ok_or_else(|| invalid(format!("analysis[{i}]: missing 'name'")))?
            .to_string(),
        file: v
            .str_of("file")
            .ok_or_else(|| invalid(format!("analysis[{i}]: missing 'file'")))?
            .to_string(),
        regex: v
            .str_of("regex")
            .ok_or_else(|| invalid(format!("analysis[{i}]: missing 'regex'")))?
            .to_string(),
        dtype: v.str_of("type").unwrap_or("string").to_string(),
    })
}

fn json_scalar_string(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => format!("{}", *n as i64),
        Json::Num(n) => format!("{n}"),
        Json::Bool(b) => b.to_string(),
        Json::Null => String::new(),
        other => other.to_string(),
    }
}

#[cfg(test)]
pub(crate) const LOGMAP_SPEC: &str = r#"
name: logmap
parametersets:
  - name: run
    parameters:
      - name: workload
        values: [4, 6]
      - name: intensity
        value: 2.4
      - name: nodes
        values: [1, 2]
        tag: scaling
steps:
  - name: compile
    do:
      - cmake -S . -B build
      - cmake --build build
  - name: execute
    depends: [compile]
    use: [run]
    remote: true
    do:
      - logmap --workload $workload --intensity $intensity
analysis:
  - name: runtime
    file: logmap.out
    regex: "time: ([0-9.eE+-]+)"
    type: float
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        assert_eq!(spec.name, "logmap");
        assert_eq!(spec.parametersets.len(), 1);
        assert_eq!(spec.parametersets[0].parameters.len(), 3);
        assert_eq!(spec.steps.len(), 2);
        assert!(spec.steps[1].remote);
        assert_eq!(spec.analysis[0].dtype, "float");
    }

    #[test]
    fn step_order_respects_deps() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let order = spec.step_order().unwrap();
        let names: Vec<&str> = order.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["compile", "execute"]);
    }

    #[test]
    fn detects_cycles() {
        let text = r#"
name: cyc
steps:
  - name: a
    depends: [b]
    do: [x]
  - name: b
    depends: [a]
    do: [y]
"#;
        let err = BenchmarkSpec::parse(text)
            .unwrap()
            .step_order()
            .unwrap_err();
        assert!(err.to_string().contains("cycle"));
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(BenchmarkSpec::parse("name: x\n").is_err()); // no steps
        let dup = "name: x\nsteps:\n  - name: a\n    do: [c]\n  - name: a\n    do: [c]\n";
        assert!(BenchmarkSpec::parse(dup).is_err());
        let unk_dep = "name: x\nsteps:\n  - name: a\n    depends: [z]\n    do: [c]\n";
        assert!(BenchmarkSpec::parse(unk_dep).is_err());
        let unk_use = "name: x\nsteps:\n  - name: a\n    use: [z]\n    do: [c]\n";
        assert!(BenchmarkSpec::parse(unk_use).is_err());
        let bad_re = "name: x\nsteps:\n  - name: a\n    do: [c]\nanalysis:\n  - name: m\n    file: f\n    regex: \"([\"\n";
        assert!(BenchmarkSpec::parse(bad_re).is_err());
    }

    #[test]
    fn multiline_do_block() {
        let text = "name: x\nsteps:\n  - name: a\n    do: |\n      echo one\n      echo two\n";
        let spec = BenchmarkSpec::parse(text).unwrap();
        assert_eq!(spec.steps[0].commands.len(), 2);
    }
}
