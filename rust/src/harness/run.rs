//! Harness execution engine: step DAG × parameter space → analysed runs.
//!
//! For each point of the expanded parameter space, steps run in
//! dependency order. Two driving modes share one engine:
//!
//! * **blocking** — [`run_benchmark`] takes a [`StepExecutor`] (the
//!   bridge to either the login node or the batch system) and drives the
//!   whole study to completion, draining each remote submission before
//!   the next step;
//! * **resumable** — a [`RunCursor`] advances the same step-DAG ×
//!   parameter-space walk through a two-phase [`StepDriver`], *yielding*
//!   at every remote submission instead of draining the batch system.
//!   The coordinator's event loop interleaves many cursors (one per
//!   in-flight pipeline) on one shared virtual timeline and resumes a
//!   cursor when its awaited job completes.
//!
//! `run_benchmark` is implemented on top of the cursor with a blocking
//! adapter, so both modes execute byte-identically step for step. After
//! the last step of a point, analysis patterns are applied to the
//! produced output files and one [`RunOutcome`] per point is produced;
//! `results_table` renders them per Table I.

use std::collections::BTreeMap;

use super::expand::{expand_for_step, substitute, ParamPoint};
use super::spec::{AnalysisPattern, BenchmarkSpec, SpecError, Step};
use crate::protocol::DataEntry;
use crate::util::json::Json;

/// A step with parameters substituted into its command lines.
#[derive(Debug, Clone)]
pub struct ResolvedStep {
    pub name: String,
    pub commands: Vec<String>,
    pub remote: bool,
    pub point: ParamPoint,
}

/// What executing one step produced.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub success: bool,
    /// Application runtime [s] (remote steps: the batch job runtime).
    pub runtime_s: f64,
    /// Output files (name -> contents) available to analysis.
    pub files: Vec<(String, String)>,
    /// Structured metrics contributed directly by the executor.
    pub metrics: Json,
    /// Scheduler metadata for remote steps.
    pub jobid: u64,
    pub queue: String,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
}

impl StepOutcome {
    pub fn local_ok() -> StepOutcome {
        StepOutcome {
            success: true,
            runtime_s: 0.0,
            files: Vec::new(),
            metrics: Json::obj(),
            jobid: 0,
            queue: String::new(),
            nodes: 1,
            tasks_per_node: 1,
            threads_per_task: 1,
        }
    }

    pub fn failed(msg: &str) -> StepOutcome {
        StepOutcome {
            success: false,
            metrics: Json::obj().set("error", msg),
            ..StepOutcome::local_ok()
        }
    }

    /// Serialize for the execution cache. A replay must reconstruct the
    /// outcome exactly: output files feed analysis patterns, metrics are
    /// merged into the protocol report verbatim.
    pub fn to_document(&self) -> String {
        let mut files = Json::arr();
        for (name, content) in &self.files {
            files.push(
                Json::obj()
                    .set("name", name.as_str())
                    .set("content", content.as_str()),
            );
        }
        Json::obj()
            .set("success", self.success)
            .set("runtime_s", self.runtime_s)
            .set("files", files)
            .set("metrics", self.metrics.clone())
            .set("jobid", self.jobid)
            .set("queue", self.queue.as_str())
            .set("nodes", self.nodes)
            .set("taskspernode", self.tasks_per_node)
            .set("threadspertask", self.threads_per_task)
            .pretty()
    }

    /// Parse a cached outcome document; `None` on any shape mismatch
    /// (the caller then falls back to executing the step).
    pub fn from_document(doc: &str) -> Option<StepOutcome> {
        let v = Json::parse(doc).ok()?;
        let mut files = Vec::new();
        for f in v.get("files").and_then(Json::as_arr).unwrap_or(&[]) {
            files.push((
                f.str_of("name")?.to_string(),
                f.str_of("content")?.to_string(),
            ));
        }
        Some(StepOutcome {
            success: v.bool_of("success")?,
            runtime_s: v.f64_of("runtime_s")?,
            files,
            metrics: v.get("metrics").cloned().unwrap_or_else(Json::obj),
            jobid: v.u64_of("jobid")?,
            queue: v.str_of("queue")?.to_string(),
            nodes: v.u64_of("nodes")?,
            tasks_per_node: v.u64_of("taskspernode")?,
            threads_per_task: v.u64_of("threadspertask")?,
        })
    }
}

/// The execution back end: interprets a resolved step's commands.
pub trait StepExecutor {
    fn execute(&mut self, step: &ResolvedStep) -> StepOutcome;
}

/// How a dispatched step proceeded under a [`StepDriver`].
#[derive(Debug)]
pub enum StepDispatch {
    /// The step finished synchronously (local steps, cache hits,
    /// submission failures).
    Done(StepOutcome),
    /// The step was submitted as batch job `jobid`; the outcome becomes
    /// available through [`StepDriver::collect`] once that job completes.
    Submitted(u64),
}

/// Two-phase execution back end for the resumable [`RunCursor`]: remote
/// steps *submit* and later *collect* instead of blocking on the batch
/// system. Implemented by the coordinator's batch executor; any plain
/// [`StepExecutor`] can be driven through the blocking adapter inside
/// [`run_benchmark`].
pub trait StepDriver {
    fn dispatch(&mut self, step: &ResolvedStep) -> StepDispatch;
    /// Outcome of the previously submitted job `jobid`. Only called
    /// after the driver's owner observed the job reach a terminal state.
    fn collect(&mut self, jobid: u64) -> StepOutcome;
}

/// One fully-executed parameter point.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub point: ParamPoint,
    pub success: bool,
    pub runtime_s: f64,
    /// Metrics: executor metrics merged with analysis extractions.
    pub metrics: Json,
    pub jobid: u64,
    pub queue: String,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
    /// Per-step success for diagnostics.
    pub step_status: Vec<(String, bool)>,
}

impl RunOutcome {
    /// Convert to a protocol data entry (§V-B (e)).
    pub fn to_data_entry(&self) -> DataEntry {
        DataEntry {
            success: self.success,
            runtime: self.runtime_s,
            nodes: self.nodes,
            taskspernode: self.tasks_per_node,
            threadspertask: self.threads_per_task,
            jobid: self.jobid,
            queue: self.queue.clone(),
            metrics: self.metrics.clone(),
        }
    }
}

/// Run the whole benchmark: expand, execute, analyse. Blocking mode:
/// every remote step drains before the next begins (the executor's
/// `execute` is dispatch + drain + collect in one call).
pub fn run_benchmark(
    spec: &BenchmarkSpec,
    tags: &[String],
    executor: &mut dyn StepExecutor,
) -> Result<Vec<RunOutcome>, SpecError> {
    let mut cursor = RunCursor::new(spec, tags)?;
    let mut driver = BlockingDriver { inner: executor };
    match cursor.poll(&mut driver) {
        CursorPoll::Finished => Ok(cursor.into_outcomes()),
        CursorPoll::Waiting { .. } => {
            unreachable!("blocking driver completes every step synchronously")
        }
    }
}

/// Adapter running a plain [`StepExecutor`] under the cursor: every
/// dispatch completes synchronously, so the cursor never yields.
struct BlockingDriver<'a> {
    inner: &'a mut dyn StepExecutor,
}

impl StepDriver for BlockingDriver<'_> {
    fn dispatch(&mut self, step: &ResolvedStep) -> StepDispatch {
        StepDispatch::Done(self.inner.execute(step))
    }

    fn collect(&mut self, _jobid: u64) -> StepOutcome {
        StepOutcome::failed("blocking driver never leaves a step pending")
    }
}

fn active_step(step: &Step, tags: &[String]) -> bool {
    match &step.tag {
        None => true,
        Some(t) => tags.iter().any(|x| x == t),
    }
}

/// What a cursor is doing after an advance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CursorPoll {
    /// A remote step was submitted as batch job `jobid`; resume with
    /// [`RunCursor::complete`] once it reaches a terminal state.
    Waiting { jobid: u64 },
    /// Every parameter point has executed; take the results with
    /// [`RunCursor::into_outcomes`].
    Finished,
}

/// Per-point accumulation state, mirroring one pass of the legacy
/// blocking loop over a point's steps.
struct PointAccum {
    files: Vec<(String, String)>,
    metrics: Json,
    step_status: Vec<(String, bool)>,
    success: bool,
    runtime_s: f64,
    jobid: u64,
    queue: String,
    nodes: u64,
    tasks_per_node: u64,
    threads_per_task: u64,
}

impl PointAccum {
    fn new() -> PointAccum {
        PointAccum {
            files: Vec::new(),
            metrics: Json::obj(),
            step_status: Vec::new(),
            success: true,
            runtime_s: 0.0,
            jobid: 0,
            queue: String::new(),
            nodes: 1,
            tasks_per_node: 1,
            threads_per_task: 1,
        }
    }
}

struct Awaited {
    resolved: ResolvedStep,
    jobid: u64,
}

/// Resumable step-DAG cursor: the benchmark run as a state machine.
///
/// The cursor owns the expanded walk (dependency-ordered steps × leaf
/// parameter points) and a position within it. [`RunCursor::poll`]
/// advances until a remote step is submitted ([`CursorPoll::Waiting`])
/// or the study completes; [`RunCursor::complete`] feeds the awaited
/// job's completion back in and keeps advancing. The walk order — and
/// therefore every executor interaction, including PRNG consumption —
/// is identical to the blocking [`run_benchmark`] loop.
pub struct RunCursor {
    steps: Vec<Step>,
    analysis: Vec<AnalysisPattern>,
    tags: Vec<String>,
    points: Vec<ParamPoint>,
    point_idx: usize,
    step_idx: usize,
    acc: PointAccum,
    outcomes: Vec<RunOutcome>,
    awaiting: Option<Awaited>,
}

impl RunCursor {
    pub fn new(spec: &BenchmarkSpec, tags: &[String]) -> Result<RunCursor, SpecError> {
        let order = spec.step_order()?;
        // The parameter space of the run is the union of axes used by
        // any step; expansion per final (leaf) step keeps per-point
        // execution simple: we expand over the *last* step's space, and
        // earlier steps see the subset of parameters they use.
        let leaf = order.last().expect("validated non-empty");
        let points = expand_for_step(spec, &leaf.name, tags);
        let steps: Vec<Step> = order.into_iter().cloned().collect();
        Ok(RunCursor {
            steps,
            analysis: spec.analysis.clone(),
            tags: tags.to_vec(),
            points,
            point_idx: 0,
            step_idx: 0,
            acc: PointAccum::new(),
            outcomes: Vec::new(),
            awaiting: None,
        })
    }

    pub fn is_finished(&self) -> bool {
        self.awaiting.is_none() && self.point_idx >= self.points.len()
    }

    /// Advance until the next remote submission or the end of the study.
    /// Idempotent while waiting: polling again without completing the
    /// awaited job just reports the same wait.
    pub fn poll(&mut self, exec: &mut dyn StepDriver) -> CursorPoll {
        if let Some(w) = &self.awaiting {
            return CursorPoll::Waiting { jobid: w.jobid };
        }
        while self.point_idx < self.points.len() {
            while self.step_idx < self.steps.len() {
                let step = self.steps[self.step_idx].clone();
                if !active_step(&step, &self.tags) {
                    self.step_idx += 1;
                    continue;
                }
                if !self.acc.success {
                    // downstream steps are skipped after a failure
                    self.acc.step_status.push((step.name.clone(), false));
                    self.step_idx += 1;
                    continue;
                }
                let point = self.points[self.point_idx].clone();
                let resolved = ResolvedStep {
                    name: step.name.clone(),
                    commands: step
                        .commands
                        .iter()
                        .map(|c| substitute(c, &point))
                        .collect(),
                    remote: step.remote,
                    point,
                };
                match exec.dispatch(&resolved) {
                    StepDispatch::Done(out) => {
                        self.apply(&resolved, out);
                        self.step_idx += 1;
                    }
                    StepDispatch::Submitted(jobid) => {
                        self.awaiting = Some(Awaited { resolved, jobid });
                        return CursorPoll::Waiting { jobid };
                    }
                }
            }
            self.finish_point();
        }
        CursorPoll::Finished
    }

    /// Feed the completion of the awaited batch job back in (collecting
    /// its outcome from the driver), then keep advancing like `poll`.
    /// Completions for a job the cursor is not waiting on are ignored.
    pub fn complete(&mut self, jobid: u64, exec: &mut dyn StepDriver) -> CursorPoll {
        match self.awaiting.take() {
            Some(w) if w.jobid == jobid => {
                let out = exec.collect(jobid);
                self.apply(&w.resolved, out);
                self.step_idx += 1;
            }
            other => self.awaiting = other,
        }
        self.poll(exec)
    }

    /// Swap the awaited job id: the executor followed a scheduler
    /// requeue (preemption) or resubmitted after a node failure, so the
    /// step's completion now arrives under `new`. Returns `false` — and
    /// changes nothing — when the cursor is not waiting on `old`.
    pub fn retarget(&mut self, old: u64, new: u64) -> bool {
        match &mut self.awaiting {
            Some(w) if w.jobid == old => {
                w.jobid = new;
                true
            }
            _ => false,
        }
    }

    fn apply(&mut self, step: &ResolvedStep, out: StepOutcome) {
        self.acc.step_status.push((step.name.clone(), out.success));
        self.acc.success &= out.success;
        if step.remote {
            self.acc.runtime_s = out.runtime_s;
            self.acc.jobid = out.jobid;
            self.acc.queue = out.queue.clone();
            self.acc.nodes = out.nodes;
            self.acc.tasks_per_node = out.tasks_per_node;
            self.acc.threads_per_task = out.threads_per_task;
        }
        self.acc.files.extend(out.files.iter().cloned());
        for (k, v) in out.metrics.as_obj().unwrap_or(&[]) {
            self.acc.metrics.insert(k, v.clone());
        }
    }

    fn finish_point(&mut self) {
        let acc = std::mem::replace(&mut self.acc, PointAccum::new());
        let mut metrics = acc.metrics;
        // Analysis: regex extraction over output files (paper §II-B).
        for pat in &self.analysis {
            if let Some(v) = apply_pattern(pat, &acc.files) {
                metrics.insert(&pat.name, v);
            }
        }
        // Parameters are recorded into metrics-adjacent storage by the
        // coordinator (protocol `parameter` section), not here.
        self.outcomes.push(RunOutcome {
            point: self.points[self.point_idx].clone(),
            success: acc.success,
            runtime_s: acc.runtime_s,
            metrics,
            jobid: acc.jobid,
            queue: acc.queue,
            nodes: acc.nodes,
            tasks_per_node: acc.tasks_per_node,
            threads_per_task: acc.threads_per_task,
            step_status: acc.step_status,
        });
        self.point_idx += 1;
        self.step_idx = 0;
    }

    /// Completed outcomes; call once `poll` returned `Finished`.
    pub fn into_outcomes(self) -> Vec<RunOutcome> {
        self.outcomes
    }
}

fn apply_pattern(pat: &AnalysisPattern, files: &[(String, String)]) -> Option<Json> {
    let re = crate::util::rex::Rex::new(&pat.regex).ok()?;
    let content = files
        .iter()
        .find(|(name, _)| name == &pat.file)
        .map(|(_, c)| c)?;
    // JUBE semantics: last match wins (repeated prints converge).
    let captures = re.captures_last(content)?;
    let text = captures
        .get(1)
        .unwrap_or_else(|| captures.get(0).expect("whole match always present"));
    match pat.dtype.as_str() {
        "float" => text.parse::<f64>().ok().map(Json::Num),
        "int" => text.parse::<i64>().ok().map(|v| Json::Num(v as f64)),
        _ => Some(Json::Str(text.to_string())),
    }
}

/// A scripted executor for tests: maps step name -> outcome factory.
pub struct ScriptedExecutor {
    pub outcomes: BTreeMap<String, Box<dyn Fn(&ResolvedStep) -> StepOutcome>>,
    pub log: Vec<(String, Vec<String>)>,
}

impl ScriptedExecutor {
    pub fn new() -> ScriptedExecutor {
        ScriptedExecutor {
            outcomes: BTreeMap::new(),
            log: Vec::new(),
        }
    }

    pub fn on(
        mut self,
        step: &str,
        f: impl Fn(&ResolvedStep) -> StepOutcome + 'static,
    ) -> ScriptedExecutor {
        self.outcomes.insert(step.to_string(), Box::new(f));
        self
    }
}

impl Default for ScriptedExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl StepExecutor for ScriptedExecutor {
    fn execute(&mut self, step: &ResolvedStep) -> StepOutcome {
        self.log
            .push((step.name.clone(), step.commands.clone()));
        match self.outcomes.get(&step.name) {
            Some(f) => f(step),
            None => StepOutcome::local_ok(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::{BenchmarkSpec, LOGMAP_SPEC};
    use super::*;

    fn exec_with_output() -> ScriptedExecutor {
        ScriptedExecutor::new().on("execute", |step| {
            let workload: f64 = step.point["workload"].parse().unwrap();
            StepOutcome {
                success: true,
                runtime_s: workload * 2.0,
                files: vec![(
                    "logmap.out".into(),
                    format!("result ok\ntime: {}\n", workload * 2.0),
                )],
                metrics: Json::obj().set("gflops", 10.0),
                jobid: 42,
                queue: "all".into(),
                nodes: 1,
                tasks_per_node: 4,
                threads_per_task: 8,
                ..StepOutcome::local_ok()
            }
        })
    }

    #[test]
    fn runs_all_points_and_analyses() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
        assert_eq!(outs.len(), 2); // workload {4, 6}
        for o in &outs {
            assert!(o.success);
            let w: f64 = o.point["workload"].parse().unwrap();
            assert_eq!(o.metrics.f64_of("runtime"), Some(w * 2.0));
            assert_eq!(o.metrics.f64_of("gflops"), Some(10.0));
            assert_eq!(o.jobid, 42);
        }
        // compile ran once per point, before execute
        let names: Vec<&str> = exec.log.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["compile", "execute", "compile", "execute"]);
    }

    #[test]
    fn substitution_reaches_commands() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        run_benchmark(&spec, &[], &mut exec).unwrap();
        let exec_cmds: Vec<&str> = exec
            .log
            .iter()
            .filter(|(n, _)| n == "execute")
            .map(|(_, c)| c[0].as_str())
            .collect();
        assert!(exec_cmds.contains(&"logmap --workload 4 --intensity 2.4"));
        assert!(exec_cmds.contains(&"logmap --workload 6 --intensity 2.4"));
    }

    #[test]
    fn failure_skips_downstream_steps() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec =
            ScriptedExecutor::new().on("compile", |_| StepOutcome::failed("compiler error"));
        let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
        assert!(outs.iter().all(|o| !o.success));
        // execute never ran
        assert!(exec.log.iter().all(|(n, _)| n != "execute"));
        // step status records the skip
        assert_eq!(outs[0].step_status.len(), 2);
        assert!(!outs[0].step_status[1].1);
    }

    #[test]
    fn tags_expand_scaling_axis() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        let outs = run_benchmark(&spec, &["scaling".to_string()], &mut exec).unwrap();
        assert_eq!(outs.len(), 4);
    }

    #[test]
    fn data_entry_conversion() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
        let e = outs[0].to_data_entry();
        assert!(e.success);
        assert_eq!(e.jobid, 42);
        assert_eq!(e.taskspernode, 4);
        assert!(e.metric("runtime").is_some());
    }

    #[test]
    fn analysis_last_match_wins() {
        let pat = AnalysisPattern {
            name: "t".into(),
            file: "f".into(),
            regex: "t=([0-9]+)".into(),
            dtype: "int".into(),
        };
        let files = vec![("f".to_string(), "t=1\nt=2\nt=3".to_string())];
        assert_eq!(apply_pattern(&pat, &files), Some(Json::Num(3.0)));
    }

    #[test]
    fn step_outcome_document_roundtrip() {
        let out = StepOutcome {
            success: true,
            runtime_s: 12.345678,
            files: vec![
                ("logmap.out".into(), "time: 12.345678\n".into()),
                ("logmap.stats".into(), "kernel_time: 9.5\n".into()),
            ],
            metrics: Json::obj().set("gflops", 10.25).set("launcher", "srun"),
            jobid: 7_700_042,
            queue: "all".into(),
            nodes: 4,
            tasks_per_node: 4,
            threads_per_task: 8,
        };
        let doc = out.to_document();
        let back = StepOutcome::from_document(&doc).unwrap();
        assert_eq!(back.success, out.success);
        assert_eq!(back.runtime_s, out.runtime_s);
        assert_eq!(back.files, out.files);
        assert_eq!(back.metrics, out.metrics);
        assert_eq!(back.jobid, out.jobid);
        assert_eq!(back.queue, out.queue);
        assert_eq!(
            (back.nodes, back.tasks_per_node, back.threads_per_task),
            (4, 4, 8)
        );
        // byte-stable re-serialization (replay determinism)
        assert_eq!(back.to_document(), doc);
    }

    #[test]
    fn bad_outcome_documents_rejected() {
        assert!(StepOutcome::from_document("{not json").is_none());
        assert!(StepOutcome::from_document("{}").is_none());
        assert!(StepOutcome::from_document("{\"success\":true}").is_none());
    }

    /// Test driver that *yields* on every remote step, like the batch
    /// executor does under the coordinator event loop.
    struct YieldingDriver {
        inner: ScriptedExecutor,
        next_jobid: u64,
        parked: Option<(u64, StepOutcome)>,
        submissions: usize,
    }

    impl YieldingDriver {
        fn new(inner: ScriptedExecutor) -> YieldingDriver {
            YieldingDriver {
                inner,
                next_jobid: 500,
                parked: None,
                submissions: 0,
            }
        }
    }

    impl StepDriver for YieldingDriver {
        fn dispatch(&mut self, step: &ResolvedStep) -> StepDispatch {
            let out = self.inner.execute(step);
            if step.remote {
                let jobid = self.next_jobid;
                self.next_jobid += 1;
                self.submissions += 1;
                self.parked = Some((jobid, out));
                StepDispatch::Submitted(jobid)
            } else {
                StepDispatch::Done(out)
            }
        }

        fn collect(&mut self, jobid: u64) -> StepOutcome {
            let (id, out) = self.parked.take().expect("a step is parked");
            assert_eq!(id, jobid);
            out
        }
    }

    #[test]
    fn cursor_yields_per_remote_step_and_matches_blocking_run() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let blocking = {
            let mut exec = exec_with_output();
            run_benchmark(&spec, &["scaling".to_string()], &mut exec).unwrap()
        };

        let mut driver = YieldingDriver::new(exec_with_output());
        let mut cursor = RunCursor::new(&spec, &["scaling".to_string()]).unwrap();
        let mut waits = 0;
        let mut poll = cursor.poll(&mut driver);
        while let CursorPoll::Waiting { jobid } = poll {
            waits += 1;
            // re-polling while waiting is idempotent
            assert_eq!(cursor.poll(&mut driver), CursorPoll::Waiting { jobid });
            poll = cursor.complete(jobid, &mut driver);
        }
        assert!(cursor.is_finished());
        let resumed = cursor.into_outcomes();

        // one yield per remote step = one per expanded point here
        assert_eq!(waits, 4);
        assert_eq!(driver.submissions, 4);
        assert_eq!(resumed.len(), blocking.len());
        for (a, b) in resumed.iter().zip(&blocking) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.success, b.success);
            assert_eq!(a.runtime_s, b.runtime_s);
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.step_status, b.step_status);
        }
    }

    #[test]
    fn cursor_ignores_foreign_completions() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut driver = YieldingDriver::new(exec_with_output());
        let mut cursor = RunCursor::new(&spec, &[]).unwrap();
        let CursorPoll::Waiting { jobid } = cursor.poll(&mut driver) else {
            panic!("expected a remote submission");
        };
        // a completion for some other pipeline's job must not advance us
        assert_eq!(
            cursor.complete(jobid + 999, &mut driver),
            CursorPoll::Waiting { jobid }
        );
        let mut poll = cursor.complete(jobid, &mut driver);
        while let CursorPoll::Waiting { jobid } = poll {
            poll = cursor.complete(jobid, &mut driver);
        }
        assert_eq!(poll, CursorPoll::Finished);
        let outs = cursor.into_outcomes();
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().all(|o| o.success));
    }

    #[test]
    fn cursor_retargets_awaited_job_after_requeue() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut driver = YieldingDriver::new(exec_with_output());
        let mut cursor = RunCursor::new(&spec, &[]).unwrap();
        let CursorPoll::Waiting { jobid } = cursor.poll(&mut driver) else {
            panic!("expected a remote submission");
        };
        // retargeting a jobid we are not waiting on is a no-op
        assert!(!cursor.retarget(jobid + 1, jobid + 2));
        assert_eq!(cursor.poll(&mut driver), CursorPoll::Waiting { jobid });
        // the executor followed a requeue: the step completes under twin
        let twin = jobid + 100;
        assert!(cursor.retarget(jobid, twin));
        assert_eq!(cursor.poll(&mut driver), CursorPoll::Waiting { jobid: twin });
        // the old id is now foreign and must not advance the cursor
        assert_eq!(
            cursor.complete(jobid, &mut driver),
            CursorPoll::Waiting { jobid: twin }
        );
        let (_, parked) = driver.parked.take().expect("step still parked");
        driver.parked = Some((twin, parked));
        let mut poll = cursor.complete(twin, &mut driver);
        while let CursorPoll::Waiting { jobid } = poll {
            poll = cursor.complete(jobid, &mut driver);
        }
        assert_eq!(poll, CursorPoll::Finished);
        assert!(cursor.into_outcomes().iter().all(|o| o.success));
    }

    #[test]
    fn cursor_skips_downstream_after_failed_remote_wait() {
        let spec = BenchmarkSpec::parse(
            "name: x\nsteps:\n  - name: a\n    remote: true\n    do: [app]\n  - name: b\n    depends: [a]\n    do: [post]\n",
        )
        .unwrap();
        let scripted =
            ScriptedExecutor::new().on("a", |_| StepOutcome::failed("boom"));
        let mut driver = YieldingDriver::new(scripted);
        let mut cursor = RunCursor::new(&spec, &[]).unwrap();
        let CursorPoll::Waiting { jobid } = cursor.poll(&mut driver) else {
            panic!("remote step must submit");
        };
        assert_eq!(cursor.complete(jobid, &mut driver), CursorPoll::Finished);
        let outs = cursor.into_outcomes();
        assert!(!outs[0].success);
        // step b was skipped, recorded as failed
        assert_eq!(outs[0].step_status, vec![("a".to_string(), false), ("b".to_string(), false)]);
    }

    #[test]
    fn analysis_missing_file_is_none() {
        let pat = AnalysisPattern {
            name: "t".into(),
            file: "missing".into(),
            regex: "x".into(),
            dtype: "string".into(),
        };
        assert_eq!(apply_pattern(&pat, &[]), None);
    }
}
