//! Harness execution engine: step DAG × parameter space → analysed runs.
//!
//! For each point of the expanded parameter space, steps run in
//! dependency order through a [`StepExecutor`] (the bridge to either the
//! login node or the batch system — supplied by the caller, typically
//! `coordinator::execution`). After the last step, analysis patterns are
//! applied to the produced output files and one [`RunOutcome`] per point
//! is returned; `results_table` renders them per Table I.

use std::collections::BTreeMap;

use super::expand::{expand_for_step, substitute, ParamPoint};
use super::spec::{AnalysisPattern, BenchmarkSpec, SpecError, Step};
use crate::protocol::DataEntry;
use crate::util::json::Json;

/// A step with parameters substituted into its command lines.
#[derive(Debug, Clone)]
pub struct ResolvedStep {
    pub name: String,
    pub commands: Vec<String>,
    pub remote: bool,
    pub point: ParamPoint,
}

/// What executing one step produced.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub success: bool,
    /// Application runtime [s] (remote steps: the batch job runtime).
    pub runtime_s: f64,
    /// Output files (name -> contents) available to analysis.
    pub files: Vec<(String, String)>,
    /// Structured metrics contributed directly by the executor.
    pub metrics: Json,
    /// Scheduler metadata for remote steps.
    pub jobid: u64,
    pub queue: String,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
}

impl StepOutcome {
    pub fn local_ok() -> StepOutcome {
        StepOutcome {
            success: true,
            runtime_s: 0.0,
            files: Vec::new(),
            metrics: Json::obj(),
            jobid: 0,
            queue: String::new(),
            nodes: 1,
            tasks_per_node: 1,
            threads_per_task: 1,
        }
    }

    pub fn failed(msg: &str) -> StepOutcome {
        StepOutcome {
            success: false,
            metrics: Json::obj().set("error", msg),
            ..StepOutcome::local_ok()
        }
    }

    /// Serialize for the execution cache. A replay must reconstruct the
    /// outcome exactly: output files feed analysis patterns, metrics are
    /// merged into the protocol report verbatim.
    pub fn to_document(&self) -> String {
        let mut files = Json::arr();
        for (name, content) in &self.files {
            files.push(
                Json::obj()
                    .set("name", name.as_str())
                    .set("content", content.as_str()),
            );
        }
        Json::obj()
            .set("success", self.success)
            .set("runtime_s", self.runtime_s)
            .set("files", files)
            .set("metrics", self.metrics.clone())
            .set("jobid", self.jobid)
            .set("queue", self.queue.as_str())
            .set("nodes", self.nodes)
            .set("taskspernode", self.tasks_per_node)
            .set("threadspertask", self.threads_per_task)
            .pretty()
    }

    /// Parse a cached outcome document; `None` on any shape mismatch
    /// (the caller then falls back to executing the step).
    pub fn from_document(doc: &str) -> Option<StepOutcome> {
        let v = Json::parse(doc).ok()?;
        let mut files = Vec::new();
        for f in v.get("files").and_then(Json::as_arr).unwrap_or(&[]) {
            files.push((
                f.str_of("name")?.to_string(),
                f.str_of("content")?.to_string(),
            ));
        }
        Some(StepOutcome {
            success: v.bool_of("success")?,
            runtime_s: v.f64_of("runtime_s")?,
            files,
            metrics: v.get("metrics").cloned().unwrap_or_else(Json::obj),
            jobid: v.u64_of("jobid")?,
            queue: v.str_of("queue")?.to_string(),
            nodes: v.u64_of("nodes")?,
            tasks_per_node: v.u64_of("taskspernode")?,
            threads_per_task: v.u64_of("threadspertask")?,
        })
    }
}

/// The execution back end: interprets a resolved step's commands.
pub trait StepExecutor {
    fn execute(&mut self, step: &ResolvedStep) -> StepOutcome;
}

/// One fully-executed parameter point.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub point: ParamPoint,
    pub success: bool,
    pub runtime_s: f64,
    /// Metrics: executor metrics merged with analysis extractions.
    pub metrics: Json,
    pub jobid: u64,
    pub queue: String,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
    /// Per-step success for diagnostics.
    pub step_status: Vec<(String, bool)>,
}

impl RunOutcome {
    /// Convert to a protocol data entry (§V-B (e)).
    pub fn to_data_entry(&self) -> DataEntry {
        DataEntry {
            success: self.success,
            runtime: self.runtime_s,
            nodes: self.nodes,
            taskspernode: self.tasks_per_node,
            threadspertask: self.threads_per_task,
            jobid: self.jobid,
            queue: self.queue.clone(),
            metrics: self.metrics.clone(),
        }
    }
}

/// Run the whole benchmark: expand, execute, analyse.
pub fn run_benchmark(
    spec: &BenchmarkSpec,
    tags: &[String],
    executor: &mut dyn StepExecutor,
) -> Result<Vec<RunOutcome>, SpecError> {
    let order = spec.step_order()?;
    // The parameter space of the run is the union of axes used by any
    // step; expansion per final (leaf) step keeps per-point execution
    // simple: we expand over the *last* step's space, and earlier steps
    // see the subset of parameters they use.
    let leaf = order.last().expect("validated non-empty");
    let points = expand_for_step(spec, &leaf.name, tags);

    let mut outcomes = Vec::with_capacity(points.len());
    for point in points {
        outcomes.push(run_point(spec, &order, &point, tags, executor));
    }
    Ok(outcomes)
}

fn active_step(step: &Step, tags: &[String]) -> bool {
    match &step.tag {
        None => true,
        Some(t) => tags.iter().any(|x| x == t),
    }
}

fn run_point(
    spec: &BenchmarkSpec,
    order: &[&Step],
    point: &ParamPoint,
    tags: &[String],
    executor: &mut dyn StepExecutor,
) -> RunOutcome {
    let mut files: Vec<(String, String)> = Vec::new();
    let mut metrics = Json::obj();
    let mut step_status = Vec::new();
    let mut success = true;
    let mut runtime_s = 0.0;
    let mut jobid = 0;
    let mut queue = String::new();
    let mut nodes = 1;
    let mut tasks_per_node = 1;
    let mut threads_per_task = 1;

    for step in order {
        if !active_step(step, tags) {
            continue;
        }
        if !success {
            step_status.push((step.name.clone(), false));
            continue; // downstream steps are skipped after a failure
        }
        let resolved = ResolvedStep {
            name: step.name.clone(),
            commands: step
                .commands
                .iter()
                .map(|c| substitute(c, point))
                .collect(),
            remote: step.remote,
            point: point.clone(),
        };
        let out = executor.execute(&resolved);
        step_status.push((step.name.clone(), out.success));
        success &= out.success;
        if step.remote {
            runtime_s = out.runtime_s;
            jobid = out.jobid;
            queue = out.queue.clone();
            nodes = out.nodes;
            tasks_per_node = out.tasks_per_node;
            threads_per_task = out.threads_per_task;
        }
        files.extend(out.files.iter().cloned());
        for (k, v) in out.metrics.as_obj().unwrap_or(&[]) {
            metrics.insert(k, v.clone());
        }
    }

    // Analysis: regex extraction over output files (paper §II-B).
    for pat in &spec.analysis {
        if let Some(v) = apply_pattern(pat, &files) {
            metrics.insert(&pat.name, v);
        }
    }
    // Parameters are recorded into metrics-adjacent storage by the
    // coordinator (protocol `parameter` section), not here.

    RunOutcome {
        point: point.clone(),
        success,
        runtime_s,
        metrics,
        jobid,
        queue,
        nodes,
        tasks_per_node,
        threads_per_task,
        step_status,
    }
}

fn apply_pattern(pat: &AnalysisPattern, files: &[(String, String)]) -> Option<Json> {
    let re = crate::util::rex::Rex::new(&pat.regex).ok()?;
    let content = files
        .iter()
        .find(|(name, _)| name == &pat.file)
        .map(|(_, c)| c)?;
    // JUBE semantics: last match wins (repeated prints converge).
    let captures = re.captures_last(content)?;
    let text = captures
        .get(1)
        .unwrap_or_else(|| captures.get(0).expect("whole match always present"));
    match pat.dtype.as_str() {
        "float" => text.parse::<f64>().ok().map(Json::Num),
        "int" => text.parse::<i64>().ok().map(|v| Json::Num(v as f64)),
        _ => Some(Json::Str(text.to_string())),
    }
}

/// A scripted executor for tests: maps step name -> outcome factory.
pub struct ScriptedExecutor {
    pub outcomes: BTreeMap<String, Box<dyn Fn(&ResolvedStep) -> StepOutcome>>,
    pub log: Vec<(String, Vec<String>)>,
}

impl ScriptedExecutor {
    pub fn new() -> ScriptedExecutor {
        ScriptedExecutor {
            outcomes: BTreeMap::new(),
            log: Vec::new(),
        }
    }

    pub fn on(
        mut self,
        step: &str,
        f: impl Fn(&ResolvedStep) -> StepOutcome + 'static,
    ) -> ScriptedExecutor {
        self.outcomes.insert(step.to_string(), Box::new(f));
        self
    }
}

impl Default for ScriptedExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl StepExecutor for ScriptedExecutor {
    fn execute(&mut self, step: &ResolvedStep) -> StepOutcome {
        self.log
            .push((step.name.clone(), step.commands.clone()));
        match self.outcomes.get(&step.name) {
            Some(f) => f(step),
            None => StepOutcome::local_ok(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::{BenchmarkSpec, LOGMAP_SPEC};
    use super::*;

    fn exec_with_output() -> ScriptedExecutor {
        ScriptedExecutor::new().on("execute", |step| {
            let workload: f64 = step.point["workload"].parse().unwrap();
            StepOutcome {
                success: true,
                runtime_s: workload * 2.0,
                files: vec![(
                    "logmap.out".into(),
                    format!("result ok\ntime: {}\n", workload * 2.0),
                )],
                metrics: Json::obj().set("gflops", 10.0),
                jobid: 42,
                queue: "all".into(),
                nodes: 1,
                tasks_per_node: 4,
                threads_per_task: 8,
                ..StepOutcome::local_ok()
            }
        })
    }

    #[test]
    fn runs_all_points_and_analyses() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
        assert_eq!(outs.len(), 2); // workload {4, 6}
        for o in &outs {
            assert!(o.success);
            let w: f64 = o.point["workload"].parse().unwrap();
            assert_eq!(o.metrics.f64_of("runtime"), Some(w * 2.0));
            assert_eq!(o.metrics.f64_of("gflops"), Some(10.0));
            assert_eq!(o.jobid, 42);
        }
        // compile ran once per point, before execute
        let names: Vec<&str> = exec.log.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["compile", "execute", "compile", "execute"]);
    }

    #[test]
    fn substitution_reaches_commands() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        run_benchmark(&spec, &[], &mut exec).unwrap();
        let exec_cmds: Vec<&str> = exec
            .log
            .iter()
            .filter(|(n, _)| n == "execute")
            .map(|(_, c)| c[0].as_str())
            .collect();
        assert!(exec_cmds.contains(&"logmap --workload 4 --intensity 2.4"));
        assert!(exec_cmds.contains(&"logmap --workload 6 --intensity 2.4"));
    }

    #[test]
    fn failure_skips_downstream_steps() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec =
            ScriptedExecutor::new().on("compile", |_| StepOutcome::failed("compiler error"));
        let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
        assert!(outs.iter().all(|o| !o.success));
        // execute never ran
        assert!(exec.log.iter().all(|(n, _)| n != "execute"));
        // step status records the skip
        assert_eq!(outs[0].step_status.len(), 2);
        assert!(!outs[0].step_status[1].1);
    }

    #[test]
    fn tags_expand_scaling_axis() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        let outs = run_benchmark(&spec, &["scaling".to_string()], &mut exec).unwrap();
        assert_eq!(outs.len(), 4);
    }

    #[test]
    fn data_entry_conversion() {
        let spec = BenchmarkSpec::parse(LOGMAP_SPEC).unwrap();
        let mut exec = exec_with_output();
        let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
        let e = outs[0].to_data_entry();
        assert!(e.success);
        assert_eq!(e.jobid, 42);
        assert_eq!(e.taskspernode, 4);
        assert!(e.metric("runtime").is_some());
    }

    #[test]
    fn analysis_last_match_wins() {
        let pat = AnalysisPattern {
            name: "t".into(),
            file: "f".into(),
            regex: "t=([0-9]+)".into(),
            dtype: "int".into(),
        };
        let files = vec![("f".to_string(), "t=1\nt=2\nt=3".to_string())];
        assert_eq!(apply_pattern(&pat, &files), Some(Json::Num(3.0)));
    }

    #[test]
    fn step_outcome_document_roundtrip() {
        let out = StepOutcome {
            success: true,
            runtime_s: 12.345678,
            files: vec![
                ("logmap.out".into(), "time: 12.345678\n".into()),
                ("logmap.stats".into(), "kernel_time: 9.5\n".into()),
            ],
            metrics: Json::obj().set("gflops", 10.25).set("launcher", "srun"),
            jobid: 7_700_042,
            queue: "all".into(),
            nodes: 4,
            tasks_per_node: 4,
            threads_per_task: 8,
        };
        let doc = out.to_document();
        let back = StepOutcome::from_document(&doc).unwrap();
        assert_eq!(back.success, out.success);
        assert_eq!(back.runtime_s, out.runtime_s);
        assert_eq!(back.files, out.files);
        assert_eq!(back.metrics, out.metrics);
        assert_eq!(back.jobid, out.jobid);
        assert_eq!(back.queue, out.queue);
        assert_eq!(
            (back.nodes, back.tasks_per_node, back.threads_per_task),
            (4, 4, 8)
        );
        // byte-stable re-serialization (replay determinism)
        assert_eq!(back.to_document(), doc);
    }

    #[test]
    fn bad_outcome_documents_rejected() {
        assert!(StepOutcome::from_document("{not json").is_none());
        assert!(StepOutcome::from_document("{}").is_none());
        assert!(StepOutcome::from_document("{\"success\":true}").is_none());
    }

    #[test]
    fn analysis_missing_file_is_none() {
        let pat = AnalysisPattern {
            name: "t".into(),
            file: "missing".into(),
            regex: "x".into(),
            dtype: "string".into(),
        };
        assert_eq!(apply_pattern(&pat, &[]), None);
    }
}
