//! The JUBE-like benchmark harness (paper §II-B, §IV-D; DESIGN.md §2).
//!
//! exaCB "delegates execution to an external benchmarking harness that
//! conforms to the protocol"; this module is that harness: benchmark
//! scripts ([`spec`]), parameter-space expansion with tags ([`expand`]),
//! and the step-DAG execution + output-analysis engine ([`run`]).
//!
//! The harness is deliberately independent of the CI layer and the batch
//! system: execution goes through the [`run::StepExecutor`] trait, so the
//! same benchmark definition runs under a scripted test executor, the
//! login-node executor, or the batch-submitting executor provided by the
//! coordinator (this is the protocol's "harness adapter" seam).

pub mod expand;
pub mod run;
pub mod spec;

pub use expand::{expand, expand_for_step, substitute, ParamPoint};
pub use run::{
    run_benchmark, CursorPoll, ResolvedStep, RunCursor, RunOutcome, ScriptedExecutor,
    StepDispatch, StepDriver, StepExecutor, StepOutcome,
};
pub use spec::{AnalysisPattern, BenchmarkSpec, Parameter, ParameterSet, SpecError, Step};
