//! Compute accounts and core-hour budgets.
//!
//! The CI execution component validates that "the compute account ... is
//! enabled so that subsequent jobs can access the relevant partition"
//! (paper §II-C). Accounts map to projects; each draws core-hours from a
//! named budget with a hard cap.

use std::collections::HashMap;

/// A compute project with partition access.
#[derive(Debug, Clone, PartialEq)]
pub struct Account {
    pub name: String,
    pub budget: String,
    pub enabled: bool,
    /// Partitions this account may submit to (empty = all).
    pub partitions: Vec<String>,
}

/// A core-hour budget shared by one or more accounts.
#[derive(Debug, Clone, PartialEq)]
pub struct Budget {
    pub name: String,
    pub granted_core_hours: f64,
    pub used_core_hours: f64,
}

impl Budget {
    pub fn remaining(&self) -> f64 {
        self.granted_core_hours - self.used_core_hours
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum AccountError {
    UnknownAccount(String),
    Disabled(String),
    NoPartitionAccess { account: String, partition: String },
    BudgetExhausted(String),
    WrongBudget { account: String, budget: String },
}

impl std::fmt::Display for AccountError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AccountError::UnknownAccount(a) => write!(f, "unknown account '{a}'"),
            AccountError::Disabled(a) => write!(f, "account '{a}' is disabled"),
            AccountError::NoPartitionAccess { account, partition } => {
                write!(f, "account '{account}' has no access to partition '{partition}'")
            }
            AccountError::BudgetExhausted(b) => write!(f, "budget '{b}' exhausted"),
            AccountError::WrongBudget { account, budget } => {
                write!(f, "account '{account}' does not draw from budget '{budget}'")
            }
        }
    }
}

impl std::error::Error for AccountError {}

/// Registry of accounts + budgets with usage accounting.
#[derive(Debug, Clone, Default)]
pub struct AccountManager {
    accounts: HashMap<String, Account>,
    budgets: HashMap<String, Budget>,
}

impl AccountManager {
    pub fn new() -> AccountManager {
        AccountManager::default()
    }

    /// A permissive manager with one open account (tests, quickstart).
    pub fn open(account: &str, budget: &str, core_hours: f64) -> AccountManager {
        let mut m = AccountManager::new();
        m.add_budget(budget, core_hours);
        m.add_account(Account {
            name: account.into(),
            budget: budget.into(),
            enabled: true,
            partitions: vec![],
        });
        m
    }

    pub fn add_account(&mut self, a: Account) {
        self.accounts.insert(a.name.clone(), a);
    }

    pub fn add_budget(&mut self, name: &str, core_hours: f64) {
        self.budgets.insert(
            name.to_string(),
            Budget {
                name: name.to_string(),
                granted_core_hours: core_hours,
                used_core_hours: 0.0,
            },
        );
    }

    pub fn set_enabled(&mut self, account: &str, enabled: bool) {
        if let Some(a) = self.accounts.get_mut(account) {
            a.enabled = enabled;
        }
    }

    pub fn account(&self, name: &str) -> Option<&Account> {
        self.accounts.get(name)
    }

    pub fn budget(&self, name: &str) -> Option<&Budget> {
        self.budgets.get(name)
    }

    /// Validate a submission (the Jacamar-runner account check).
    pub fn authorize(
        &self,
        account: &str,
        budget: &str,
        partition: &str,
    ) -> Result<(), AccountError> {
        let a = self
            .accounts
            .get(account)
            .ok_or_else(|| AccountError::UnknownAccount(account.to_string()))?;
        if !a.enabled {
            return Err(AccountError::Disabled(account.to_string()));
        }
        if a.budget != budget {
            return Err(AccountError::WrongBudget {
                account: account.to_string(),
                budget: budget.to_string(),
            });
        }
        if !a.partitions.is_empty() && !a.partitions.iter().any(|p| p == partition) {
            return Err(AccountError::NoPartitionAccess {
                account: account.to_string(),
                partition: partition.to_string(),
            });
        }
        let b = self
            .budgets
            .get(budget)
            .ok_or_else(|| AccountError::BudgetExhausted(budget.to_string()))?;
        if b.remaining() <= 0.0 {
            return Err(AccountError::BudgetExhausted(budget.to_string()));
        }
        Ok(())
    }

    /// Charge consumed core-hours to the account's budget.
    pub fn charge(&mut self, account: &str, core_hours: f64) {
        if let Some(budget) = self
            .accounts
            .get(account)
            .map(|a| a.budget.clone())
        {
            if let Some(b) = self.budgets.get_mut(&budget) {
                b.used_core_hours += core_hours;
            }
        }
    }

    /// Total core-hours used across all budgets.
    pub fn total_used(&self) -> f64 {
        self.budgets.values().map(|b| b.used_core_hours).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> AccountManager {
        let mut m = AccountManager::new();
        m.add_budget("exalab", 10_000.0);
        m.add_account(Account {
            name: "cexalab".into(),
            budget: "exalab".into(),
            enabled: true,
            partitions: vec!["dc-gpu".into()],
        });
        m
    }

    #[test]
    fn authorize_happy_path() {
        assert!(mgr().authorize("cexalab", "exalab", "dc-gpu").is_ok());
    }

    #[test]
    fn rejects_unknown_disabled_wrong() {
        let mut m = mgr();
        assert!(matches!(
            m.authorize("nobody", "exalab", "dc-gpu"),
            Err(AccountError::UnknownAccount(_))
        ));
        assert!(matches!(
            m.authorize("cexalab", "other", "dc-gpu"),
            Err(AccountError::WrongBudget { .. })
        ));
        assert!(matches!(
            m.authorize("cexalab", "exalab", "booster"),
            Err(AccountError::NoPartitionAccess { .. })
        ));
        m.set_enabled("cexalab", false);
        assert!(matches!(
            m.authorize("cexalab", "exalab", "dc-gpu"),
            Err(AccountError::Disabled(_))
        ));
    }

    #[test]
    fn budget_exhaustion() {
        let mut m = mgr();
        m.charge("cexalab", 10_000.0);
        assert!(matches!(
            m.authorize("cexalab", "exalab", "dc-gpu"),
            Err(AccountError::BudgetExhausted(_))
        ));
        assert_eq!(m.total_used(), 10_000.0);
    }

    #[test]
    fn open_manager_allows_everything() {
        let m = AccountManager::open("cjsc", "zam", 1e9);
        assert!(m.authorize("cjsc", "zam", "any-partition").is_ok());
    }
}
