//! The batch system: a discrete-event Slurm-like scheduler.
//!
//! Substitutes Slurm on the simulated machines (DESIGN.md §2). Jobs are
//! submitted against partitions with finite node counts; scheduling is
//! FIFO with simple backfill (a later job may start if it fits while the
//! queue head waits). The simulated clock advances only through job
//! completions — wall-clock of the *host* process is irrelevant, which
//! is what makes 90-day daily-pipeline studies (Figs. 3/4) tractable.

use std::collections::HashMap;

use super::accounts::{AccountError, AccountManager};
use super::job::{JobCtx, JobPayload, JobRecord, JobResult, JobSpec, JobState};
use crate::util::timeutil::SimTime;

#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    Account(AccountError),
    UnknownPartition(String),
    TooLarge {
        requested: u64,
        partition: String,
        total: u64,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Account(e) => write!(f, "account rejected: {e}"),
            SubmitError::UnknownPartition(p) => write!(f, "unknown partition '{p}'"),
            SubmitError::TooLarge {
                requested,
                partition,
                total,
            } => write!(
                f,
                "job requests {requested} nodes but partition '{partition}' has {total}"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<AccountError> for SubmitError {
    fn from(e: AccountError) -> SubmitError {
        SubmitError::Account(e)
    }
}

struct PendingJob {
    jobid: u64,
    payload: JobPayload,
}

struct RunningJob {
    jobid: u64,
    end_time: SimTime,
}

struct PartitionState {
    total_nodes: u64,
    free_nodes: u64,
}

/// One machine's batch scheduler.
pub struct BatchSystem {
    pub machine: String,
    pub cores_per_node: u64,
    pub accounts: AccountManager,
    /// Fixed scheduler-cycle latency added before any job starts [s].
    pub sched_latency_s: i64,
    /// Job launch overhead added to application runtime [s].
    pub launch_overhead_s: f64,
    clock: SimTime,
    next_jobid: u64,
    partitions: HashMap<String, PartitionState>,
    pending: Vec<PendingJob>,
    running: Vec<RunningJob>,
    records: HashMap<u64, JobRecord>,
}

impl BatchSystem {
    pub fn new(machine: &str, cores_per_node: u64, accounts: AccountManager) -> BatchSystem {
        BatchSystem {
            machine: machine.to_string(),
            cores_per_node,
            accounts,
            sched_latency_s: 12,
            launch_overhead_s: 1.5,
            clock: SimTime(0),
            next_jobid: 7_700_000, // JSC-flavoured job ids
            partitions: HashMap::new(),
            pending: Vec::new(),
            running: Vec::new(),
            records: HashMap::new(),
        }
    }

    pub fn add_partition(&mut self, name: &str, nodes: u64) {
        self.partitions.insert(
            name.to_string(),
            PartitionState {
                total_nodes: nodes,
                free_nodes: nodes,
            },
        );
    }

    pub fn now(&self) -> SimTime {
        self.clock
    }

    /// Move the clock forward (e.g. to the next daily pipeline trigger).
    /// Panics if moving backwards.
    pub fn advance_clock_to(&mut self, t: SimTime) {
        // Finish any job that completes before t first.
        while let Some(next_end) = self.earliest_end() {
            if next_end > t {
                break;
            }
            self.complete_next();
        }
        assert!(t >= self.clock, "clock cannot move backwards");
        self.clock = t;
        self.try_schedule();
    }

    /// Submit a job; validation failures produce a `Rejected` record and
    /// return the error (the CI job sees both).
    pub fn submit(&mut self, spec: JobSpec, payload: JobPayload) -> Result<u64, SubmitError> {
        let jobid = self.next_jobid;
        self.next_jobid += 1;
        let mut record = JobRecord {
            jobid,
            spec: spec.clone(),
            state: JobState::Pending,
            submit_time: self.clock,
            start_time: None,
            end_time: None,
            result: None,
        };

        let validation = self.validate(&spec);
        if let Err(e) = validation {
            record.state = JobState::Rejected;
            record.result = Some(JobResult::failure(&e.to_string()));
            self.records.insert(jobid, record);
            return Err(e);
        }
        self.records.insert(jobid, record);
        self.pending.push(PendingJob { jobid, payload });
        self.try_schedule();
        Ok(jobid)
    }

    fn validate(&self, spec: &JobSpec) -> Result<(), SubmitError> {
        self.accounts
            .authorize(&spec.account, &spec.budget, &spec.partition)?;
        let part = self
            .partitions
            .get(&spec.partition)
            .ok_or_else(|| SubmitError::UnknownPartition(spec.partition.clone()))?;
        if spec.nodes > part.total_nodes {
            return Err(SubmitError::TooLarge {
                requested: spec.nodes,
                partition: spec.partition.clone(),
                total: part.total_nodes,
            });
        }
        Ok(())
    }

    /// FIFO + backfill: start every pending job that currently fits.
    fn try_schedule(&mut self) {
        let mut i = 0;
        while i < self.pending.len() {
            let jobid = self.pending[i].jobid;
            let spec = self.records[&jobid].spec.clone();
            let fits = self
                .partitions
                .get(&spec.partition)
                .map(|p| p.free_nodes >= spec.nodes)
                .unwrap_or(false);
            if fits {
                let PendingJob { payload, .. } = self.pending.remove(i);
                self.start_job(jobid, spec, payload);
                // restart the scan: records/partitions changed
                i = 0;
            } else {
                i += 1;
            }
        }
    }

    fn start_job(&mut self, jobid: u64, spec: JobSpec, payload: JobPayload) {
        let part = self.partitions.get_mut(&spec.partition).unwrap();
        part.free_nodes -= spec.nodes;
        let start = self.clock.add_secs(self.sched_latency_s);
        let ctx = JobCtx {
            jobid,
            start_time: start,
            nodes: spec.nodes,
            tasks_per_node: spec.tasks_per_node,
            threads_per_task: spec.threads_per_task,
            partition: spec.partition.clone(),
        };
        let result = payload(&ctx);
        let app_duration = result.duration_s + self.launch_overhead_s;
        let (state, duration) = if app_duration > spec.walltime_limit_s as f64 {
            (JobState::Timeout, spec.walltime_limit_s as f64)
        } else if result.success {
            (JobState::Completed, app_duration)
        } else {
            (JobState::Failed, app_duration)
        };
        let end = start.add_secs(duration.ceil() as i64);
        let rec = self.records.get_mut(&jobid).unwrap();
        rec.state = JobState::Running; // terminal state set at completion
        rec.start_time = Some(start);
        rec.end_time = Some(end);
        rec.result = Some(if state == JobState::Timeout {
            JobResult {
                success: false,
                ..result
            }
        } else {
            result
        });
        self.running.push(RunningJob { jobid, end_time: end });
        // stash terminal state in the record via a parallel map-free trick:
        // we re-derive it at completion from result.success + walltime.
        let _ = state;
    }

    fn earliest_end(&self) -> Option<SimTime> {
        self.running.iter().map(|r| r.end_time).min()
    }

    /// Complete the earliest-finishing running job; advances the clock.
    fn complete_next(&mut self) -> Option<u64> {
        let idx = self
            .running
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.end_time)
            .map(|(i, _)| i)?;
        let RunningJob { jobid, end_time } = self.running.remove(idx);
        self.clock = self.clock.max(end_time);
        let cores = self.cores_per_node;
        let rec = self.records.get_mut(&jobid).unwrap();
        let spec = rec.spec.clone();
        // derive terminal state
        let app_ok = rec.result.as_ref().map(|r| r.success).unwrap_or(false);
        let hit_wall = rec
            .result
            .as_ref()
            .map(|r| r.duration_s + self.launch_overhead_s > spec.walltime_limit_s as f64)
            .unwrap_or(false);
        rec.state = if hit_wall {
            JobState::Timeout
        } else if app_ok {
            JobState::Completed
        } else {
            JobState::Failed
        };
        let ch = rec.core_hours(cores);
        self.accounts.charge(&spec.account, ch);
        if let Some(p) = self.partitions.get_mut(&spec.partition) {
            p.free_nodes += spec.nodes;
        }
        self.try_schedule();
        Some(jobid)
    }

    /// Run the event loop until no job is pending or running.
    pub fn run_until_idle(&mut self) {
        loop {
            self.try_schedule();
            if self.complete_next().is_none() {
                break;
            }
        }
        debug_assert!(self.running.is_empty());
    }

    // ---- discrete-event interleaving API (coordinator event loop) -----
    //
    // A coordinator interleaving many pipelines across many machines
    // drives each machine one completion event at a time instead of
    // draining it: peek at the next event time, pick the globally
    // earliest machine, advance it by exactly one event, and wake the
    // pipeline that was waiting on the completed job.

    /// Simulated time of this machine's next completion event, if any
    /// job is running. Pending jobs never stall silently: a submission
    /// that fits starts immediately (`try_schedule` runs on submit and
    /// on every completion), so `None` means the machine is idle.
    pub fn peek_next_event(&self) -> Option<SimTime> {
        self.earliest_end()
    }

    /// Complete the single earliest-finishing running job, advancing
    /// this machine's clock to its end time, charging accounting, and
    /// starting any pending jobs that now fit. Returns the completed
    /// job id, or `None` when the machine is idle.
    pub fn advance_next_event(&mut self) -> Option<u64> {
        self.complete_next()
    }

    /// Current lifecycle state of a job (`None` for unknown ids).
    pub fn job_state(&self, jobid: u64) -> Option<JobState> {
        self.records.get(&jobid).map(|r| r.state)
    }

    pub fn record(&self, jobid: u64) -> Option<&JobRecord> {
        self.records.get(&jobid)
    }

    /// All records, sorted by job id (the `sacct` dump).
    pub fn records(&self) -> Vec<&JobRecord> {
        let mut v: Vec<&JobRecord> = self.records.values().collect();
        v.sort_by_key(|r| r.jobid);
        v
    }

    pub fn free_nodes(&self, partition: &str) -> Option<u64> {
        self.partitions.get(partition).map(|p| p.free_nodes)
    }

    pub fn total_nodes(&self, partition: &str) -> Option<u64> {
        self.partitions.get(partition).map(|p| p.total_nodes)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }
}

/// Build a batch system for a simulated machine with its standard queues.
pub fn for_machine(m: &crate::cluster::Machine, accounts: AccountManager) -> BatchSystem {
    let mut bs = BatchSystem::new(&m.name, m.cores_per_node, accounts);
    for q in &m.queues {
        // devel queues get a small slice, production queues the full system
        let nodes = if q.contains("devel") {
            (m.nodes / 12).max(2)
        } else {
            m.nodes
        };
        bs.add_partition(q, nodes);
    }
    bs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn quick_payload(secs: f64, ok: bool) -> JobPayload {
        Box::new(move |_ctx| JobResult {
            duration_s: secs,
            success: ok,
            metrics: Json::obj(),
            files: vec![],
        })
    }

    fn sys() -> BatchSystem {
        let mut bs = BatchSystem::new("jedi", 288, AccountManager::open("p", "b", 1e9));
        bs.add_partition("all", 8);
        bs
    }

    #[test]
    fn job_lifecycle_completed() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    partition: "all".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Completed);
        assert!(rec.queue_wait_s().unwrap() >= 0);
        let dur = rec.end_time.unwrap().0 - rec.start_time.unwrap().0;
        assert!((100..=105).contains(&dur), "dur={dur}");
    }

    #[test]
    fn failed_payload_marks_failed() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(10.0, false),
            )
            .unwrap();
        bs.run_until_idle();
        assert_eq!(bs.record(id).unwrap().state, JobState::Failed);
    }

    #[test]
    fn walltime_enforced() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    walltime_limit_s: 60,
                    ..Default::default()
                },
                quick_payload(3600.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Timeout);
        assert!(!rec.result.as_ref().unwrap().success);
        assert_eq!(rec.end_time.unwrap().0 - rec.start_time.unwrap().0, 60);
    }

    #[test]
    fn contention_queues_jobs() {
        let mut bs = sys(); // 8 nodes
        let a = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1000.0, true),
            )
            .unwrap();
        let b = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1000.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        let ra = bs.record(a).unwrap();
        let rb = bs.record(b).unwrap();
        // b cannot start before a finishes
        assert!(rb.start_time.unwrap() >= ra.end_time.unwrap());
    }

    #[test]
    fn backfill_lets_small_jobs_through() {
        let mut bs = sys(); // 8 nodes
        let _big = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1000.0, true),
            )
            .unwrap();
        let blocked = bs
            .submit(
                JobSpec {
                    nodes: 8,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(10.0, true),
            )
            .unwrap();
        let small = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(10.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        // the 2-node job backfills ahead of the blocked 8-node job
        let s = bs.record(small).unwrap().start_time.unwrap();
        let blk = bs.record(blocked).unwrap().start_time.unwrap();
        assert!(s < blk, "small={:?} blocked={:?}", s, blk);
    }

    #[test]
    fn rejection_paths() {
        let mut bs = sys();
        let err = bs
            .submit(
                JobSpec {
                    nodes: 99,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1.0, true),
            )
            .unwrap_err();
        assert!(matches!(err, SubmitError::TooLarge { .. }));
        let err = bs
            .submit(
                JobSpec {
                    partition: "nope".into(),
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1.0, true),
            )
            .unwrap_err();
        assert!(matches!(err, SubmitError::UnknownPartition(_)));
        // rejected jobs leave a record
        assert_eq!(
            bs.records()
                .iter()
                .filter(|r| r.state == JobState::Rejected)
                .count(),
            2
        );
    }

    #[test]
    fn accounting_charges_core_hours() {
        let mut bs = sys();
        bs.submit(
            JobSpec {
                nodes: 4,
                account: "p".into(),
                budget: "b".into(),
                ..Default::default()
            },
            quick_payload(3600.0, true),
        )
        .unwrap();
        bs.run_until_idle();
        // ~1h on 4x288 cores ≈ 1152 core-hours (+ overheads)
        let used = bs.accounts.total_used();
        assert!(used > 1100.0 && used < 1200.0, "used={used}");
    }

    #[test]
    fn clock_advances_through_days() {
        let mut bs = sys();
        bs.advance_clock_to(SimTime::from_days(3));
        assert_eq!(bs.now().day(), 3);
        bs.submit(
            JobSpec {
                account: "p".into(),
                budget: "b".into(),
                ..Default::default()
            },
            quick_payload(50.0, true),
        )
        .unwrap();
        bs.run_until_idle();
        assert!(bs.now() > SimTime::from_days(3));
        assert!(bs.now() < SimTime::from_days(3).add_secs(600));
    }

    #[test]
    fn peek_and_advance_interleave_events() {
        let mut bs = sys(); // 8 nodes
        assert_eq!(bs.peek_next_event(), None);
        let short = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        let long = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(500.0, true),
            )
            .unwrap();
        // both are running; neither is terminal yet
        assert_eq!(bs.running_count(), 2);
        assert!(!bs.job_state(short).unwrap().is_terminal());
        let first_end = bs.peek_next_event().unwrap();
        // one event at a time, earliest first, clock tracking each end
        assert_eq!(bs.advance_next_event(), Some(short));
        assert_eq!(bs.now(), first_end);
        assert!(bs.job_state(short).unwrap().is_terminal());
        assert!(!bs.job_state(long).unwrap().is_terminal());
        assert_eq!(bs.advance_next_event(), Some(long));
        assert_eq!(bs.advance_next_event(), None);
        assert_eq!(bs.peek_next_event(), None);
        assert_eq!(bs.job_state(9_999_999), None);
    }

    #[test]
    fn advance_next_event_starts_queued_jobs() {
        let mut bs = sys(); // 8 nodes
        let a = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        let b = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        // b waits for a; completing a's event must start b
        assert_eq!(bs.pending_count(), 1);
        assert_eq!(bs.advance_next_event(), Some(a));
        assert_eq!(bs.pending_count(), 0);
        assert_eq!(bs.running_count(), 1);
        assert!(
            bs.record(b).unwrap().start_time.unwrap() >= bs.record(a).unwrap().end_time.unwrap()
        );
    }

    #[test]
    fn machine_factory_builds_queues() {
        let machines = crate::cluster::standard_machines();
        let jedi = machines.iter().find(|m| m.name == "jedi").unwrap();
        let bs = for_machine(jedi, AccountManager::open("a", "b", 1.0));
        assert_eq!(bs.total_nodes("all"), Some(48));
        assert!(bs.total_nodes("devel").unwrap() < 48);
    }
}
