//! The batch system: a discrete-event Slurm-like scheduler.
//!
//! Substitutes Slurm on the simulated machines (DESIGN.md §2). Jobs are
//! submitted against partitions with finite node counts; scheduling is
//! FIFO with EASY backfill: the queue head reserves nodes at the
//! earliest time enough of them free up (its *shadow time*), and a later
//! job may only jump the queue if it fits right now without pushing that
//! reservation. The simulated clock advances only through job
//! completions — wall-clock of the *host* process is irrelevant, which
//! is what makes 90-day daily-pipeline studies (Figs. 3/4) tractable.
//!
//! Fleet-scale costs (DESIGN.md §8): the running set is a min-heap on
//! `(end_time, jobid)`, pending jobs queue per partition, and completing
//! one job reschedules only its own partition — O(log n) per event plus
//! the (short) backfill scan of that partition's queue, instead of the
//! former global restart-at-zero rescans.

use std::collections::{BinaryHeap, HashMap, VecDeque};

use super::accounts::{AccountError, AccountManager};
use super::fault::{FaultKind, FaultPlan};
use super::job::{JobCtx, JobPayload, JobRecord, JobResult, JobSpec, JobState};
use crate::util::json::Json;
use crate::util::timeutil::SimTime;

#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    Account(AccountError),
    UnknownPartition(String),
    TooLarge {
        requested: u64,
        partition: String,
        total: u64,
    },
    /// The scheduler is inside an outage window (DESIGN.md §14):
    /// submissions bounce until `until`; callers retry with
    /// [`BatchSystem::submit_deferred`] past that instant.
    Outage { until: SimTime },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Account(e) => write!(f, "account rejected: {e}"),
            SubmitError::UnknownPartition(p) => write!(f, "unknown partition '{p}'"),
            SubmitError::TooLarge {
                requested,
                partition,
                total,
            } => write!(
                f,
                "job requests {requested} nodes but partition '{partition}' has {total}"
            ),
            SubmitError::Outage { until } => {
                write!(f, "scheduler outage: submissions rejected until {}", until.0)
            }
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<AccountError> for SubmitError {
    fn from(e: AccountError) -> SubmitError {
        SubmitError::Account(e)
    }
}

struct PendingJob {
    jobid: u64,
    nodes: u64,
    walltime_limit_s: u64,
    payload: JobPayload,
    /// Requeued-after-preemption jobs are immune to further faults, so a
    /// requeue cannot cascade and the requeued measurement stays
    /// byte-identical to an unpreempted replay of the same stream.
    immune: bool,
}

/// A submission accepted for a future release instant (retry-after-fault
/// with deterministic backoff). The spec lives in the job's record.
struct DeferredJob {
    release: SimTime,
    jobid: u64,
    payload: JobPayload,
}

/// The kinds of timeline events a machine can advance through. Ordered
/// by dispatch priority at equal instants: completions publish state and
/// free nodes first, deferred releases join the queue next, window
/// boundaries merely re-run the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EventKind {
    Completion,
    Release,
    Boundary,
}

/// One running job on the completion heap. The terminal state is decided
/// once, when the job starts (walltime vs payload outcome), and carried
/// here until completion publishes it — `complete_next` never re-derives
/// it, so a mutated launch overhead or a future fault model cannot make
/// start and completion disagree.
struct RunningJob {
    end_time: SimTime,
    jobid: u64,
    nodes: u64,
    partition: String,
    terminal: JobState,
}

// Reversed ordering on (end_time, jobid) turns std's max-heap into the
// min-heap we need; the jobid tiebreak preserves the deterministic
// earliest-submitted-first completion order of the old linear scan.
impl Ord for RunningJob {
    fn cmp(&self, other: &RunningJob) -> std::cmp::Ordering {
        (other.end_time, other.jobid).cmp(&(self.end_time, self.jobid))
    }
}

impl PartialOrd for RunningJob {
    fn partial_cmp(&self, other: &RunningJob) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for RunningJob {
    fn eq(&self, other: &RunningJob) -> bool {
        self.end_time == other.end_time && self.jobid == other.jobid
    }
}

impl Eq for RunningJob {}

struct PartitionState {
    total_nodes: u64,
    free_nodes: u64,
    /// FIFO queue of jobs waiting for this partition's nodes.
    queue: VecDeque<PendingJob>,
}

/// One machine's batch scheduler.
pub struct BatchSystem {
    pub machine: String,
    pub cores_per_node: u64,
    pub accounts: AccountManager,
    /// Fixed scheduler-cycle latency added before any job starts [s].
    pub sched_latency_s: i64,
    /// Job launch overhead added to application runtime [s].
    pub launch_overhead_s: f64,
    clock: SimTime,
    next_jobid: u64,
    partitions: HashMap<String, PartitionState>,
    running: BinaryHeap<RunningJob>,
    records: HashMap<u64, JobRecord>,
    /// Jobids in submission order. Jobids are allocated monotonically, so
    /// this doubles as the sorted `sacct` order with no per-call sort.
    record_order: Vec<u64>,
    /// Completed jobids since the last drain, oldest first. `None` (the
    /// default) disables logging; the coordinator event loop enables it
    /// so completions triggered *inside* a task poll still wake waiters.
    event_log: Option<Vec<u64>>,
    /// Armed fault schedule (DESIGN.md §14). `None` — and equally a
    /// zero-rate plan with no windows — leaves every timeline byte
    /// untouched.
    fault: Option<FaultPlan>,
    /// Submissions waiting for their release instant.
    deferred: Vec<DeferredJob>,
    /// Preempted jobid → (requeued twin's jobid, original payload
    /// result). Released into the queue when the preemption publishes.
    requeues: HashMap<u64, (u64, JobResult)>,
}

impl BatchSystem {
    pub fn new(machine: &str, cores_per_node: u64, accounts: AccountManager) -> BatchSystem {
        BatchSystem {
            machine: machine.to_string(),
            cores_per_node,
            accounts,
            sched_latency_s: 12,
            launch_overhead_s: 1.5,
            clock: SimTime(0),
            next_jobid: 7_700_000, // JSC-flavoured job ids
            partitions: HashMap::new(),
            running: BinaryHeap::new(),
            records: HashMap::new(),
            record_order: Vec::new(),
            event_log: None,
            fault: None,
            deferred: Vec::new(),
            requeues: HashMap::new(),
        }
    }

    /// Arm (or disarm, with `None`) the seeded fault schedule.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault = plan;
    }

    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault.as_ref()
    }

    pub fn add_partition(&mut self, name: &str, nodes: u64) {
        self.partitions.insert(
            name.to_string(),
            PartitionState {
                total_nodes: nodes,
                free_nodes: nodes,
                queue: VecDeque::new(),
            },
        );
    }

    pub fn now(&self) -> SimTime {
        self.clock
    }

    /// Move the clock forward (e.g. to the next daily pipeline trigger).
    /// Panics if moving backwards.
    pub fn advance_clock_to(&mut self, t: SimTime) {
        // Process every timeline event at or before t first: completions,
        // deferred releases, and fault-window boundaries.
        while let Some((et, _)) = self.next_event() {
            if et > t {
                break;
            }
            self.advance_next_event();
        }
        assert!(t >= self.clock, "clock cannot move backwards");
        self.clock = t;
        self.schedule_all();
    }

    /// Submit a job; validation failures produce a `Rejected` record and
    /// return the error (the CI job sees both).
    pub fn submit(&mut self, spec: JobSpec, payload: JobPayload) -> Result<u64, SubmitError> {
        let jobid = self.next_jobid;
        self.next_jobid += 1;
        let mut record = JobRecord {
            jobid,
            spec: spec.clone(),
            state: JobState::Pending,
            submit_time: self.clock,
            start_time: None,
            end_time: None,
            result: None,
        };

        let validation = self.validate(&spec);
        if let Err(e) = validation {
            record.state = JobState::Rejected;
            record.result = Some(JobResult::failure(&e.to_string()));
            self.records.insert(jobid, record);
            self.record_order.push(jobid);
            crate::obs::count_machine(&self.machine, crate::obs::Ctr::JobsRejected, 1);
            if crate::obs::tracing() {
                // outage bounces get their own instant so a chaos trace
                // separates window rejections from validation failures
                let what = if matches!(e, SubmitError::Outage { .. }) {
                    "outage"
                } else {
                    "reject"
                };
                crate::obs::trace::instant(
                    &self.machine,
                    what,
                    self.clock,
                    crate::obs::trace::args(&[
                        ("jobid", jobid.to_string()),
                        ("job", spec.name.clone()),
                    ]),
                );
            }
            return Err(e);
        }
        self.records.insert(jobid, record);
        self.record_order.push(jobid);
        crate::obs::count_machine(&self.machine, crate::obs::Ctr::JobsSubmitted, 1);
        let partition = spec.partition.clone();
        self.partitions
            .get_mut(&partition)
            .expect("validated partition exists")
            .queue
            .push_back(PendingJob {
                jobid,
                nodes: spec.nodes,
                walltime_limit_s: spec.walltime_limit_s,
                payload,
                immune: false,
            });
        self.schedule_partition(&partition);
        Ok(jobid)
    }

    /// Submit a job that joins the queue only at `release` (clamped to
    /// now). This is the retry path around outage windows: validation
    /// runs against resources and accounts but deliberately skips the
    /// outage gate — the release instant is chosen to land past it.
    pub fn submit_deferred(
        &mut self,
        release: SimTime,
        spec: JobSpec,
        payload: JobPayload,
    ) -> Result<u64, SubmitError> {
        let release = release.max(self.clock);
        let jobid = self.next_jobid;
        self.next_jobid += 1;
        let mut record = JobRecord {
            jobid,
            spec: spec.clone(),
            state: JobState::Pending,
            submit_time: release,
            start_time: None,
            end_time: None,
            result: None,
        };
        if let Err(e) = self.validate_resources(&spec) {
            record.state = JobState::Rejected;
            record.result = Some(JobResult::failure(&e.to_string()));
            self.records.insert(jobid, record);
            self.record_order.push(jobid);
            crate::obs::count_machine(&self.machine, crate::obs::Ctr::JobsRejected, 1);
            return Err(e);
        }
        self.records.insert(jobid, record);
        self.record_order.push(jobid);
        crate::obs::count_machine(&self.machine, crate::obs::Ctr::JobsSubmitted, 1);
        if release <= self.clock {
            let partition = spec.partition.clone();
            self.partitions
                .get_mut(&partition)
                .expect("validated partition exists")
                .queue
                .push_back(PendingJob {
                    jobid,
                    nodes: spec.nodes,
                    walltime_limit_s: spec.walltime_limit_s,
                    payload,
                    immune: false,
                });
            self.schedule_partition(&partition);
        } else {
            self.deferred.push(DeferredJob {
                release,
                jobid,
                payload,
            });
        }
        Ok(jobid)
    }

    fn validate(&self, spec: &JobSpec) -> Result<(), SubmitError> {
        if let Some(until) = self.fault.as_ref().and_then(|p| p.outage_until(self.clock)) {
            return Err(SubmitError::Outage { until });
        }
        self.validate_resources(spec)
    }

    fn validate_resources(&self, spec: &JobSpec) -> Result<(), SubmitError> {
        self.accounts
            .authorize(&spec.account, &spec.budget, &spec.partition)?;
        let part = self
            .partitions
            .get(&spec.partition)
            .ok_or_else(|| SubmitError::UnknownPartition(spec.partition.clone()))?;
        if spec.nodes > part.total_nodes {
            return Err(SubmitError::TooLarge {
                requested: spec.nodes,
                partition: spec.partition.clone(),
                total: part.total_nodes,
            });
        }
        Ok(())
    }

    /// Move deferred submissions whose release instant has arrived into
    /// their partition queues (jobid order for determinism).
    fn release_due_deferred(&mut self) {
        let mut due = Vec::new();
        let mut i = 0;
        while i < self.deferred.len() {
            if self.deferred[i].release <= self.clock {
                due.push(self.deferred.swap_remove(i));
            } else {
                i += 1;
            }
        }
        due.sort_by_key(|d| d.jobid);
        for d in due {
            let spec = self.records[&d.jobid].spec.clone();
            self.partitions
                .get_mut(&spec.partition)
                .expect("validated partition exists")
                .queue
                .push_back(PendingJob {
                    jobid: d.jobid,
                    nodes: spec.nodes,
                    walltime_limit_s: spec.walltime_limit_s,
                    payload: d.payload,
                    immune: false,
                });
        }
    }

    /// Schedule every partition (sorted by name for determinism). Only
    /// needed when the whole machine's state may have changed — submit
    /// and completion reschedule just the affected partition.
    fn schedule_all(&mut self) {
        let mut names: Vec<String> = self.partitions.keys().cloned().collect();
        names.sort_unstable();
        for name in names {
            self.schedule_partition(&name);
        }
    }

    /// FIFO + EASY backfill over one partition's queue.
    ///
    /// Phase 1 starts queue heads while they fit. If the head is blocked,
    /// phase 2 computes its reservation — the shadow time when enough
    /// nodes will have freed, and the spare nodes beyond its need at that
    /// moment — and backfills only later jobs that fit *now* and either
    /// project (by their walltime limit) to finish before the shadow or
    /// fit inside the spare. The head can therefore wait at most until
    /// its shadow: a stream of small later submissions can no longer
    /// starve it.
    fn schedule_partition(&mut self, pname: &str) {
        // Frozen scheduler: during an outage the pending queues hold
        // still; during maintenance the partitions drain — running jobs
        // finish (complete_next stays live) but nothing new starts until
        // the window boundary re-runs the scheduler.
        if self.fault.as_ref().is_some_and(|p| p.frozen(self.clock)) {
            return;
        }
        let Some(part) = self.partitions.get_mut(pname) else {
            return;
        };
        let mut queue = std::mem::take(&mut part.queue);
        // Phase 1: strict FIFO while the head fits.
        while let Some(head) = queue.front() {
            if head.nodes <= self.partitions[pname].free_nodes {
                let job = queue.pop_front().expect("nonempty");
                self.start_job(job.jobid, job.payload, false, job.immune);
            } else {
                break;
            }
        }
        // Phase 2: head blocked — backfill under its reservation.
        if let Some(head) = queue.front() {
            let free = self.partitions[pname].free_nodes;
            let (shadow, mut spare) = self.head_reservation(pname, head.nodes, free);
            crate::obs::count_machine(&self.machine, crate::obs::Ctr::HeadHolds, 1);
            if crate::obs::tracing() {
                crate::obs::trace::instant(
                    &self.machine,
                    "head-hold",
                    self.clock,
                    crate::obs::trace::args(&[
                        ("jobid", head.jobid.to_string()),
                        ("need_nodes", head.nodes.to_string()),
                        ("free_nodes", free.to_string()),
                        ("shadow", shadow.0.to_string()),
                    ]),
                );
            }
            let mut i = 1;
            while i < queue.len() {
                let cand = &queue[i];
                if cand.nodes > self.partitions[pname].free_nodes {
                    i += 1;
                    continue;
                }
                let projected_end = self
                    .clock
                    .add_secs(self.sched_latency_s + cand.walltime_limit_s as i64);
                let before_shadow = projected_end <= shadow;
                if before_shadow || cand.nodes <= spare {
                    if !before_shadow {
                        spare -= cand.nodes;
                    }
                    let job = queue.remove(i).expect("index in bounds");
                    self.start_job(job.jobid, job.payload, true, job.immune);
                    // the next candidate shifted into position i
                } else {
                    i += 1;
                }
            }
        }
        self.partitions
            .get_mut(pname)
            .expect("partition still exists")
            .queue = queue;
    }

    /// The blocked head's reservation: walk this partition's running
    /// jobs in completion order until enough nodes have freed for `need`.
    /// Returns (shadow time, spare nodes beyond `need` at the shadow).
    fn head_reservation(&self, pname: &str, need: u64, free_now: u64) -> (SimTime, u64) {
        let mut ends: Vec<(SimTime, u64)> = self
            .running
            .iter()
            .filter(|r| r.partition == pname)
            .map(|r| (r.end_time, r.nodes))
            .collect();
        ends.sort_unstable();
        let mut avail = free_now;
        for (end, nodes) in ends {
            avail += nodes;
            if avail >= need {
                return (end, avail - need);
            }
        }
        // Unreachable when validation holds (need <= total and every
        // running job eventually frees its nodes); reserve forever so
        // nothing backfills against an impossible head.
        (SimTime(i64::MAX), 0)
    }

    fn start_job(&mut self, jobid: u64, payload: JobPayload, backfilled: bool, immune: bool) {
        let spec = self.records[&jobid].spec.clone();
        let part = self.partitions.get_mut(&spec.partition).unwrap();
        part.free_nodes -= spec.nodes;
        let start = self.clock.add_secs(self.sched_latency_s);
        let ctx = JobCtx {
            jobid,
            start_time: start,
            nodes: spec.nodes,
            tasks_per_node: spec.tasks_per_node,
            threads_per_task: spec.threads_per_task,
            partition: spec.partition.clone(),
        };
        let result = payload(&ctx);
        let app_duration = result.duration_s + self.launch_overhead_s;
        let (mut state, duration) = if app_duration > spec.walltime_limit_s as f64 {
            (JobState::Timeout, spec.walltime_limit_s as f64)
        } else if result.success {
            (JobState::Completed, app_duration)
        } else {
            (JobState::Failed, app_duration)
        };
        let mut end_secs = duration.ceil() as i64;
        // Seeded fault injection (DESIGN.md §14): a job that would have
        // completed may be struck mid-run. The decision is a pure
        // function of (plan seed, machine, jobid), so submission-order
        // permutations cannot reshape anyone's fate; requeued twins are
        // immune so preemption cannot cascade.
        let mut requeued_as: Option<u64> = None;
        if state == JobState::Completed && !immune {
            if let Some(d) = self
                .fault
                .as_ref()
                .and_then(|p| p.decide(jobid, &spec.name, start))
            {
                end_secs = ((end_secs as f64) * d.strike_frac).ceil().max(1.0) as i64;
                state = match d.kind {
                    FaultKind::NodeFail => JobState::NodeFail,
                    FaultKind::Preempt => JobState::Preempted,
                };
            }
        }
        let end = start.add_secs(end_secs);
        if state == JobState::Preempted {
            // Allocate the requeued twin now (keeping jobids monotone in
            // allocation order, so the sacct dump stays sorted) but
            // release it into the queue only when the preemption
            // publishes at `end` — a requeued job can never start before
            // the preemption instant. The twin carries the *original*
            // payload result, so its measurement is byte-identical to an
            // unpreempted replay of the same stream.
            let twin = self.next_jobid;
            self.next_jobid += 1;
            self.records.insert(
                twin,
                JobRecord {
                    jobid: twin,
                    spec: spec.clone(),
                    state: JobState::Pending,
                    submit_time: end,
                    start_time: None,
                    end_time: None,
                    result: None,
                },
            );
            self.record_order.push(twin);
            self.requeues.insert(jobid, (twin, result.clone()));
            requeued_as = Some(twin);
        }
        let rec = self.records.get_mut(&jobid).unwrap();
        rec.state = JobState::Running; // terminal state published at completion
        rec.start_time = Some(start);
        rec.end_time = Some(end);
        rec.result = Some(match state {
            JobState::Timeout => {
                // A killed job reports nothing past the wall: the recorded
                // duration is truncated to the limit and the metrics/files
                // the payload "produced" after its death are dropped, so a
                // timed-out run can never feed fictional measurements into
                // tracking history or energy series. The replacement metrics
                // flag the truncation for the analysis layer.
                JobResult {
                    duration_s: result.duration_s.min(spec.walltime_limit_s as f64),
                    success: false,
                    metrics: Json::obj()
                        .set("timeout", true)
                        .set("walltime_limit_s", spec.walltime_limit_s),
                    files: Vec::new(),
                }
            }
            // Same honesty contract for faults: the struck run records
            // only the truncated duration and the fault flag — the
            // application metrics/files of the run that never finished
            // are dropped and can never warm a cache or feed a gate.
            JobState::NodeFail => JobResult {
                duration_s: end_secs as f64,
                success: false,
                metrics: Json::obj().set("node_fail", true),
                files: Vec::new(),
            },
            JobState::Preempted => JobResult {
                duration_s: end_secs as f64,
                success: false,
                metrics: Json::obj()
                    .set("preempted", true)
                    .set("requeued_as", requeued_as.expect("twin allocated above")),
                files: Vec::new(),
            },
            _ => result,
        });
        let submit = rec.submit_time;
        if crate::obs::tracing() {
            crate::obs::trace::span(
                &self.machine,
                "queue-wait",
                submit,
                start,
                crate::obs::trace::args(&[
                    ("jobid", jobid.to_string()),
                    ("job", spec.name.clone()),
                    ("backfilled", backfilled.to_string()),
                ]),
            );
            crate::obs::trace::span(
                &self.machine,
                "run",
                start,
                end,
                crate::obs::trace::args(&[
                    ("jobid", jobid.to_string()),
                    ("job", spec.name.clone()),
                    ("nodes", spec.nodes.to_string()),
                    ("state", state.name().to_string()),
                    ("backfilled", backfilled.to_string()),
                ]),
            );
            match state {
                JobState::NodeFail => crate::obs::trace::instant(
                    &self.machine,
                    "node-fail",
                    end,
                    crate::obs::trace::args(&[
                        ("jobid", jobid.to_string()),
                        ("job", spec.name.clone()),
                    ]),
                ),
                JobState::Preempted => crate::obs::trace::instant(
                    &self.machine,
                    "preempt",
                    end,
                    crate::obs::trace::args(&[
                        ("jobid", jobid.to_string()),
                        ("job", spec.name.clone()),
                        (
                            "requeued_as",
                            requeued_as.expect("twin allocated above").to_string(),
                        ),
                    ]),
                ),
                _ => {}
            }
        }
        if crate::obs::metrics_on() {
            use crate::obs::{Ctr, Hist};
            crate::obs::count_machine(&self.machine, Ctr::JobsStarted, 1);
            if backfilled {
                crate::obs::count_machine(&self.machine, Ctr::JobsBackfilled, 1);
            }
            match state {
                JobState::Timeout => crate::obs::count_machine(&self.machine, Ctr::JobsTimeout, 1),
                JobState::Failed => crate::obs::count_machine(&self.machine, Ctr::JobsFailed, 1),
                JobState::NodeFail => {
                    crate::obs::count_machine(&self.machine, Ctr::JobsNodeFailed, 1)
                }
                JobState::Preempted => {
                    crate::obs::count_machine(&self.machine, Ctr::JobsPreempted, 1)
                }
                _ => {}
            }
            crate::obs::observe(Hist::QueueWaitS, start.0 - submit.0);
            crate::obs::observe(Hist::RunTimeS, end.0 - start.0);
        }
        self.running.push(RunningJob {
            end_time: end,
            jobid,
            nodes: spec.nodes,
            partition: spec.partition,
            terminal: state,
        });
    }

    fn earliest_end(&self) -> Option<SimTime> {
        self.running.peek().map(|r| r.end_time)
    }

    /// Complete the earliest-finishing running job; advances the clock.
    fn complete_next(&mut self) -> Option<u64> {
        let RunningJob {
            end_time,
            jobid,
            nodes,
            partition,
            terminal,
        } = self.running.pop()?;
        self.clock = self.clock.max(end_time);
        let cores = self.cores_per_node;
        let rec = self.records.get_mut(&jobid).unwrap();
        // publish the terminal state decided at start — no re-derivation
        rec.state = terminal;
        let account = rec.spec.account.clone();
        let ch = rec.core_hours(cores);
        self.accounts.charge(&account, ch);
        if let Some(p) = self.partitions.get_mut(&partition) {
            p.free_nodes += nodes;
        }
        if terminal == JobState::Preempted {
            // The preemption just published (clock == preemption
            // instant): release the requeued twin into the queue now.
            // The trailing schedule_partition starts it causally.
            if let Some((twin, result)) = self.requeues.remove(&jobid) {
                let spec = self.records[&twin].spec.clone();
                self.partitions
                    .get_mut(&partition)
                    .expect("partition still exists")
                    .queue
                    .push_back(PendingJob {
                        jobid: twin,
                        nodes: spec.nodes,
                        walltime_limit_s: spec.walltime_limit_s,
                        payload: Box::new(move |_| result),
                        immune: true,
                    });
                crate::obs::count_machine(&self.machine, crate::obs::Ctr::JobsRequeued, 1);
                if crate::obs::tracing() {
                    crate::obs::trace::instant(
                        &self.machine,
                        "requeue",
                        end_time,
                        crate::obs::trace::args(&[
                            ("jobid", twin.to_string()),
                            ("preempted", jobid.to_string()),
                        ]),
                    );
                }
            }
        }
        if let Some(log) = self.event_log.as_mut() {
            log.push(jobid);
        }
        crate::obs::count_machine(&self.machine, crate::obs::Ctr::JobsCompleted, 1);
        if crate::obs::tracing() {
            crate::obs::trace::instant(
                &self.machine,
                "complete",
                end_time,
                crate::obs::trace::args(&[
                    ("jobid", jobid.to_string()),
                    ("state", terminal.name().to_string()),
                ]),
            );
        }
        self.schedule_partition(&partition);
        Some(jobid)
    }

    /// Run the event loop until no job is pending or running. Fault
    /// aware: deferred releases and window boundaries are events too, so
    /// a frozen queue thaws and a deferred retry launches before the
    /// machine is declared idle.
    pub fn run_until_idle(&mut self) {
        self.schedule_all();
        while self.next_event().is_some() {
            self.advance_next_event();
        }
        debug_assert!(self.running.is_empty());
    }

    // ---- discrete-event interleaving API (coordinator event loop) -----
    //
    // A coordinator interleaving many pipelines across many machines
    // drives each machine one completion event at a time instead of
    // draining it: peek at the next event time, pick the globally
    // earliest machine, advance it by exactly one event, and wake the
    // pipeline that was waiting on the completed job.

    /// The machine's next timeline event: the earliest of (a) a running
    /// job's completion, (b) a deferred submission's release, (c) a
    /// fault-window boundary that could thaw or freeze scheduling.
    /// Boundaries only count while something is pending or deferred —
    /// an idle machine inside a window has no event. Ties dispatch
    /// completions first, then releases, then boundaries.
    fn next_event(&self) -> Option<(SimTime, EventKind)> {
        let mut best: Option<(SimTime, EventKind)> = None;
        if let Some(t) = self.earliest_end() {
            best = Some((t, EventKind::Completion));
        }
        if let Some(t) = self.deferred.iter().map(|d| d.release).min() {
            if best.map_or(true, |(bt, _)| t < bt) {
                best = Some((t, EventKind::Release));
            }
        }
        if self.pending_count() > 0 || !self.deferred.is_empty() {
            if let Some(t) = self
                .fault
                .as_ref()
                .and_then(|p| p.next_boundary_after(self.clock))
            {
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, EventKind::Boundary));
                }
            }
        }
        best
    }

    /// Simulated time of this machine's next event, if any. Without an
    /// armed fault plan, pending jobs never stall silently: a submission
    /// that fits starts immediately (scheduling runs on submit and on
    /// every completion), so `None` means the machine is idle. With one,
    /// deferred releases and window boundaries are events too.
    pub fn peek_next_event(&self) -> Option<SimTime> {
        self.next_event().map(|(t, _)| t)
    }

    /// Advance this machine by exactly one timeline event. For a
    /// completion: publish the terminal state, charge accounting, start
    /// pending jobs that now fit, and return the completed jobid. For a
    /// deferred release or a window boundary: move the clock, re-run the
    /// scheduler, and return `None` — both drivers treat a `None` with a
    /// changed clock as a boundary event, observing any resulting
    /// completions through later events.
    pub fn advance_next_event(&mut self) -> Option<u64> {
        match self.next_event()? {
            (_, EventKind::Completion) => self.complete_next(),
            (t, EventKind::Release) => {
                self.clock = self.clock.max(t);
                self.release_due_deferred();
                self.schedule_all();
                None
            }
            (t, EventKind::Boundary) => {
                self.clock = self.clock.max(t);
                self.schedule_all();
                None
            }
        }
    }

    /// Turn completion logging on or off, returning the previous state
    /// so a driver can restore whatever it found (nest-safe). While on,
    /// every completed jobid is appended for [`BatchSystem::drain_event_log`].
    pub fn set_event_log(&mut self, on: bool) -> bool {
        let was = self.event_log.is_some();
        if on {
            if self.event_log.is_none() {
                self.event_log = Some(Vec::new());
            }
        } else {
            self.event_log = None;
        }
        was
    }

    /// Take all completions logged since the last drain, oldest first.
    /// Empty when logging is off.
    pub fn drain_event_log(&mut self) -> Vec<u64> {
        match self.event_log.as_mut() {
            Some(log) if !log.is_empty() => std::mem::take(log),
            _ => Vec::new(),
        }
    }

    /// Current lifecycle state of a job (`None` for unknown ids).
    pub fn job_state(&self, jobid: u64) -> Option<JobState> {
        self.records.get(&jobid).map(|r| r.state)
    }

    pub fn record(&self, jobid: u64) -> Option<&JobRecord> {
        self.records.get(&jobid)
    }

    /// All records, sorted by job id (the `sacct` dump). Jobids are
    /// allocated monotonically at submit, so submission order *is*
    /// sorted order — no per-call sort, one Vec of refs.
    pub fn records(&self) -> Vec<&JobRecord> {
        self.record_order.iter().map(|id| &self.records[id]).collect()
    }

    /// Iterate records in job-id order without allocating (the hot-path
    /// variant of [`BatchSystem::records`] for stats and benches).
    pub fn records_iter(&self) -> impl Iterator<Item = &JobRecord> + '_ {
        self.record_order.iter().map(move |id| &self.records[id])
    }

    pub fn record_count(&self) -> usize {
        self.record_order.len()
    }

    pub fn free_nodes(&self, partition: &str) -> Option<u64> {
        self.partitions.get(partition).map(|p| p.free_nodes)
    }

    pub fn total_nodes(&self, partition: &str) -> Option<u64> {
        self.partitions.get(partition).map(|p| p.total_nodes)
    }

    pub fn pending_count(&self) -> usize {
        self.partitions.values().map(|p| p.queue.len()).sum()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }
}

/// Build a batch system for a simulated machine with its standard queues.
pub fn for_machine(m: &crate::cluster::Machine, accounts: AccountManager) -> BatchSystem {
    let mut bs = BatchSystem::new(&m.name, m.cores_per_node, accounts);
    for q in &m.queues {
        // devel queues get a small slice, production queues the full system
        let nodes = if q.contains("devel") {
            (m.nodes / 12).max(2)
        } else {
            m.nodes
        };
        bs.add_partition(q, nodes);
    }
    bs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn quick_payload(secs: f64, ok: bool) -> JobPayload {
        Box::new(move |_ctx| JobResult {
            duration_s: secs,
            success: ok,
            metrics: Json::obj(),
            files: vec![],
        })
    }

    fn sys() -> BatchSystem {
        let mut bs = BatchSystem::new("jedi", 288, AccountManager::open("p", "b", 1e9));
        bs.add_partition("all", 8);
        bs
    }

    #[test]
    fn job_lifecycle_completed() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    partition: "all".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Completed);
        assert!(rec.queue_wait_s().unwrap() >= 0);
        let dur = rec.end_time.unwrap().0 - rec.start_time.unwrap().0;
        assert!((100..=105).contains(&dur), "dur={dur}");
    }

    #[test]
    fn failed_payload_marks_failed() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(10.0, false),
            )
            .unwrap();
        bs.run_until_idle();
        assert_eq!(bs.record(id).unwrap().state, JobState::Failed);
    }

    #[test]
    fn walltime_enforced() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    walltime_limit_s: 60,
                    ..Default::default()
                },
                quick_payload(3600.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Timeout);
        assert!(!rec.result.as_ref().unwrap().success);
        assert_eq!(rec.end_time.unwrap().0 - rec.start_time.unwrap().0, 60);
    }

    /// Regression (bugfix 1): a timed-out job used to keep the payload's
    /// full result — a duration past the wall plus metrics and files from
    /// the part of the run that never happened. The record must be
    /// truncated to the limit with the fictional measurements dropped.
    #[test]
    fn timeout_truncates_recorded_result() {
        let mut bs = sys();
        let id = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    walltime_limit_s: 60,
                    ..Default::default()
                },
                Box::new(|_ctx| JobResult {
                    duration_s: 3600.0,
                    success: true,
                    metrics: Json::obj().set("tts", 3600.0).set("energy_j", 1.0e7),
                    files: vec![("app.out".into(), "time: 3600".into())],
                }),
            )
            .unwrap();
        bs.run_until_idle();
        let result = bs.record(id).unwrap().result.clone().unwrap();
        assert!(!result.success);
        assert!(
            result.duration_s <= 60.0,
            "recorded duration {} exceeds the 60s wall",
            result.duration_s
        );
        // the killed run's measurements are gone; only the flag remains
        assert!(result.metrics.f64_of("tts").is_none());
        assert!(result.metrics.f64_of("energy_j").is_none());
        assert_eq!(result.metrics.bool_of("timeout"), Some(true));
        assert!(result.files.is_empty(), "files survived the kill");
    }

    /// Regression (bugfix 2): the terminal state is decided exactly once,
    /// at start. Completion used to re-derive it from
    /// `result.duration_s + launch_overhead_s`, so mutating the overhead
    /// while a job ran flipped an exact-walltime job from Completed to
    /// Timeout between the two derivations.
    #[test]
    fn terminal_state_decided_once_at_start() {
        let mut bs = sys();
        // exact boundary: 58.5s payload + 1.5s overhead == the 60s wall;
        // "exceeds" is strict, so this completes
        let id = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    walltime_limit_s: 60,
                    ..Default::default()
                },
                quick_payload(58.5, true),
            )
            .unwrap();
        assert_eq!(bs.job_state(id), Some(JobState::Running));
        // a mid-flight overhead change must not rewrite history
        bs.launch_overhead_s = 100.0;
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Completed);
        assert_eq!(rec.end_time.unwrap().0 - rec.start_time.unwrap().0, 60);
        // and one second past the wall is a timeout, decided at start
        bs.launch_overhead_s = 1.5;
        let over = bs
            .submit(
                JobSpec {
                    account: "p".into(),
                    budget: "b".into(),
                    walltime_limit_s: 60,
                    ..Default::default()
                },
                quick_payload(59.5, true),
            )
            .unwrap();
        bs.launch_overhead_s = 0.0;
        bs.run_until_idle();
        assert_eq!(bs.record(over).unwrap().state, JobState::Timeout);
    }

    #[test]
    fn contention_queues_jobs() {
        let mut bs = sys(); // 8 nodes
        let a = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1000.0, true),
            )
            .unwrap();
        let b = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1000.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        let ra = bs.record(a).unwrap();
        let rb = bs.record(b).unwrap();
        // b cannot start before a finishes
        assert!(rb.start_time.unwrap() >= ra.end_time.unwrap());
    }

    #[test]
    fn backfill_lets_small_jobs_through() {
        let mut bs = sys(); // 8 nodes
        let _big = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1000.0, true),
            )
            .unwrap();
        let blocked = bs
            .submit(
                JobSpec {
                    nodes: 8,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(10.0, true),
            )
            .unwrap();
        let small = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    // a tight walltime keeps the projected end inside the
                    // blocked head's reservation — that's what makes this
                    // a legal backfill under EASY
                    walltime_limit_s: 30,
                    ..Default::default()
                },
                quick_payload(10.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        // the 2-node job backfills ahead of the blocked 8-node job
        let s = bs.record(small).unwrap().start_time.unwrap();
        let blk = bs.record(blocked).unwrap().start_time.unwrap();
        assert!(s < blk, "small={:?} blocked={:?}", s, blk);
    }

    /// Regression (bugfix 3): pure backfill used to start *any* pending
    /// job that fit, so a 48-node job behind a stream of 16-node jobs
    /// never saw 48 nodes free at once. Under the head-of-line
    /// reservation the big job starts at its shadow time — when the
    /// initial wave drains — and every later small job waits behind it.
    #[test]
    fn head_of_line_job_is_not_starved_by_backfill() {
        let mut bs = BatchSystem::new("jedi", 288, AccountManager::open("p", "b", 1e9));
        bs.add_partition("all", 48);
        let small = || JobSpec {
            nodes: 16,
            account: "p".into(),
            budget: "b".into(),
            walltime_limit_s: 1100,
            ..Default::default()
        };
        // staggered initial wave filling the partition: nodes never all
        // free at the same instant until the queue drains
        let mut wave = Vec::new();
        for secs in [300.0, 600.0, 900.0] {
            wave.push(bs.submit(small(), quick_payload(secs, true)).unwrap());
        }
        let big = bs
            .submit(
                JobSpec {
                    nodes: 48,
                    account: "p".into(),
                    budget: "b".into(),
                    walltime_limit_s: 500,
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        let mut stream = Vec::new();
        for _ in 0..10 {
            stream.push(bs.submit(small(), quick_payload(1000.0, true)).unwrap());
        }
        bs.run_until_idle();
        let rec = bs.record(big).unwrap();
        assert_eq!(rec.state, JobState::Completed);
        let big_start = rec.start_time.unwrap();
        // the big job starts when the initial wave has drained (~914s),
        // not after the whole 10-job stream
        let wave_end = bs.record(wave[2]).unwrap().end_time.unwrap();
        assert_eq!(big_start, wave_end.add_secs(bs.sched_latency_s));
        for id in &stream {
            let s = bs.record(*id).unwrap().start_time.unwrap();
            assert!(
                s >= big_start,
                "stream job {id} started at {s:?}, starving the 48-node head (started {big_start:?})"
            );
        }
    }

    #[test]
    fn rejection_paths() {
        let mut bs = sys();
        let err = bs
            .submit(
                JobSpec {
                    nodes: 99,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1.0, true),
            )
            .unwrap_err();
        assert!(matches!(err, SubmitError::TooLarge { .. }));
        let err = bs
            .submit(
                JobSpec {
                    partition: "nope".into(),
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(1.0, true),
            )
            .unwrap_err();
        assert!(matches!(err, SubmitError::UnknownPartition(_)));
        // rejected jobs leave a record
        assert_eq!(
            bs.records()
                .iter()
                .filter(|r| r.state == JobState::Rejected)
                .count(),
            2
        );
    }

    #[test]
    fn accounting_charges_core_hours() {
        let mut bs = sys();
        bs.submit(
            JobSpec {
                nodes: 4,
                account: "p".into(),
                budget: "b".into(),
                ..Default::default()
            },
            quick_payload(3600.0, true),
        )
        .unwrap();
        bs.run_until_idle();
        // ~1h on 4x288 cores ≈ 1152 core-hours (+ overheads)
        let used = bs.accounts.total_used();
        assert!(used > 1100.0 && used < 1200.0, "used={used}");
    }

    #[test]
    fn clock_advances_through_days() {
        let mut bs = sys();
        bs.advance_clock_to(SimTime::from_days(3));
        assert_eq!(bs.now().day(), 3);
        bs.submit(
            JobSpec {
                account: "p".into(),
                budget: "b".into(),
                ..Default::default()
            },
            quick_payload(50.0, true),
        )
        .unwrap();
        bs.run_until_idle();
        assert!(bs.now() > SimTime::from_days(3));
        assert!(bs.now() < SimTime::from_days(3).add_secs(600));
    }

    #[test]
    fn peek_and_advance_interleave_events() {
        let mut bs = sys(); // 8 nodes
        assert_eq!(bs.peek_next_event(), None);
        let short = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        let long = bs
            .submit(
                JobSpec {
                    nodes: 2,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(500.0, true),
            )
            .unwrap();
        // both are running; neither is terminal yet
        assert_eq!(bs.running_count(), 2);
        assert!(!bs.job_state(short).unwrap().is_terminal());
        let first_end = bs.peek_next_event().unwrap();
        // one event at a time, earliest first, clock tracking each end
        assert_eq!(bs.advance_next_event(), Some(short));
        assert_eq!(bs.now(), first_end);
        assert!(bs.job_state(short).unwrap().is_terminal());
        assert!(!bs.job_state(long).unwrap().is_terminal());
        assert_eq!(bs.advance_next_event(), Some(long));
        assert_eq!(bs.advance_next_event(), None);
        assert_eq!(bs.peek_next_event(), None);
        assert_eq!(bs.job_state(9_999_999), None);
    }

    #[test]
    fn advance_next_event_starts_queued_jobs() {
        let mut bs = sys(); // 8 nodes
        let a = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        let b = bs
            .submit(
                JobSpec {
                    nodes: 6,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        // b waits for a; completing a's event must start b
        assert_eq!(bs.pending_count(), 1);
        assert_eq!(bs.advance_next_event(), Some(a));
        assert_eq!(bs.pending_count(), 0);
        assert_eq!(bs.running_count(), 1);
        assert!(
            bs.record(b).unwrap().start_time.unwrap() >= bs.record(a).unwrap().end_time.unwrap()
        );
    }

    #[test]
    fn event_log_captures_completions_in_order() {
        let mut bs = sys();
        // off by default: completions are not buffered
        bs.submit(
            JobSpec {
                nodes: 1,
                account: "p".into(),
                budget: "b".into(),
                ..Default::default()
            },
            quick_payload(10.0, true),
        )
        .unwrap();
        bs.run_until_idle();
        assert!(bs.drain_event_log().is_empty());
        assert!(!bs.set_event_log(true));
        let a = bs
            .submit(
                JobSpec {
                    nodes: 1,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(100.0, true),
            )
            .unwrap();
        let b = bs
            .submit(
                JobSpec {
                    nodes: 1,
                    account: "p".into(),
                    budget: "b".into(),
                    ..Default::default()
                },
                quick_payload(50.0, true),
            )
            .unwrap();
        bs.run_until_idle();
        // completion order (b is shorter), drained once
        assert_eq!(bs.drain_event_log(), vec![b, a]);
        assert!(bs.drain_event_log().is_empty());
        assert!(bs.set_event_log(false));
    }

    #[test]
    fn records_are_jobid_sorted_without_resorting() {
        let mut bs = sys();
        let mut ids = Vec::new();
        for i in 0..5u64 {
            ids.push(
                bs.submit(
                    JobSpec {
                        nodes: 1,
                        account: "p".into(),
                        budget: "b".into(),
                        ..Default::default()
                    },
                    quick_payload(10.0 * (5 - i) as f64, true),
                )
                .unwrap(),
            );
        }
        bs.run_until_idle();
        let listed: Vec<u64> = bs.records().iter().map(|r| r.jobid).collect();
        assert_eq!(listed, ids);
        let iterated: Vec<u64> = bs.records_iter().map(|r| r.jobid).collect();
        assert_eq!(iterated, ids);
        assert_eq!(bs.record_count(), 5);
    }

    #[test]
    fn machine_factory_builds_queues() {
        let machines = crate::cluster::standard_machines();
        let jedi = machines.iter().find(|m| m.name == "jedi").unwrap();
        let bs = for_machine(jedi, AccountManager::open("a", "b", 1.0));
        assert_eq!(bs.total_nodes("all"), Some(48));
        assert!(bs.total_nodes("devel").unwrap() < 48);
    }

    // ---- fault model (DESIGN.md §14) ---------------------------------

    use super::super::fault::{FaultPlan, ForcedFault, Window};

    fn spec1() -> JobSpec {
        JobSpec {
            nodes: 1,
            account: "p".into(),
            budget: "b".into(),
            partition: "all".into(),
            ..Default::default()
        }
    }

    #[test]
    fn node_fail_truncates_and_drops_metrics() {
        let mut bs = sys();
        bs.set_fault_plan(Some(FaultPlan {
            node_fail_rate: 1.0,
            ..FaultPlan::seeded("jedi", 7)
        }));
        let id = bs
            .submit(
                spec1(),
                Box::new(|_ctx| JobResult {
                    duration_s: 100.0,
                    success: true,
                    metrics: Json::obj().set("tts", 100.0),
                    files: vec![("app.out".into(), "time: 100".into())],
                }),
            )
            .unwrap();
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::NodeFail);
        let dur = rec.end_time.unwrap().0 - rec.start_time.unwrap().0;
        assert!((1..102).contains(&dur), "struck mid-run, got {dur}");
        let result = rec.result.as_ref().unwrap();
        assert!(!result.success);
        // honesty contract: the dead run's measurements are gone
        assert!(result.metrics.f64_of("tts").is_none());
        assert_eq!(result.metrics.bool_of("node_fail"), Some(true));
        assert!(result.files.is_empty());
    }

    #[test]
    fn preempted_job_requeues_with_original_result() {
        let mut bs = sys();
        bs.set_fault_plan(Some(FaultPlan {
            preempt_rate: 1.0,
            ..FaultPlan::seeded("jedi", 7)
        }));
        let id = bs
            .submit(
                spec1(),
                Box::new(|_ctx| JobResult {
                    duration_s: 100.0,
                    success: true,
                    metrics: Json::obj().set("tts", 100.0),
                    files: vec![("app.out".into(), "time: 100".into())],
                }),
            )
            .unwrap();
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Preempted);
        let result = rec.result.as_ref().unwrap();
        assert!(!result.success);
        let twin = result.metrics.u64_of("requeued_as").unwrap();
        assert!(twin > id);
        // the requeued twin is immune, starts after the preemption
        // instant, and carries the original (unclipped) measurement
        let trec = bs.record(twin).unwrap();
        assert_eq!(trec.state, JobState::Completed);
        assert!(trec.submit_time >= rec.end_time.unwrap());
        assert!(trec.start_time.unwrap() >= rec.end_time.unwrap());
        let tres = trec.result.as_ref().unwrap();
        assert_eq!(tres.metrics.f64_of("tts"), Some(100.0));
        assert_eq!(tres.files.len(), 1);
        // sacct dump stays jobid-sorted with the twin appended
        let listed: Vec<u64> = bs.records().iter().map(|r| r.jobid).collect();
        let mut sorted = listed.clone();
        sorted.sort_unstable();
        assert_eq!(listed, sorted);
    }

    #[test]
    fn outage_rejects_then_deferred_retry_lands() {
        let mut bs = sys();
        bs.set_fault_plan(Some(FaultPlan {
            outages: vec![Window::new(SimTime(100), SimTime(200))],
            ..FaultPlan::quiet("jedi")
        }));
        bs.advance_clock_to(SimTime(150));
        let err = bs.submit(spec1(), quick_payload(10.0, true)).unwrap_err();
        assert!(matches!(err, SubmitError::Outage { until } if until == SimTime(200)));
        // the bounce leaves an honest Rejected record
        assert_eq!(
            bs.records()
                .iter()
                .filter(|r| r.state == JobState::Rejected)
                .count(),
            1
        );
        // deferred retry past the window runs to completion
        let id = bs
            .submit_deferred(SimTime(230), spec1(), quick_payload(10.0, true))
            .unwrap();
        assert_eq!(bs.job_state(id), Some(JobState::Pending));
        bs.run_until_idle();
        let rec = bs.record(id).unwrap();
        assert_eq!(rec.state, JobState::Completed);
        assert_eq!(rec.submit_time, SimTime(230));
        assert!(rec.start_time.unwrap() >= SimTime(230));
    }

    #[test]
    fn maintenance_drains_partition_until_boundary() {
        let mut bs = sys();
        let maint = Window::new(SimTime(50), SimTime(500));
        bs.set_fault_plan(Some(FaultPlan {
            maintenance: vec![maint],
            ..FaultPlan::quiet("jedi")
        }));
        // started before the window: runs to completion (drain)
        let running = bs.submit(spec1(), quick_payload(100.0, true)).unwrap();
        bs.advance_clock_to(SimTime(60));
        // submitted during the window: accepted but frozen
        let frozen = bs.submit(spec1(), quick_payload(10.0, true)).unwrap();
        assert_eq!(bs.pending_count(), 1);
        bs.run_until_idle();
        assert_eq!(bs.record(running).unwrap().state, JobState::Completed);
        let rec = bs.record(frozen).unwrap();
        assert_eq!(rec.state, JobState::Completed);
        assert!(
            rec.start_time.unwrap() >= maint.end,
            "job started {:?}, inside the maintenance window",
            rec.start_time.unwrap()
        );
    }

    #[test]
    fn quiet_plan_is_byte_inert() {
        let run = |armed: bool| -> Vec<(u64, JobState, i64, i64)> {
            let mut bs = sys();
            if armed {
                bs.set_fault_plan(Some(FaultPlan::quiet("jedi")));
            }
            for secs in [100.0, 50.0, 900.0, 20.0] {
                bs.submit(
                    JobSpec {
                        nodes: 3,
                        account: "p".into(),
                        budget: "b".into(),
                        partition: "all".into(),
                        ..Default::default()
                    },
                    quick_payload(secs, true),
                )
                .unwrap();
            }
            bs.run_until_idle();
            bs.records()
                .iter()
                .map(|r| {
                    (
                        r.jobid,
                        r.state,
                        r.start_time.unwrap().0,
                        r.end_time.unwrap().0,
                    )
                })
                .collect()
        };
        assert_eq!(run(false), run(true));
    }
}
