//! Batch-job model: specs, states, and accounting records.

use crate::util::json::Json;
use crate::util::timeutil::SimTime;

/// What a job asks the batch system for (an `sbatch` header).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    /// Compute project / account (`project` input in the CI component).
    pub account: String,
    /// Budget the core-hours are drawn from (`budget` input).
    pub budget: String,
    pub partition: String,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
    /// Wall-time limit [s]; the job is killed when it exceeds this.
    pub walltime_limit_s: u64,
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec {
            name: "job".into(),
            account: "default".into(),
            budget: "default".into(),
            partition: "all".into(),
            nodes: 1,
            tasks_per_node: 1,
            threads_per_task: 1,
            walltime_limit_s: 3600,
        }
    }
}

/// Lifecycle states (Slurm-like subset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Failed,
    Timeout,
    /// Rejected at submission (bad partition, disabled account, …).
    Rejected,
    /// A node hosting the job failed mid-run; the job ends early and no
    /// application metrics are recorded (DESIGN.md §14 honesty contract).
    NodeFail,
    /// Preempted by the scheduler; the batch system requeues the job
    /// automatically under a fresh jobid (`requeued_as` in the result
    /// metrics points at it).
    Preempted,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Pending | JobState::Running)
    }

    pub fn name(&self) -> &'static str {
        match self {
            JobState::Pending => "PENDING",
            JobState::Running => "RUNNING",
            JobState::Completed => "COMPLETED",
            JobState::Failed => "FAILED",
            JobState::Timeout => "TIMEOUT",
            JobState::Rejected => "REJECTED",
            JobState::NodeFail => "NODE_FAIL",
            JobState::Preempted => "PREEMPTED",
        }
    }
}

/// What a payload reports back when the job runs.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Application wall-clock [s] (the Table-I `runtime`).
    pub duration_s: f64,
    pub success: bool,
    /// Benchmark-specific metrics (protocol `metrics` object).
    pub metrics: Json,
    /// Named output files the harness may analyse (`logmap.out`, …).
    pub files: Vec<(String, String)>,
}

impl JobResult {
    pub fn failure(msg: &str) -> JobResult {
        JobResult {
            duration_s: 0.0,
            success: false,
            metrics: Json::obj().set("error", msg),
            files: Vec::new(),
        }
    }
}

/// Context handed to the payload when the job starts.
#[derive(Debug, Clone)]
pub struct JobCtx {
    pub jobid: u64,
    pub start_time: SimTime,
    pub nodes: u64,
    pub tasks_per_node: u64,
    pub threads_per_task: u64,
    pub partition: String,
}

/// The payload executed when the job starts on the (simulated) nodes.
pub type JobPayload = Box<dyn FnOnce(&JobCtx) -> JobResult>;

/// Full accounting record of a job (the `sacct` view).
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub jobid: u64,
    pub spec: JobSpec,
    pub state: JobState,
    pub submit_time: SimTime,
    pub start_time: Option<SimTime>,
    pub end_time: Option<SimTime>,
    pub result: Option<JobResult>,
}

impl JobRecord {
    /// Core-hours consumed (accounting basis).
    pub fn core_hours(&self, cores_per_node: u64) -> f64 {
        match (self.start_time, self.end_time) {
            (Some(s), Some(e)) => {
                let secs = (e.0 - s.0).max(0) as f64;
                secs / 3600.0 * (self.spec.nodes * cores_per_node) as f64
            }
            _ => 0.0,
        }
    }

    pub fn queue_wait_s(&self) -> Option<i64> {
        self.start_time.map(|s| s.0 - self.submit_time.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminal_states() {
        assert!(!JobState::Pending.is_terminal());
        assert!(!JobState::Running.is_terminal());
        for s in [
            JobState::Completed,
            JobState::Failed,
            JobState::Timeout,
            JobState::Rejected,
            JobState::NodeFail,
            JobState::Preempted,
        ] {
            assert!(s.is_terminal());
        }
    }

    #[test]
    fn core_hours_accounting() {
        let rec = JobRecord {
            jobid: 1,
            spec: JobSpec {
                nodes: 4,
                ..Default::default()
            },
            state: JobState::Completed,
            submit_time: SimTime(0),
            start_time: Some(SimTime(100)),
            end_time: Some(SimTime(100 + 1800)),
            result: None,
        };
        // 0.5 h on 4 nodes x 128 cores = 256 core-hours
        assert!((rec.core_hours(128) - 256.0).abs() < 1e-9);
        assert_eq!(rec.queue_wait_s(), Some(100));
    }

    #[test]
    fn unstarted_job_costs_nothing() {
        let rec = JobRecord {
            jobid: 2,
            spec: JobSpec::default(),
            state: JobState::Rejected,
            submit_time: SimTime(0),
            start_time: None,
            end_time: None,
            result: None,
        };
        assert_eq!(rec.core_hours(128), 0.0);
        assert_eq!(rec.queue_wait_s(), None);
    }
}
