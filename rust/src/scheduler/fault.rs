//! Seeded fault model for the batch system (DESIGN.md §14).
//!
//! A [`FaultPlan`] is configured per machine and decides — as a *pure
//! function* of `(seed, machine, jobid)` — whether a starting job is
//! struck by a node failure or a preemption, and which fraction of its
//! runtime it completes before the strike. Outage and maintenance
//! windows are plain half-open time intervals; their boundaries are
//! timeline events dispatched through the scheduler's event heap so
//! `drive`/`drive_reference` replay byte-identically.
//!
//! Zero-rate plans with no windows are contractually inert: arming one
//! changes no byte of any timeline (asserted by
//! `tests/integration_chaos.rs` and the fault-model properties).

use crate::util::fnv1a;
use crate::util::prng::Prng;
use crate::util::timeutil::SimTime;

/// Half-open time window `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub start: SimTime,
    pub end: SimTime,
}

impl Window {
    pub fn new(start: SimTime, end: SimTime) -> Window {
        Window { start, end }
    }

    /// Convenience: `[day D at h0:00, day D at h1:00)`.
    pub fn on_day(day: i64, from_hour: i64, to_hour: i64) -> Window {
        Window {
            start: SimTime::from_days(day).add_secs(from_hour * 3600),
            end: SimTime::from_days(day).add_secs(to_hour * 3600),
        }
    }

    pub fn contains(&self, t: SimTime) -> bool {
        t >= self.start && t < self.end
    }
}

/// What kind of fault strikes a running job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The hosting node dies: the job ends early in
    /// [`crate::scheduler::job::JobState::NodeFail`] and its application
    /// metrics are discarded (honesty contract).
    NodeFail,
    /// The scheduler preempts the job and requeues it automatically
    /// under a fresh jobid carrying the *original* payload result, so a
    /// requeued measurement is byte-identical to an unpreempted one.
    Preempt,
}

/// A deterministic targeted fault: jobs whose name contains
/// `name_contains` and that start inside `window` are struck with
/// `kind`. Evaluated before the rate-based draw — this is how chaos
/// scenarios make one specific app flaky on an exact schedule.
#[derive(Debug, Clone)]
pub struct ForcedFault {
    pub name_contains: String,
    pub window: Window,
    pub kind: FaultKind,
}

/// The decision for one starting job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultDecision {
    pub kind: FaultKind,
    /// Fraction of the job's nominal duration completed before the
    /// strike, in `[0.1, 0.9]`.
    pub strike_frac: f64,
}

/// Per-machine seeded fault schedule.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    pub machine: String,
    /// Probability a starting job is struck by a node failure.
    pub node_fail_rate: f64,
    /// Probability a starting job is preempted (drawn after node-fail).
    pub preempt_rate: f64,
    /// Scheduler outages: submissions are rejected and the pending
    /// queues are frozen while the clock is inside one of these.
    pub outages: Vec<Window>,
    /// Maintenance windows: partitions drain — running jobs finish but
    /// no new job starts until the window closes.
    pub maintenance: Vec<Window>,
    pub forced: Vec<ForcedFault>,
}

impl FaultPlan {
    /// The inert plan: zero rates, no windows. Arming it is
    /// byte-identical to not arming any plan at all.
    pub fn quiet(machine: &str) -> FaultPlan {
        FaultPlan {
            seed: 0,
            machine: machine.to_string(),
            node_fail_rate: 0.0,
            preempt_rate: 0.0,
            outages: Vec::new(),
            maintenance: Vec::new(),
            forced: Vec::new(),
        }
    }

    pub fn seeded(machine: &str, seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            ..FaultPlan::quiet(machine)
        }
    }

    /// Decide the fate of a job starting at `start`. Pure in
    /// `(self.seed, self.machine, jobid)` — no shared PRNG state is
    /// consumed, so submission-order permutations cannot change any
    /// job's fate.
    pub fn decide(&self, jobid: u64, job_name: &str, start: SimTime) -> Option<FaultDecision> {
        for f in &self.forced {
            if f.window.contains(start) && job_name.contains(&f.name_contains) {
                // Deterministic mid-run strike for targeted faults.
                return Some(FaultDecision {
                    kind: f.kind,
                    strike_frac: 0.5,
                });
            }
        }
        if self.node_fail_rate <= 0.0 && self.preempt_rate <= 0.0 {
            return None;
        }
        let key = format!("{}|{jobid}", self.machine);
        let mut rng = Prng::new(self.seed ^ fnv1a(key.as_bytes()));
        // Fixed draw order keeps the schedule stable as rates vary.
        let node_fail = rng.bool_with(self.node_fail_rate);
        let preempt = rng.bool_with(self.preempt_rate);
        let strike_frac = rng.range_f64(0.1, 0.9);
        let kind = if node_fail {
            FaultKind::NodeFail
        } else if preempt {
            FaultKind::Preempt
        } else {
            return None;
        };
        Some(FaultDecision { kind, strike_frac })
    }

    pub fn in_outage(&self, t: SimTime) -> bool {
        self.outages.iter().any(|w| w.contains(t))
    }

    /// End of the outage covering `t`, if any (for deferred resubmit).
    pub fn outage_until(&self, t: SimTime) -> Option<SimTime> {
        self.outages.iter().find(|w| w.contains(t)).map(|w| w.end)
    }

    pub fn in_maintenance(&self, t: SimTime) -> bool {
        self.maintenance.iter().any(|w| w.contains(t))
    }

    /// Scheduling is frozen at `t` (outage or maintenance drain).
    pub fn frozen(&self, t: SimTime) -> bool {
        self.in_outage(t) || self.in_maintenance(t)
    }

    /// Earliest window boundary strictly after `t`: these are the
    /// timeline instants where scheduling eligibility flips, dispatched
    /// as events through the batch system's heap.
    pub fn next_boundary_after(&self, t: SimTime) -> Option<SimTime> {
        self.outages
            .iter()
            .chain(self.maintenance.iter())
            .flat_map(|w| [w.start, w.end])
            .filter(|b| *b > t)
            .min()
    }
}

/// Deterministic bounded backoff for retry-after-fault resubmissions:
/// a pure content hash of the retry context, mapped into
/// `[30 s, 300 s]`. No PRNG stream is consumed, so retries cannot
/// perturb measurement streams.
pub fn backoff_s(machine: &str, tag: &str, attempt: u32) -> i64 {
    let key = format!("backoff|{machine}|{tag}|{attempt}");
    30 + (fnv1a(key.as_bytes()) % 271) as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan {
            seed: 42,
            node_fail_rate: 0.2,
            preempt_rate: 0.2,
            ..FaultPlan::quiet("jedi")
        }
    }

    #[test]
    fn decisions_are_pure_per_jobid() {
        let p = plan();
        for jobid in 7_700_000..7_700_200 {
            let a = p.decide(jobid, "app", SimTime(0));
            let b = p.decide(jobid, "app", SimTime(86_400));
            assert_eq!(a, b, "rate-based decision must not depend on time");
        }
    }

    #[test]
    fn decisions_vary_with_seed_and_machine() {
        let a = plan();
        let b = FaultPlan { seed: 43, ..plan() };
        let c = FaultPlan {
            machine: "ant".into(),
            ..plan()
        };
        let differs = |x: &FaultPlan, y: &FaultPlan| {
            (7_700_000..7_700_400).any(|j| x.decide(j, "app", SimTime(0)) != y.decide(j, "app", SimTime(0)))
        };
        assert!(differs(&a, &b), "seed must reshape the schedule");
        assert!(differs(&a, &c), "machine must reshape the schedule");
    }

    #[test]
    fn quiet_plan_never_strikes() {
        let p = FaultPlan::quiet("jedi");
        assert!((0..500).all(|j| p.decide(7_700_000 + j, "app", SimTime(0)).is_none()));
        assert!(!p.frozen(SimTime(0)));
        assert_eq!(p.next_boundary_after(SimTime(0)), None);
    }

    #[test]
    fn forced_faults_match_name_and_window() {
        let mut p = FaultPlan::quiet("jedi");
        p.forced.push(ForcedFault {
            name_contains: "lmp".into(),
            window: Window::on_day(3, 0, 24),
            kind: FaultKind::NodeFail,
        });
        let inside = SimTime::from_days(3).add_secs(3600);
        let outside = SimTime::from_days(4).add_secs(3600);
        assert_eq!(
            p.decide(1, "exacb-lmp-execute", inside).map(|d| d.kind),
            Some(FaultKind::NodeFail)
        );
        assert!(p.decide(1, "exacb-gromacs-execute", inside).is_none());
        assert!(p.decide(1, "exacb-lmp-execute", outside).is_none());
    }

    #[test]
    fn windows_are_half_open() {
        let w = Window::on_day(2, 1, 4);
        assert!(!w.contains(SimTime::from_days(2)));
        assert!(w.contains(w.start));
        assert!(!w.contains(w.end));
    }

    #[test]
    fn boundary_scan_finds_next_flip() {
        let mut p = FaultPlan::quiet("jedi");
        p.outages.push(Window::on_day(1, 2, 5));
        p.maintenance.push(Window::on_day(1, 4, 6));
        let t0 = SimTime::from_days(1);
        let b1 = p.next_boundary_after(t0).unwrap();
        assert_eq!(b1, SimTime::from_days(1).add_secs(2 * 3600));
        let b2 = p.next_boundary_after(b1).unwrap();
        assert_eq!(b2, SimTime::from_days(1).add_secs(4 * 3600));
        assert!(p.frozen(SimTime::from_days(1).add_secs(3 * 3600)));
        assert!(!p.frozen(SimTime::from_days(1).add_secs(7 * 3600)));
    }

    #[test]
    fn backoff_is_bounded_and_deterministic() {
        let a = backoff_s("jedi", "step", 0);
        assert_eq!(a, backoff_s("jedi", "step", 0));
        assert!((30..=300).contains(&a));
        assert_ne!(a, backoff_s("jedi", "step", 1));
    }
}
