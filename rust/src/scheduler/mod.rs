//! Slurm-like batch scheduling substrate (paper §IV-D: "JUBE's Slurm
//! integration"; DESIGN.md §2).
//!
//! * [`job`] — specs, states, payloads, accounting records.
//! * [`accounts`] — compute projects and core-hour budgets.
//! * [`slurm`] — the discrete-event FIFO+backfill scheduler.
//! * [`fault`] — seeded fault plans: node failures, preemption,
//!   outage and maintenance windows (DESIGN.md §14).

pub mod accounts;
pub mod fault;
pub mod job;
pub mod slurm;

pub use accounts::{Account, AccountError, AccountManager, Budget};
pub use fault::{backoff_s, FaultDecision, FaultKind, FaultPlan, ForcedFault, Window};
pub use job::{JobCtx, JobPayload, JobRecord, JobResult, JobSpec, JobState};
pub use slurm::{for_machine, BatchSystem, SubmitError};
