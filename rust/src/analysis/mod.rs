//! Data analysis (paper §IV-F; top layer in the DESIGN.md §1 module
//! map): "to decouple execution and data acquisition from evaluation,
//! exaCB provides dedicated CI jobs for data analysis" — these are the
//! analytics those jobs run. Everything consumes protocol
//! [`crate::protocol::Report`]s, so the pipeline "can also be applied
//! outside of a full exaCB workflow".
//!
//! * [`dataset`] — loading/filtering report sets, series extraction.
//! * [`timeseries`] — Figs. 3–4: daily series + changepoint detection.
//! * [`scaling`] — Figs. 5 & 7: strong/weak scaling with guide bands.
//! * [`energy`] — Fig. 9: energy-vs-frequency sweet spots.

pub mod dataset;
pub mod energy;
pub mod scaling;
pub mod timeseries;

pub use dataset::ReportSet;
pub use energy::{energy_sweep_plot, EnergySweep};
pub use scaling::{machine_comparison_plot, weak_scaling_plot, StrongScaling, WeakScaling};
pub use timeseries::{analyse, SeriesAnalysis};
