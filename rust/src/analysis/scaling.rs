//! Scaling analysis (Figs. 5 & 7): strong-scaling speedup/efficiency with
//! ideal-scaling guide bands, and weak-scaling efficiency.

use super::dataset::ReportSet;
use crate::util::plot::{Band, Plot, Series};

/// One system's strong-scaling curve.
#[derive(Debug, Clone)]
pub struct StrongScaling {
    pub system: String,
    /// (nodes, median runtime)
    pub runtimes: Vec<(u64, f64)>,
    /// (nodes, speedup vs smallest node count)
    pub speedups: Vec<(u64, f64)>,
    /// (nodes, parallel efficiency vs smallest node count)
    pub efficiencies: Vec<(u64, f64)>,
}

impl StrongScaling {
    pub fn from_set(set: &ReportSet, system: &str, metric: &str) -> Option<StrongScaling> {
        let runtimes = set.filter_system(system).nodes_medians(metric);
        if runtimes.len() < 2 {
            return None;
        }
        let (n0, t0) = runtimes[0];
        let speedups: Vec<(u64, f64)> = runtimes
            .iter()
            .map(|&(n, t)| (n, t0 / t * 1.0_f64.max(1.0)))
            .collect();
        let efficiencies = runtimes
            .iter()
            .map(|&(n, t)| (n, (t0 * n0 as f64) / (t * n as f64)))
            .collect();
        Some(StrongScaling {
            system: system.to_string(),
            runtimes,
            speedups,
            efficiencies,
        })
    }

    /// Largest node count still at or above the given efficiency (the
    /// "80% scaling regime" boundary of Fig. 5).
    pub fn scaling_limit(&self, efficiency: f64) -> Option<u64> {
        self.efficiencies
            .iter()
            .filter(|(_, e)| *e >= efficiency)
            .map(|(n, _)| *n)
            .max()
    }
}

/// Fig. 5: runtime vs nodes for several systems, log-log, with the ideal
/// and `band_pct`% scaling band anchored on each system's first point.
/// `halve` lists systems whose runtime is halved "for easier
/// comparability" (the paper does this for the Ampere result).
pub fn machine_comparison_plot(
    set: &ReportSet,
    systems: &[String],
    metric: &str,
    band_pct: f64,
    halve: &[String],
) -> Plot {
    let mut p = Plot::new(
        "Strong scaling comparison (Fig. 5)",
        "nodes",
        "time to solution [s]",
    )
    .logx()
    .logy();
    for system in systems {
        let Some(s) = StrongScaling::from_set(set, system, metric) else {
            continue;
        };
        let factor = if halve.contains(system) { 0.5 } else { 1.0 };
        let pts: Vec<(f64, f64)> = s
            .runtimes
            .iter()
            .map(|&(n, t)| (n as f64, t * factor))
            .collect();
        let label = if factor != 1.0 {
            format!("{system} (/2)")
        } else {
            system.clone()
        };
        // guide band: ideal scaling and band_pct% of ideal from this curve
        if let Some(&(n0, t0)) = s.runtimes.first() {
            let t0 = t0 * factor;
            let upper: Vec<(f64, f64)> = s
                .runtimes
                .iter()
                .map(|&(n, _)| {
                    let ideal = t0 * n0 as f64 / n as f64;
                    (n as f64, ideal / (band_pct / 100.0))
                })
                .collect();
            let lower: Vec<(f64, f64)> = s
                .runtimes
                .iter()
                .map(|&(n, _)| (n as f64, t0 * n0 as f64 / n as f64))
                .collect();
            p.add_band(Band {
                name: format!("{system} {band_pct:.0}% band"),
                upper,
                lower,
            });
        }
        p.add(Series::new(&label, pts));
    }
    p
}

/// One weak-scaling curve: (nodes, efficiency) with t(1 node) reference.
#[derive(Debug, Clone)]
pub struct WeakScaling {
    pub label: String,
    pub runtimes: Vec<(u64, f64)>,
    pub efficiencies: Vec<(u64, f64)>,
}

impl WeakScaling {
    /// Weak-scaling efficiency: t(n0)/t(n) (perfect = 1.0, workload per
    /// node constant).
    pub fn from_set(set: &ReportSet, label: &str, metric: &str) -> Option<WeakScaling> {
        let runtimes = set.nodes_medians(metric);
        if runtimes.len() < 2 {
            return None;
        }
        let t0 = runtimes[0].1;
        let efficiencies = runtimes.iter().map(|&(n, t)| (n, t0 / t)).collect();
        Some(WeakScaling {
            label: label.to_string(),
            runtimes,
            efficiencies,
        })
    }
}

/// Fig. 7: weak-scaling efficiency for multiple software stages.
pub fn weak_scaling_plot(curves: &[WeakScaling]) -> Plot {
    let mut p = Plot::new(
        "Weak scaling across software stages (Fig. 7)",
        "nodes",
        "weak-scaling efficiency",
    )
    .logx();
    for c in curves {
        p.add(Series::new(
            &c.label,
            c.efficiencies
                .iter()
                .map(|&(n, e)| (n as f64, e))
                .collect(),
        ));
    }
    p
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{synthetic_report, ReportSet};
    use super::*;

    /// Amdahl-ish runtime generator.
    fn scaling_set(system: &str, t1: f64, serial: f64) -> ReportSet {
        ReportSet::from_reports(
            [1u64, 2, 4, 8, 16, 32]
                .iter()
                .map(|&n| {
                    let t = t1 * (serial + (1.0 - serial) / n as f64);
                    synthetic_report(system, 1, 1, &[(n, t, true)], &[])
                })
                .collect(),
        )
    }

    #[test]
    fn strong_scaling_math() {
        let set = scaling_set("jedi", 100.0, 0.02);
        let s = StrongScaling::from_set(&set, "jedi", "runtime").unwrap();
        assert_eq!(s.speedups[0], (1, 1.0));
        let (n, sp) = *s.speedups.last().unwrap();
        assert_eq!(n, 32);
        assert!(sp > 16.0 && sp < 32.0, "sp={sp}");
        // efficiency monotonically decays
        for w in s.efficiencies.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9);
        }
    }

    #[test]
    fn scaling_limit_finds_80pct_boundary() {
        let set = scaling_set("jedi", 100.0, 0.02);
        let s = StrongScaling::from_set(&set, "jedi", "runtime").unwrap();
        let limit = s.scaling_limit(0.8).unwrap();
        // with 2% serial fraction, 80% efficiency is lost somewhere
        // between 8 and 16 nodes: eff(8)=0.88, eff(16)=0.78
        assert_eq!(limit, 8, "{:?}", s.efficiencies);
    }

    #[test]
    fn comparison_plot_has_bands_and_halving() {
        let mut set = scaling_set("jedi", 40.0, 0.02);
        set.reports
            .extend(scaling_set("juwels-booster", 130.0, 0.02).reports);
        let p = machine_comparison_plot(
            &set,
            &["jedi".into(), "juwels-booster".into()],
            "runtime",
            80.0,
            &["juwels-booster".into()],
        );
        assert_eq!(p.series.len(), 2);
        assert_eq!(p.bands.len(), 2);
        assert!(p.series.iter().any(|s| s.name == "juwels-booster (/2)"));
        // halved: first point of the booster curve is 65
        let booster = p
            .series
            .iter()
            .find(|s| s.name.contains("booster"))
            .unwrap();
        assert!((booster.points[0].1 - 65.0).abs() < 1e-9);
    }

    #[test]
    fn weak_scaling_efficiency() {
        // runtime grows slowly with nodes under weak scaling
        let set = ReportSet::from_reports(
            [1u64, 2, 4, 8, 16]
                .iter()
                .map(|&n| {
                    let t = 100.0 * (1.0 + 0.03 * (n as f64).log2());
                    synthetic_report("jedi", 1, 1, &[(n, t, true)], &[])
                })
                .collect(),
        );
        let w = WeakScaling::from_set(&set, "stage 2026", "runtime").unwrap();
        assert!((w.efficiencies[0].1 - 1.0).abs() < 1e-9);
        let last = w.efficiencies.last().unwrap().1;
        assert!(last < 1.0 && last > 0.8, "{last}");
        let p = weak_scaling_plot(&[w]);
        assert_eq!(p.series.len(), 1);
    }

    #[test]
    fn insufficient_data_is_none() {
        let set = ReportSet::from_reports(vec![synthetic_report(
            "jedi",
            1,
            1,
            &[(1, 10.0, true)],
            &[],
        )]);
        assert!(StrongScaling::from_set(&set, "jedi", "runtime").is_none());
        assert!(StrongScaling::from_set(&set, "ghost", "runtime").is_none());
    }
}
