//! Report datasets: loading, filtering, and series extraction.
//!
//! The analysis CI jobs (§IV-F) consume protocol documents from the
//! `exacb.data` branch (or injected externally) and need uniform slicing:
//! by prefix, pipeline, time span, system — then extraction of
//! (x, metric) series. All figures' data flows through this module.

use crate::protocol::Report;
use crate::store::{DataStore, Snapshot};
use crate::util::timeutil::SimTime;

/// A set of reports with their store paths.
#[derive(Debug, Clone, Default)]
pub struct ReportSet {
    pub reports: Vec<(String, Report)>,
}

impl ReportSet {
    /// Load every parseable report under `prefix` on the `exacb.data`
    /// branch. Only `.json` documents are considered (the branch also
    /// carries `results.csv` artifacts); unparseable documents are
    /// skipped (robustness against partial generation) but counted.
    ///
    /// This is the legacy full-walk path, retained as the executable
    /// differential reference for [`ReportSet::from_snapshot`] — hot
    /// consumers (post-processing tables, energy scans) read via the
    /// snapshot.
    pub fn load(store: &DataStore, branch: &str, prefix: &str) -> (ReportSet, usize) {
        let mut set = ReportSet::default();
        let mut skipped = 0;
        for (path, content) in store.read_all_iter(branch, prefix) {
            if !path.ends_with(".json") {
                continue;
            }
            match Report::parse(content) {
                Ok(r) => set.reports.push((path.to_string(), r)),
                Err(_) => skipped += 1,
            }
        }
        set.reports.sort_by(|a, b| a.0.cmp(&b.0));
        (set, skipped)
    }

    /// Load every parseable report under `prefix` from a [`Snapshot`] —
    /// same paths, same order, same skip count as [`ReportSet::load`]
    /// (differentially tested byte-identical), but each document was
    /// parsed exactly once, at snapshot build time.
    pub fn from_snapshot(snap: &Snapshot, prefix: &str) -> (ReportSet, usize) {
        let mut set = ReportSet::default();
        let mut skipped = 0;
        // paths_under iterates in path order, so no sort is needed
        for (path, digest) in snap.paths_under(prefix) {
            if !path.ends_with(".json") {
                continue;
            }
            match snap.doc(digest).and_then(|d| d.report.as_ref()) {
                Some(r) => set.reports.push((path.to_string(), r.clone())),
                None => skipped += 1,
            }
        }
        (set, skipped)
    }

    pub fn from_reports(reports: Vec<Report>) -> ReportSet {
        ReportSet {
            reports: reports.into_iter().map(|r| (String::new(), r)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.reports.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reports.is_empty()
    }

    /// Keep reports whose pipeline id is in `pipelines` (empty = all).
    pub fn filter_pipelines(&self, pipelines: &[u64]) -> ReportSet {
        if pipelines.is_empty() {
            return self.clone();
        }
        ReportSet {
            reports: self
                .reports
                .iter()
                .filter(|(_, r)| pipelines.contains(&r.reporter.pipeline_id))
                .cloned()
                .collect(),
        }
    }

    /// Keep reports whose experiment timestamp lies in [from, to].
    pub fn filter_time_span(&self, from: Option<SimTime>, to: Option<SimTime>) -> ReportSet {
        ReportSet {
            reports: self
                .reports
                .iter()
                .filter(|(_, r)| {
                    let Some(t) = r.experiment.time() else {
                        return false;
                    };
                    from.map(|f| t >= f).unwrap_or(true) && to.map(|e| t <= e).unwrap_or(true)
                })
                .cloned()
                .collect(),
        }
    }

    /// Keep reports for one system.
    pub fn filter_system(&self, system: &str) -> ReportSet {
        ReportSet {
            reports: self
                .reports
                .iter()
                .filter(|(_, r)| r.experiment.system == system)
                .cloned()
                .collect(),
        }
    }

    /// Distinct systems present, sorted.
    pub fn systems(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .reports
            .iter()
            .map(|(_, r)| r.experiment.system.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Extract a (time, metric) series across reports: one point per
    /// successful data entry carrying the metric, ordered by time.
    /// `runtime` is always available as a pseudo-metric.
    pub fn time_series(&self, metric: &str) -> Vec<(SimTime, f64)> {
        let mut pts = Vec::new();
        for (_, r) in &self.reports {
            let Some(t) = r.experiment.time() else {
                continue;
            };
            for e in &r.data {
                if !e.success {
                    continue;
                }
                let v = if metric == "runtime" {
                    Some(e.runtime)
                } else {
                    e.metric(metric)
                };
                if let Some(v) = v {
                    pts.push((t, v));
                }
            }
        }
        pts.sort_by_key(|(t, _)| *t);
        pts
    }

    /// Extract (nodes, metric) points across successful entries.
    pub fn nodes_series(&self, metric: &str) -> Vec<(u64, f64)> {
        let mut pts = Vec::new();
        for (_, r) in &self.reports {
            for e in &r.data {
                if !e.success {
                    continue;
                }
                let v = if metric == "runtime" {
                    Some(e.runtime)
                } else {
                    e.metric(metric)
                };
                if let Some(v) = v {
                    pts.push((e.nodes, v));
                }
            }
        }
        pts.sort_by_key(|(n, _)| *n);
        pts
    }

    /// Median metric value per node count (collapses repeats).
    pub fn nodes_medians(&self, metric: &str) -> Vec<(u64, f64)> {
        let pts = self.nodes_series(metric);
        let mut out: Vec<(u64, f64)> = Vec::new();
        let mut i = 0;
        while i < pts.len() {
            let n = pts[i].0;
            let vals: Vec<f64> = pts
                .iter()
                .filter(|(m, _)| *m == n)
                .map(|(_, v)| *v)
                .collect();
            out.push((n, crate::util::stats::median(&vals)));
            i += vals.len();
        }
        out
    }

    /// Success-rate summary: (successful entries, total entries).
    pub fn success_counts(&self) -> (usize, usize) {
        let mut ok = 0;
        let mut total = 0;
        for (_, r) in &self.reports {
            for e in &r.data {
                total += 1;
                if e.success {
                    ok += 1;
                }
            }
        }
        (ok, total)
    }
}

#[cfg(test)]
pub(crate) fn synthetic_report(
    system: &str,
    day: i64,
    pipeline: u64,
    entries: &[(u64, f64, bool)], // (nodes, runtime, success)
    metrics: &[(&str, f64)],
) -> Report {
    use crate::protocol::{DataEntry, Experiment, Reporter};
    use crate::util::json::Json;
    Report {
        reporter: Reporter {
            tool: "exacb".into(),
            tool_version: "0.1".into(),
            pipeline_id: pipeline,
            system: system.into(),
            timestamp: SimTime::from_days(day).iso8601(),
            ..Default::default()
        },
        parameter: Json::obj(),
        experiment: Experiment {
            system: system.into(),
            timestamp: SimTime::from_days(day).iso8601(),
            ..Default::default()
        },
        data: entries
            .iter()
            .map(|&(nodes, runtime, success)| {
                let mut m = Json::obj();
                for (k, v) in metrics {
                    m.insert(k, *v);
                }
                DataEntry {
                    success,
                    runtime,
                    nodes,
                    metrics: m,
                    ..Default::default()
                }
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_set() -> ReportSet {
        ReportSet::from_reports(vec![
            synthetic_report("jedi", 1, 100, &[(1, 10.0, true)], &[("bw", 5.0)]),
            synthetic_report("jedi", 2, 101, &[(2, 6.0, true)], &[("bw", 5.1)]),
            synthetic_report("jureca", 2, 102, &[(2, 12.0, true)], &[("bw", 2.0)]),
            synthetic_report("jedi", 3, 103, &[(4, 4.0, false)], &[("bw", 0.0)]),
        ])
    }

    #[test]
    fn filters_compose() {
        let set = sample_set();
        assert_eq!(set.filter_system("jedi").len(), 3);
        assert_eq!(set.filter_pipelines(&[101, 102]).len(), 2);
        assert_eq!(
            set.filter_time_span(Some(SimTime::from_days(2)), None).len(),
            3
        );
        assert_eq!(
            set.filter_time_span(Some(SimTime::from_days(2)), Some(SimTime::from_days(2)))
                .len(),
            2
        );
        assert_eq!(set.systems(), vec!["jedi", "jureca"]);
    }

    #[test]
    fn series_skip_failures() {
        let set = sample_set();
        let ts = set.time_series("bw");
        assert_eq!(ts.len(), 3); // failed day-3 entry skipped
        let ns = set.filter_system("jedi").nodes_series("runtime");
        assert_eq!(ns, vec![(1, 10.0), (2, 6.0)]);
    }

    #[test]
    fn medians_collapse_repeats() {
        let set = ReportSet::from_reports(vec![
            synthetic_report("s", 1, 1, &[(1, 10.0, true), (1, 14.0, true), (1, 12.0, true)], &[]),
            synthetic_report("s", 1, 1, &[(2, 5.0, true)], &[]),
        ]);
        assert_eq!(set.nodes_medians("runtime"), vec![(1, 12.0), (2, 5.0)]);
    }

    #[test]
    fn load_skips_garbage(){
        let mut store = DataStore::new();
        let good = synthetic_report("jedi", 1, 1, &[(1, 1.0, true)], &[]);
        store.commit(
            "exacb.data",
            &[
                ("p/a.json".into(), good.to_document()),
                ("p/bad.json".into(), "{not json".into()),
                ("q/other.json".into(), good.to_document()),
            ],
            "m",
            SimTime(0),
        );
        let (set, skipped) = ReportSet::load(&store, "exacb.data", "p/");
        assert_eq!(set.len(), 1);
        assert_eq!(skipped, 1);
    }

    #[test]
    fn success_counts() {
        let (ok, total) = sample_set().success_counts();
        assert_eq!((ok, total), (3, 4));
    }
}
