//! Time-series analysis (Figs. 3–4): daily metric series, stability
//! assessment, and regression/recovery detection.

use super::dataset::ReportSet;
use crate::util::plot::{Plot, Series};
use crate::util::stats::{self, Changepoint};
use crate::util::timeutil::SimTime;

/// One analysed metric series.
#[derive(Debug, Clone)]
pub struct SeriesAnalysis {
    pub label: String,
    pub points: Vec<(SimTime, f64)>,
    pub mean: f64,
    /// Coefficient of variation (sd/mean).
    pub cv: f64,
    pub changepoints: Vec<Changepoint>,
}

impl SeriesAnalysis {
    /// "Stable" series: no detected level shifts and small variation —
    /// Fig. 3's BabelStream verdict.
    pub fn is_stable(&self) -> bool {
        self.changepoints.is_empty() && self.cv < 0.03
    }
}

/// Analyse one metric label over a report set.
pub fn analyse(set: &ReportSet, label: &str, threshold_sd: f64) -> SeriesAnalysis {
    let points = set.time_series(label);
    let values: Vec<f64> = points.iter().map(|(_, v)| *v).collect();
    let s = stats::summary(&values);
    SeriesAnalysis {
        label: label.to_string(),
        points,
        mean: s.mean,
        cv: if s.mean.abs() > 0.0 { s.sd / s.mean } else { f64::NAN },
        changepoints: stats::changepoints(&values, threshold_sd),
    }
}

/// The time-series component's plot: one series per data label, x in
/// days since epoch (rendered as dates by the caller).
pub fn plot(
    title: &str,
    ylabel: &str,
    analyses: &[SeriesAnalysis],
    plot_labels: &[String],
) -> Plot {
    let mut p = Plot::new(title, "date", ylabel);
    for (i, a) in analyses.iter().enumerate() {
        let name = plot_labels
            .get(i)
            .cloned()
            .unwrap_or_else(|| a.label.clone());
        p.add(Series::new(
            &name,
            a.points
                .iter()
                .map(|(t, v)| (t.day() as f64, *v))
                .collect(),
        ));
    }
    // mark detected changepoints as vertical guides
    for a in analyses {
        for cp in &a.changepoints {
            if let Some((t, _)) = a.points.get(cp.index) {
                let kind = if cp.after < cp.before { "regression" } else { "recovery" };
                p.add_vmark(t.day() as f64, kind);
            }
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{synthetic_report, ReportSet};
    use super::*;

    fn daily_series(f: impl Fn(i64) -> f64, days: i64) -> ReportSet {
        ReportSet::from_reports(
            (0..days)
                .map(|d| {
                    synthetic_report(
                        "jupiter",
                        d,
                        100 + d as u64,
                        &[(1, 10.0, true)],
                        &[("bw", f(d))],
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn stable_series_fig3() {
        // BabelStream-like: flat with small wiggle
        let set = daily_series(|d| 3_400_000.0 * (1.0 + 0.003 * ((d % 5) as f64 - 2.0)), 90);
        let a = analyse(&set, "bw", 8.0);
        assert!(a.is_stable(), "cv={} cps={:?}", a.cv, a.changepoints);
        assert_eq!(a.points.len(), 90);
    }

    #[test]
    fn regression_recovery_fig4() {
        // Graph500-like: dip between day 30 and 60
        let set = daily_series(
            |d| {
                let base = if (30..60).contains(&d) { 0.72 } else { 1.0 };
                2.0e9 * base * (1.0 + 0.004 * ((d % 7) as f64 - 3.0))
            },
            90,
        );
        let a = analyse(&set, "bw", 8.0);
        assert!(!a.is_stable());
        assert!(a.changepoints.len() >= 2, "{:?}", a.changepoints);
        let down = a.changepoints.iter().find(|c| c.after < c.before).unwrap();
        let up = a.changepoints.iter().find(|c| c.after > c.before).unwrap();
        assert!((28..=32).contains(&down.index), "down at {}", down.index);
        assert!((58..=62).contains(&up.index), "up at {}", up.index);
    }

    #[test]
    fn plot_carries_series_and_marks() {
        let set = daily_series(
            |d| if (30..60).contains(&d) { 7.0 } else { 10.0 } + 0.01 * (d % 3) as f64,
            90,
        );
        let a = analyse(&set, "bw", 8.0);
        let p = plot("ts", "y", &[a], &["Copy kernel".to_string()]);
        assert_eq!(p.series.len(), 1);
        assert_eq!(p.series[0].name, "Copy kernel");
        assert!(p.vmarks.len() >= 2);
        assert!(p.render_svg().contains("regression"));
    }

    #[test]
    fn empty_set_analyses_cleanly() {
        let set = ReportSet::default();
        let a = analyse(&set, "bw", 8.0);
        assert!(a.points.is_empty());
        assert!(a.mean.is_nan());
    }
}
