//! Energy analysis (Fig. 9): energy-to-solution vs operating frequency,
//! sweet-spot identification across applications.

use super::dataset::ReportSet;
use crate::util::plot::{Plot, Series};

/// One application's frequency sweep.
#[derive(Debug, Clone)]
pub struct EnergySweep {
    pub app: String,
    /// (freq MHz, energy J) sorted by frequency.
    pub points: Vec<(f64, f64)>,
    /// The energy-minimising frequency.
    pub sweet_spot_mhz: f64,
    /// Energy saving at the sweet spot vs nominal (fraction, e.g. 0.18).
    pub saving_vs_nominal: f64,
}

impl EnergySweep {
    /// Build from reports carrying `freq_mhz` and `energy_j` metrics.
    pub fn from_set(set: &ReportSet, app: &str) -> Option<EnergySweep> {
        let mut points: Vec<(f64, f64)> = Vec::new();
        for (_, r) in &set.reports {
            for e in &r.data {
                if !e.success {
                    continue;
                }
                if let (Some(f), Some(en)) = (e.metric("freq_mhz"), e.metric("energy_j")) {
                    points.push((f, en));
                }
            }
        }
        if points.len() < 3 {
            return None;
        }
        points.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // collapse duplicate frequencies by median
        let mut collapsed: Vec<(f64, f64)> = Vec::new();
        let mut i = 0;
        while i < points.len() {
            let f = points[i].0;
            let vals: Vec<f64> = points
                .iter()
                .filter(|(g, _)| (*g - f).abs() < 0.5)
                .map(|(_, e)| *e)
                .collect();
            collapsed.push((f, crate::util::stats::median(&vals)));
            i += vals.len();
        }
        let (spot, e_min) = collapsed
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        let e_nominal = collapsed.last()?.1;
        Some(EnergySweep {
            app: app.to_string(),
            points: collapsed,
            sweet_spot_mhz: spot,
            saving_vs_nominal: 1.0 - e_min / e_nominal,
        })
    }
}

/// Fig. 9: energy vs frequency for several applications, sweet spots
/// marked with vertical guides.
pub fn energy_sweep_plot(sweeps: &[EnergySweep]) -> Plot {
    let mut p = Plot::new(
        "Energy-to-solution vs GPU frequency (Fig. 9)",
        "GPU frequency [MHz]",
        "energy to solution [J]",
    );
    for s in sweeps {
        p.add(Series::new(&s.app, s.points.clone()));
        p.add_vmark(s.sweet_spot_mhz, &format!("{} sweet spot", s.app));
    }
    p
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{synthetic_report, ReportSet};
    use super::*;

    fn sweep_set(app_bias: f64) -> ReportSet {
        // bowl with minimum at 900 + bias
        let reports = (0..12)
            .map(|i| {
                let f = 400.0 + i as f64 * 140.0;
                let e = 1000.0 + 0.002 * (f - (900.0 + app_bias)).powi(2);
                synthetic_report(
                    "jedi",
                    1,
                    1,
                    &[(1, 100.0, true)],
                    &[("freq_mhz", f), ("energy_j", e)],
                )
            })
            .collect();
        ReportSet::from_reports(reports)
    }

    #[test]
    fn finds_sweet_spot() {
        let s = EnergySweep::from_set(&sweep_set(0.0), "appA").unwrap();
        assert!(
            (s.sweet_spot_mhz - 960.0).abs() < 150.0,
            "spot={}",
            s.sweet_spot_mhz
        );
        assert!(s.saving_vs_nominal > 0.1, "{}", s.saving_vs_nominal);
    }

    #[test]
    fn different_apps_have_different_spots() {
        // Fig. 9 shows two applications with distinct sweet spots
        let a = EnergySweep::from_set(&sweep_set(0.0), "appA").unwrap();
        let b = EnergySweep::from_set(&sweep_set(400.0), "appB").unwrap();
        assert!(b.sweet_spot_mhz > a.sweet_spot_mhz);
        let p = energy_sweep_plot(&[a, b]);
        assert_eq!(p.series.len(), 2);
        assert_eq!(p.vmarks.len(), 2);
    }

    #[test]
    fn too_few_points_is_none() {
        let set = ReportSet::from_reports(vec![synthetic_report(
            "jedi",
            1,
            1,
            &[(1, 1.0, true)],
            &[("freq_mhz", 900.0), ("energy_j", 5.0)],
        )]);
        assert!(EnergySweep::from_set(&set, "x").is_none());
    }
}
