//! Energy analysis (Fig. 9; DESIGN.md §11): energy-to-solution vs
//! operating frequency, sweet-spot identification across applications,
//! and the energy-delay product the collection-wide studies rank by.

use super::dataset::ReportSet;
use crate::util::plot::{Plot, Series};

/// One application's frequency sweep.
#[derive(Debug, Clone)]
pub struct EnergySweep {
    pub app: String,
    /// (freq MHz, energy J) sorted by frequency.
    pub points: Vec<(f64, f64)>,
    /// (freq MHz, median runtime s), aligned with `points`.
    pub runtimes: Vec<(f64, f64)>,
    /// (freq MHz, energy-delay product J·s), aligned with `points`.
    pub edp: Vec<(f64, f64)>,
    /// The energy-minimising frequency among *interior* sweep points —
    /// a minimum on the sweep boundary is un-bracketed and therefore
    /// never called a sweet spot (Fig. 9's spots are interior by
    /// construction).
    pub sweet_spot_mhz: f64,
    /// The EDP-minimising frequency (boundary allowed: EDP ranks
    /// operating points, it does not claim a bracketed bowl).
    pub edp_spot_mhz: f64,
    /// Energy saving at the sweet spot vs the highest swept frequency
    /// (fraction, e.g. 0.18). **Signed**: when the true minimum sits on
    /// the nominal boundary, the best interior point costs energy and
    /// this is negative — surfaced honestly instead of implying a
    /// saving.
    pub saving_vs_nominal: f64,
}

impl EnergySweep {
    /// Build from reports carrying `freq_mhz` and `energy_j` metrics.
    ///
    /// Only reports recorded under `app` (first store-path segment equal
    /// to `app` or extending it as `app.…`, e.g. the per-frequency
    /// `app.f800` prefixes) contribute: a multi-application set used to
    /// silently mix every application into one sweep. Reports without a
    /// store path (injected sets) are trusted to be pre-selected.
    pub fn from_set(set: &ReportSet, app: &str) -> Option<EnergySweep> {
        let mut triples: Vec<(f64, f64, f64)> = Vec::new();
        let dotted = format!("{app}.");
        for (path, r) in &set.reports {
            if !path.is_empty() {
                let seg = path.split('/').next().unwrap_or("");
                if seg != app && !seg.starts_with(&dotted) {
                    continue;
                }
            }
            for e in &r.data {
                if !e.success {
                    continue;
                }
                if let (Some(f), Some(en)) = (e.metric("freq_mhz"), e.metric("energy_j")) {
                    if f.is_finite() && en.is_finite() {
                        triples.push((f, en, e.runtime));
                    }
                }
            }
        }
        if triples.len() < 3 {
            return None;
        }
        triples.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // collapse duplicate frequencies by median
        let mut points: Vec<(f64, f64)> = Vec::new();
        let mut runtimes: Vec<(f64, f64)> = Vec::new();
        let mut i = 0;
        while i < triples.len() {
            let f = triples[i].0;
            let group: Vec<&(f64, f64, f64)> = triples
                .iter()
                .filter(|(g, _, _)| (*g - f).abs() < 0.5)
                .collect();
            let es: Vec<f64> = group.iter().map(|(_, e, _)| *e).collect();
            let ts: Vec<f64> = group.iter().map(|(_, _, t)| *t).collect();
            points.push((f, crate::util::stats::median(&es)));
            runtimes.push((f, crate::util::stats::median(&ts)));
            i += group.len();
        }
        if points.len() < 3 {
            // duplicate-frequency repetitions collapsed below a sweep
            return None;
        }
        let edp: Vec<(f64, f64)> = points
            .iter()
            .zip(&runtimes)
            .map(|(&(f, e), &(_, t))| (f, e * t))
            .collect();
        // sweet spot: best *interior* point (endpoints are un-bracketed)
        let interior = &points[1..points.len() - 1];
        let (spot, e_spot) = interior
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        let (edp_spot, _) = edp
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        let e_nominal = points.last()?.1;
        Some(EnergySweep {
            app: app.to_string(),
            points,
            runtimes,
            edp,
            sweet_spot_mhz: spot,
            edp_spot_mhz: edp_spot,
            saving_vs_nominal: 1.0 - e_spot / e_nominal.max(1e-300),
        })
    }

    /// Median energy at the sweet spot [J].
    pub fn energy_at_spot_j(&self) -> f64 {
        self.points
            .iter()
            .find(|(f, _)| (*f - self.sweet_spot_mhz).abs() < 0.5)
            .map(|(_, e)| *e)
            .unwrap_or(f64::NAN)
    }

    /// Median energy at the highest swept frequency [J].
    pub fn energy_at_nominal_j(&self) -> f64 {
        self.points.last().map(|(_, e)| *e).unwrap_or(f64::NAN)
    }

    /// Human-honest saving label: "12.3% saving" or "no saving (-1.2%)".
    pub fn saving_label(&self) -> String {
        if self.saving_vs_nominal > 0.0 {
            format!("{:.1}% saving vs nominal", self.saving_vs_nominal * 100.0)
        } else {
            format!(
                "no saving below nominal ({:+.1}%)",
                self.saving_vs_nominal * 100.0
            )
        }
    }
}

/// Fig. 9: energy vs frequency for several applications, sweet spots
/// marked with vertical guides.
pub fn energy_sweep_plot(sweeps: &[EnergySweep]) -> Plot {
    let mut p = Plot::new(
        "Energy-to-solution vs GPU frequency (Fig. 9)",
        "GPU frequency [MHz]",
        "energy to solution [J]",
    );
    for s in sweeps {
        p.add(Series::new(&s.app, s.points.clone()));
        p.add_vmark(s.sweet_spot_mhz, &format!("{} sweet spot", s.app));
    }
    p
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{synthetic_report, ReportSet};
    use super::*;

    fn sweep_set(app_bias: f64) -> ReportSet {
        // bowl with minimum at 900 + bias
        let reports = (0..12)
            .map(|i| {
                let f = 400.0 + i as f64 * 140.0;
                let e = 1000.0 + 0.002 * (f - (900.0 + app_bias)).powi(2);
                synthetic_report(
                    "jedi",
                    1,
                    1,
                    &[(1, 100.0, true)],
                    &[("freq_mhz", f), ("energy_j", e)],
                )
            })
            .collect();
        ReportSet::from_reports(reports)
    }

    #[test]
    fn finds_sweet_spot() {
        let s = EnergySweep::from_set(&sweep_set(0.0), "appA").unwrap();
        assert!(
            (s.sweet_spot_mhz - 960.0).abs() < 150.0,
            "spot={}",
            s.sweet_spot_mhz
        );
        assert!(s.saving_vs_nominal > 0.1, "{}", s.saving_vs_nominal);
        assert!(s.saving_label().contains("saving vs nominal"));
        // EDP and runtime series align with the energy points
        assert_eq!(s.edp.len(), s.points.len());
        assert_eq!(s.runtimes.len(), s.points.len());
        for ((f, e), (g, edp)) in s.points.iter().zip(&s.edp) {
            assert_eq!(f, g);
            assert!((edp - e * 100.0).abs() < 1e-9, "{edp} vs {}", e * 100.0);
        }
    }

    #[test]
    fn different_apps_have_different_spots() {
        // Fig. 9 shows two applications with distinct sweet spots
        let a = EnergySweep::from_set(&sweep_set(0.0), "appA").unwrap();
        let b = EnergySweep::from_set(&sweep_set(400.0), "appB").unwrap();
        assert!(b.sweet_spot_mhz > a.sweet_spot_mhz);
        let p = energy_sweep_plot(&[a, b]);
        assert_eq!(p.series.len(), 2);
        assert_eq!(p.vmarks.len(), 2);
    }

    #[test]
    fn too_few_points_is_none() {
        let set = ReportSet::from_reports(vec![synthetic_report(
            "jedi",
            1,
            1,
            &[(1, 1.0, true)],
            &[("freq_mhz", 900.0), ("energy_j", 5.0)],
        )]);
        assert!(EnergySweep::from_set(&set, "x").is_none());
    }

    /// Regression: a set loaded across several applications must not mix
    /// their points into one sweep — `from_set` filters by the store-path
    /// prefix the `app` argument names.
    #[test]
    fn multi_app_set_is_filtered_by_prefix() {
        let mut reports: Vec<(String, crate::protocol::Report)> = Vec::new();
        for (app, bias) in [("jedi.alpha", 0.0), ("jedi.beta", 400.0)] {
            for i in 0..8 {
                let f = 400.0 + i as f64 * 200.0;
                let e = 1000.0 + 0.002 * (f - (900.0 + bias)).powi(2);
                let r = synthetic_report(
                    "jedi",
                    1,
                    i as u64,
                    &[(1, 50.0, true)],
                    &[("freq_mhz", f), ("energy_j", e)],
                );
                reports.push((format!("{app}.f{f:.0}/{i}/report.json"), r));
            }
        }
        let set = ReportSet { reports };
        let a = EnergySweep::from_set(&set, "jedi.alpha").unwrap();
        let b = EnergySweep::from_set(&set, "jedi.beta").unwrap();
        // 8 distinct frequencies each — not 16 mixed points
        assert_eq!(a.points.len(), 8, "{:?}", a.points);
        assert_eq!(b.points.len(), 8, "{:?}", b.points);
        assert!(
            b.sweet_spot_mhz > a.sweet_spot_mhz,
            "{} vs {}",
            a.sweet_spot_mhz,
            b.sweet_spot_mhz
        );
        // an app whose name is a proper prefix of another must not
        // swallow the longer name's points ("jedi.alpha" vs "jedi.alphab")
        assert!(EnergySweep::from_set(&set, "jedi.alph").is_none());
        // unknown app: nothing survives the filter
        assert!(EnergySweep::from_set(&set, "jedi.gamma").is_none());
    }

    /// Regression: when the energy minimum sits on the nominal boundary
    /// the sweep has no bracketed bowl — the best interior point costs
    /// energy and `saving_vs_nominal` goes negative, surfaced honestly
    /// instead of reporting the boundary as a 0%-saving "sweet spot".
    #[test]
    fn boundary_minimum_reports_negative_saving() {
        // energy strictly decreasing toward nominal (no interior bowl)
        let reports = (0..8)
            .map(|i| {
                let f = 400.0 + i as f64 * 200.0;
                let e = 3000.0 - f; // min at the top frequency
                synthetic_report(
                    "jedi",
                    1,
                    i as u64,
                    &[(1, 60.0, true)],
                    &[("freq_mhz", f), ("energy_j", e)],
                )
            })
            .collect();
        let s = EnergySweep::from_set(&ReportSet::from_reports(reports), "mono").unwrap();
        assert!(
            s.saving_vs_nominal < 0.0,
            "boundary minimum must not imply a saving: {}",
            s.saving_vs_nominal
        );
        assert!(s.saving_label().contains("no saving"), "{}", s.saving_label());
        // the reported spot is the best interior candidate
        let interior: Vec<f64> = s.points[1..s.points.len() - 1].iter().map(|p| p.0).collect();
        assert!(interior.contains(&s.sweet_spot_mhz));
    }

    #[test]
    fn edp_spot_sits_at_or_above_the_energy_spot() {
        // EDP penalises slowdown, so its optimum is never below the
        // energy optimum on a bowl
        let s = EnergySweep::from_set(&sweep_set(0.0), "appA").unwrap();
        assert!(
            s.edp_spot_mhz >= s.sweet_spot_mhz,
            "edp {} vs energy {}",
            s.edp_spot_mhz,
            s.sweet_spot_mhz
        );
    }
}
