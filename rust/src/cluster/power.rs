//! GPU power/frequency models (Fig. 8 power traces, Fig. 9 energy bowls).
//!
//! Mechanics encoded:
//! * dynamic power grows ~cubically with core clock: P(f) = P_idle +
//!   (P_tdp - P_idle)·(f/f_nom)³·u, with utilisation u from the phase,
//! * application throughput grows *sub-linearly* with clock — a fraction
//!   `mem_bound` of the work is memory-bound and does not scale with f,
//! * therefore energy-to-solution E(f) = P(f)·T(f) has an interior
//!   minimum ("sweet spot") below f_nom — exactly what the paper's Fig. 9
//!   frequency study finds.

/// Per-GPU power model.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModel {
    /// Idle power [W].
    pub idle_w: f64,
    /// TDP at nominal clock, full utilisation [W].
    pub tdp_w: f64,
    /// Nominal (maximum) core clock [MHz].
    pub nominal_mhz: f64,
    /// Minimum settable clock [MHz].
    pub min_mhz: f64,
    /// Power-measurement sensor noise sigma [W].
    pub sensor_noise_w: f64,
}

impl PowerModel {
    /// Look up a shipped power fingerprint by name — machine definition
    /// files may write `power = "a100"` instead of the full table.
    pub fn preset(s: &str) -> Option<PowerModel> {
        match s.to_ascii_lowercase().as_str() {
            "a100" => Some(PowerModel::a100()),
            "gh200" => Some(PowerModel::gh200()),
            _ => None,
        }
    }

    pub fn a100() -> PowerModel {
        PowerModel {
            idle_w: 55.0,
            tdp_w: 400.0,
            nominal_mhz: 1410.0,
            min_mhz: 210.0,
            sensor_noise_w: 4.0,
        }
    }

    pub fn gh200() -> PowerModel {
        PowerModel {
            idle_w: 75.0,
            tdp_w: 700.0,
            nominal_mhz: 1980.0,
            min_mhz: 345.0,
            sensor_noise_w: 6.0,
        }
    }

    /// Instantaneous power [W] at clock `f_mhz` and utilisation `u` ∈ [0,1].
    ///
    /// Dynamic power follows P_dyn ∝ f·V²; DVFS lowers voltage with the
    /// clock down to a **voltage floor** (~55% of nominal) below which
    /// only the linear-in-f term remains — the reason real energy bowls
    /// flatten at the low end instead of plunging cubically.
    pub fn power_w(&self, f_mhz: f64, u: f64) -> f64 {
        let f = (f_mhz / self.nominal_mhz).clamp(0.1, 1.2);
        let v = f.clamp(0.55, 1.0);
        self.idle_w + (self.tdp_w - self.idle_w) * f * v * v * u.clamp(0.0, 1.0)
    }

    /// Relative application throughput at clock `f_mhz` for a workload
    /// with memory-bound fraction `mem_bound` ∈ [0,1] (1.0 at nominal).
    ///
    /// Compute-bound work scales linearly with the clock; memory-bound
    /// work barely scales — but below ~35% of nominal even memory-bound
    /// kernels lose throughput (issue-rate/latency limit), which is what
    /// keeps the Fig. 9 sweet spots interior.
    pub fn perf_factor(&self, f_mhz: f64, mem_bound: f64) -> f64 {
        let f = (f_mhz / self.nominal_mhz).clamp(0.05, 1.2);
        let mb = mem_bound.clamp(0.0, 1.0);
        let issue = (f / 0.35).min(1.0).powf(0.3);
        (mb + (1.0 - mb) * f) * issue
    }

    /// Energy-to-solution [J] for a workload of `t_nominal_s` seconds at
    /// nominal clock, run instead at `f_mhz`.
    pub fn energy_j(&self, f_mhz: f64, t_nominal_s: f64, u: f64, mem_bound: f64) -> f64 {
        let t = t_nominal_s / self.perf_factor(f_mhz, mem_bound);
        self.power_w(f_mhz, u) * t
    }

    /// Frequency [MHz] minimising energy-to-solution (grid search over
    /// the settable range — mirrors the paper's empirical sweep).
    pub fn sweet_spot_mhz(&self, u: f64, mem_bound: f64) -> f64 {
        let mut best = (self.nominal_mhz, f64::MAX);
        let mut f = self.min_mhz;
        while f <= self.nominal_mhz + 1e-9 {
            let e = self.energy_j(f, 1.0, u, mem_bound);
            if e < best.1 {
                best = (f, e);
            }
            f += 15.0;
        }
        best.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_monotone_in_frequency_and_util() {
        let p = PowerModel::a100();
        assert!(p.power_w(1410.0, 1.0) > p.power_w(800.0, 1.0));
        assert!(p.power_w(1410.0, 1.0) > p.power_w(1410.0, 0.3));
        assert!((p.power_w(1410.0, 1.0) - p.tdp_w).abs() < 1.0);
        assert!((p.power_w(1410.0, 0.0) - p.idle_w).abs() < 1.0);
    }

    #[test]
    fn perf_linear_for_compute_flat_for_memory() {
        let p = PowerModel::gh200();
        // pure compute: halving clock halves perf
        let half = p.perf_factor(990.0, 0.0);
        assert!((half - 0.5).abs() < 1e-9);
        // pure memory-bound: clock barely matters
        let mb = p.perf_factor(990.0, 1.0);
        assert!((mb - 1.0).abs() < 1e-9);
    }

    #[test]
    fn energy_bowl_has_interior_minimum() {
        // Fig. 9's premise: the sweet spot is strictly inside the range.
        let p = PowerModel::a100();
        for mem_bound in [0.3, 0.5, 0.7] {
            let spot = p.sweet_spot_mhz(0.9, mem_bound);
            assert!(
                spot > p.min_mhz && spot < p.nominal_mhz,
                "mem_bound={mem_bound} spot={spot}"
            );
            // energy at the spot beats both extremes by a visible margin
            let e_spot = p.energy_j(spot, 100.0, 0.9, mem_bound);
            let e_min = p.energy_j(p.min_mhz, 100.0, 0.9, mem_bound);
            let e_nom = p.energy_j(p.nominal_mhz, 100.0, 0.9, mem_bound);
            assert!(e_spot < e_min && e_spot < e_nom);
        }
    }

    #[test]
    fn more_memory_bound_means_lower_sweet_spot() {
        let p = PowerModel::gh200();
        let compute_spot = p.sweet_spot_mhz(0.9, 0.2);
        let memory_spot = p.sweet_spot_mhz(0.9, 0.8);
        assert!(
            memory_spot < compute_spot,
            "memory-bound={memory_spot} compute-bound={compute_spot}"
        );
    }
}
