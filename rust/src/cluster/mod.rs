//! Simulated HPC cluster substrate (DESIGN.md §2).
//!
//! The paper evaluates on JUPITER, JEDI, JUWELS Booster and JURECA-DC;
//! none of which are available here, so this module models the hardware
//! behaviour the experiments depend on: GPU generations and memory
//! bandwidth ([`machine`]), the interconnect with UCX protocol switching
//! ([`network`]), power/frequency response ([`power`]), and software
//! stages plus timed system events ([`stage`]).
//!
//! [`Cluster`] ties it together: a set of machines with an event log and
//! a per-run environment view used by the workload models.

pub mod machine;
pub mod network;
pub mod power;
pub mod stage;

pub use machine::{standard_machines, GpuGen, Machine};
pub use network::NetworkLink;
pub use power::PowerModel;
pub use stage::{EventLog, MetricClass, SoftwareStage, SystemEvent};

use crate::util::prng::Prng;
use crate::util::timeutil::SimTime;

/// The simulated computing centre: machines + system-event history.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub machines: Vec<Machine>,
    pub events: EventLog,
}

impl Cluster {
    /// The standard JSC-like centre.
    pub fn standard() -> Cluster {
        Cluster {
            machines: standard_machines(),
            events: EventLog::new(),
        }
    }

    pub fn with_events(mut self, events: EventLog) -> Cluster {
        self.events = events;
        self
    }

    pub fn machine(&self, name: &str) -> Option<&Machine> {
        self.machines.iter().find(|m| m.name == name)
    }

    /// The execution environment for one run: machine view at a point in
    /// time, with stage + event factors resolved.
    pub fn env_at<'a>(
        &'a self,
        machine: &str,
        stage: &SoftwareStage,
        t: SimTime,
    ) -> Option<RunEnv<'a>> {
        let m = self.machine(machine)?;
        Some(RunEnv {
            machine: m,
            stage: stage.clone(),
            time: t,
            events: &self.events,
        })
    }
}

/// Resolved per-run environment: what a job launched on `machine` at
/// `time` under `stage` actually sees.
#[derive(Debug, Clone)]
pub struct RunEnv<'a> {
    pub machine: &'a Machine,
    pub stage: SoftwareStage,
    pub time: SimTime,
    events: &'a EventLog,
}

impl<'a> RunEnv<'a> {
    /// Combined multiplicative factor for a metric class: stage × events.
    pub fn factor(&self, class: MetricClass) -> f64 {
        self.stage.factor(class) * self.events.factor_at(&self.machine.name, class, self.time)
    }

    /// Effective attainable STREAM bandwidth per GPU [MB/s] now.
    pub fn stream_bw_mbs(&self) -> f64 {
        self.machine.stream_bw_mbs() * self.factor(MetricClass::MemBw)
    }

    /// Effective pt2pt bandwidth [MB/s] for a message size + threshold.
    pub fn pt2pt_bw_mbs(&self, bytes: u64, rndv_thresh: u64) -> f64 {
        self.machine.network.pt2pt_bw_mbs(bytes, rndv_thresh) * self.factor(MetricClass::Network)
    }

    /// Multiplicative run-to-run noise for this machine.
    pub fn noise(&self, rng: &mut Prng) -> f64 {
        rng.jitter(self.machine.noise_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_resolves_factors() {
        let cluster =
            Cluster::standard().with_events(EventLog::fig4_scenario("jupiter"));
        let stage = SoftwareStage::stage_2026();
        let before = cluster
            .env_at("jupiter", &stage, SimTime::from_days(10))
            .unwrap();
        let during = cluster
            .env_at("jupiter", &stage, SimTime::from_days(45))
            .unwrap();
        assert_eq!(before.factor(MetricClass::Network), 1.0);
        assert!((during.factor(MetricClass::Network) - 0.72).abs() < 1e-12);
        // memory bandwidth unaffected (Fig. 3 stays flat while Fig. 4 dips)
        assert_eq!(during.factor(MetricClass::MemBw), 1.0);
    }

    #[test]
    fn stage_and_event_factors_compose() {
        let cluster = Cluster::standard().with_events(EventLog::fig4_scenario("jedi"));
        let env = cluster
            .env_at("jedi", &SoftwareStage::stage_2025(), SimTime::from_days(45))
            .unwrap();
        let expect = 0.90 * 0.72;
        assert!((env.factor(MetricClass::Network) - expect).abs() < 1e-12);
    }

    #[test]
    fn unknown_machine_is_none() {
        let cluster = Cluster::standard();
        assert!(cluster
            .env_at("frontier", &SoftwareStage::stage_2026(), SimTime(0))
            .is_none());
    }

    #[test]
    fn noise_is_small_and_positive() {
        let cluster = Cluster::standard();
        let env = cluster
            .env_at("jedi", &SoftwareStage::stage_2026(), SimTime(0))
            .unwrap();
        let mut rng = Prng::new(1);
        for _ in 0..100 {
            let n = env.noise(&mut rng);
            assert!(n > 0.9 && n < 1.1);
        }
    }
}
