//! Machine (system) hardware models.
//!
//! Substitution for the JSC systems the paper benchmarks on (DESIGN.md
//! §2): each machine is described by its GPU generation, node count,
//! per-GPU memory bandwidth and compute peak, network link, and power
//! envelope. Figures 3–9 depend only on *relative* behaviour between
//! these systems (generational speedups, bandwidth stability, network
//! crossovers, frequency/energy bowls), which these models encode.

use super::network::NetworkLink;
use super::power::PowerModel;

/// GPU generation (the paper's Fig. 5 compares Ampere vs Hopper-class).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuGen {
    /// NVIDIA A100-class (JUWELS Booster, JURECA-DC).
    Ampere,
    /// NVIDIA H100-class.
    Hopper,
    /// GH200 superchip (JEDI, JUPITER).
    GraceHopper,
}

impl GpuGen {
    /// Parse a generation name from a machine definition file
    /// (case-insensitive; accepts the [`GpuGen::name`] spellings plus
    /// the common aliases "a100"/"h100"/"gracehopper").
    pub fn parse(s: &str) -> Option<GpuGen> {
        match s.to_ascii_lowercase().as_str() {
            "ampere" | "a100" => Some(GpuGen::Ampere),
            "hopper" | "h100" => Some(GpuGen::Hopper),
            "gh200" | "gracehopper" | "grace-hopper" => Some(GpuGen::GraceHopper),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GpuGen::Ampere => "Ampere",
            GpuGen::Hopper => "Hopper",
            GpuGen::GraceHopper => "GH200",
        }
    }

    /// Peak HBM bandwidth per GPU [GB/s] (generation-typical).
    pub fn hbm_bw_gbs(&self) -> f64 {
        match self {
            GpuGen::Ampere => 1555.0,
            GpuGen::Hopper => 3350.0,
            GpuGen::GraceHopper => 4000.0,
        }
    }

    /// Peak FP32 vector throughput per GPU [TFLOP/s].
    pub fn peak_tflops(&self) -> f64 {
        match self {
            GpuGen::Ampere => 19.5,
            GpuGen::Hopper => 66.9,
            GpuGen::GraceHopper => 66.9,
        }
    }

    /// Nominal (max boost) GPU clock [MHz] — the Fig. 9 sweep range top.
    pub fn nominal_mhz(&self) -> f64 {
        match self {
            GpuGen::Ampere => 1410.0,
            GpuGen::Hopper => 1980.0,
            GpuGen::GraceHopper => 1980.0,
        }
    }
}

/// A simulated HPC system.
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    /// System name as used in CI inputs (`machine: "jedi"`).
    pub name: String,
    /// Human-readable system version (Table I `version` column).
    pub version: String,
    pub gpu_gen: GpuGen,
    pub nodes: u64,
    pub gpus_per_node: u64,
    pub cores_per_node: u64,
    /// Batch partitions (queues) this system exposes.
    pub queues: Vec<String>,
    pub network: NetworkLink,
    pub power: PowerModel,
    /// Fraction of peak HBM bandwidth a tuned STREAM actually attains.
    pub stream_efficiency: f64,
    /// Run-to-run multiplicative noise sigma (log-normal).
    pub noise_sigma: f64,
    /// Relative compute throughput vs the *host calibration anchor*
    /// (the machine on which PJRT wallclock is measured; see
    /// workloads::calibration).
    pub perf_factor: f64,
}

impl Machine {
    /// Build a machine from a loaded definition (DESIGN.md §15).
    /// Infallible: `defs::validate` has already checked the ranges.
    pub fn from_def(def: &crate::defs::MachineDef) -> Machine {
        Machine {
            name: def.name.clone(),
            version: def.version.clone(),
            gpu_gen: def.gpu,
            nodes: def.nodes,
            gpus_per_node: def.gpus_per_node,
            cores_per_node: def.cores_per_node,
            queues: def.partitions.clone(),
            network: def.network.clone(),
            power: def.power.clone(),
            stream_efficiency: def.stream_efficiency,
            noise_sigma: def.noise_sigma,
            perf_factor: def.perf_factor,
        }
    }

    /// Attainable memory bandwidth per GPU [MB/s] — BabelStream's metric.
    pub fn stream_bw_mbs(&self) -> f64 {
        self.gpu_gen.hbm_bw_gbs() * self.stream_efficiency * 1000.0
    }

    /// Total GPUs in the system.
    pub fn total_gpus(&self) -> u64 {
        self.nodes * self.gpus_per_node
    }

    pub fn has_queue(&self, q: &str) -> bool {
        self.queues.iter().any(|x| x == q)
    }
}

/// The standard JSC-like systems of the paper.
pub fn standard_machines() -> Vec<Machine> {
    vec![
        // JEDI — JUPITER Exascale Development Instrument: GH200 nodes.
        Machine {
            name: "jedi".into(),
            version: "2026.1".into(),
            gpu_gen: GpuGen::GraceHopper,
            nodes: 48,
            gpus_per_node: 4,
            cores_per_node: 288,
            queues: vec!["all".into(), "devel".into()],
            network: NetworkLink::ndr400(),
            power: PowerModel::gh200(),
            stream_efficiency: 0.855,
            noise_sigma: 0.006,
            perf_factor: 3.35,
        },
        // JUPITER — the exascale system (same node design as JEDI, scaled).
        Machine {
            name: "jupiter".into(),
            version: "2026.1".into(),
            gpu_gen: GpuGen::GraceHopper,
            nodes: 5888,
            gpus_per_node: 4,
            cores_per_node: 288,
            queues: vec!["booster".into(), "devel".into(), "all".into()],
            network: NetworkLink::ndr400(),
            power: PowerModel::gh200(),
            stream_efficiency: 0.855,
            noise_sigma: 0.006,
            perf_factor: 3.35,
        },
        // JUWELS Booster — A100 nodes.
        Machine {
            name: "juwels-booster".into(),
            version: "2024.3".into(),
            gpu_gen: GpuGen::Ampere,
            nodes: 936,
            gpus_per_node: 4,
            cores_per_node: 96,
            queues: vec!["booster".into(), "develbooster".into()],
            network: NetworkLink::hdr200(),
            power: PowerModel::a100(),
            stream_efficiency: 0.87,
            noise_sigma: 0.008,
            perf_factor: 1.0,
        },
        // JURECA-DC — A100 partition.
        Machine {
            name: "jureca".into(),
            version: "2024.3".into(),
            gpu_gen: GpuGen::Ampere,
            nodes: 192,
            gpus_per_node: 4,
            cores_per_node: 128,
            // "all" is the cross-system campaign partition name shared
            // with jedi/jupiter, so multi-machine collections can target
            // one queue name everywhere.
            queues: vec!["dc-gpu".into(), "dc-gpu-devel".into(), "all".into()],
            network: NetworkLink::hdr100(),
            power: PowerModel::a100(),
            stream_efficiency: 0.86,
            noise_sigma: 0.010,
            perf_factor: 0.97,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_machines_present() {
        let ms = standard_machines();
        let names: Vec<&str> = ms.iter().map(|m| m.name.as_str()).collect();
        for n in ["jedi", "jupiter", "juwels-booster", "jureca"] {
            assert!(names.contains(&n), "{n}");
        }
    }

    #[test]
    fn generational_ordering_holds() {
        // Fig. 5's premise: Hopper-class beats Ampere by roughly 2x+.
        let ms = standard_machines();
        let jedi = ms.iter().find(|m| m.name == "jedi").unwrap();
        let jwb = ms.iter().find(|m| m.name == "juwels-booster").unwrap();
        assert!(jedi.perf_factor / jwb.perf_factor > 2.0);
        assert!(jedi.stream_bw_mbs() > 2.0 * jwb.stream_bw_mbs());
    }

    #[test]
    fn stream_bw_is_below_peak() {
        for m in standard_machines() {
            assert!(m.stream_bw_mbs() < m.gpu_gen.hbm_bw_gbs() * 1000.0);
            assert!(m.stream_bw_mbs() > 0.5 * m.gpu_gen.hbm_bw_gbs() * 1000.0);
        }
    }

    #[test]
    fn queues_lookup() {
        let ms = standard_machines();
        let jureca = ms.iter().find(|m| m.name == "jureca").unwrap();
        assert!(jureca.has_queue("dc-gpu"));
        assert!(!jureca.has_queue("booster"));
    }

    #[test]
    fn jupiter_is_exascale_sized() {
        let ms = standard_machines();
        let jup = ms.iter().find(|m| m.name == "jupiter").unwrap();
        assert!(jup.total_gpus() > 20_000);
    }
}
