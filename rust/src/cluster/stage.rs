//! Software stages and timed system events.
//!
//! JSC deploys software in yearly "stages" (the paper's Fig. 7 compares
//! stage 2025 vs 2026); independently, the system evolves over time —
//! driver updates, fabric reconfigurations, firmware — which shows up in
//! daily benchmark series as regressions and recoveries (Fig. 4).
//!
//! Both are modelled as multiplicative factors on *metric classes*:
//! `compute`, `membw`, `network`, `io`. A stage carries static factors;
//! an event changes a factor from its date onward.

use crate::util::timeutil::SimTime;

/// What part of the machine a factor applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MetricClass {
    Compute,
    MemBw,
    Network,
    Io,
}

impl MetricClass {
    pub fn name(&self) -> &'static str {
        match self {
            MetricClass::Compute => "compute",
            MetricClass::MemBw => "membw",
            MetricClass::Network => "network",
            MetricClass::Io => "io",
        }
    }
}

/// A named software stage with per-class performance factors (1.0 = the
/// 2026 reference stage).
#[derive(Debug, Clone, PartialEq)]
pub struct SoftwareStage {
    pub name: String,
    pub compute: f64,
    pub membw: f64,
    pub network: f64,
    pub io: f64,
}

impl SoftwareStage {
    pub fn factor(&self, class: MetricClass) -> f64 {
        match class {
            MetricClass::Compute => self.compute,
            MetricClass::MemBw => self.membw,
            MetricClass::Network => self.network,
            MetricClass::Io => self.io,
        }
    }

    /// The 2026 reference stage.
    pub fn stage_2026() -> SoftwareStage {
        SoftwareStage {
            name: "2026".into(),
            compute: 1.0,
            membw: 1.0,
            network: 1.0,
            io: 1.0,
        }
    }

    /// The older 2025 stage: slightly slower compiler output and an MPI
    /// with poorer collectives — the gap Fig. 7 visualises.
    pub fn stage_2025() -> SoftwareStage {
        SoftwareStage {
            name: "2025".into(),
            compute: 0.94,
            membw: 0.995,
            network: 0.90,
            io: 0.97,
        }
    }

    pub fn by_name(name: &str) -> Option<SoftwareStage> {
        match name {
            "2025" | "stage-2025" => Some(Self::stage_2025()),
            "2026" | "stage-2026" => Some(Self::stage_2026()),
            _ => None,
        }
    }
}

/// A timed change to a metric-class factor on one system.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemEvent {
    pub machine: String,
    pub date: SimTime,
    pub class: MetricClass,
    /// New factor in effect from `date` (replaces the previous one).
    pub factor: f64,
    pub description: String,
}

/// Event log for a simulation; answers "what is the factor for class C on
/// machine M at time T".
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<SystemEvent>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn push(&mut self, ev: SystemEvent) {
        self.events.push(ev);
        self.events.sort_by_key(|e| e.date);
    }

    /// Effective factor at `t` (latest event at or before `t` wins; 1.0
    /// if none).
    pub fn factor_at(&self, machine: &str, class: MetricClass, t: SimTime) -> f64 {
        self.events
            .iter()
            .filter(|e| e.machine == machine && e.class == class && e.date <= t)
            .next_back()
            .map(|e| e.factor)
            .unwrap_or(1.0)
    }

    pub fn events(&self) -> &[SystemEvent] {
        &self.events
    }

    /// A coordinated stack-update day (DESIGN.md §14): the software
    /// stack is redeployed fleet-wide on day `day`, shifting **every**
    /// metric class on **every** listed machine to `factor` at once.
    /// Because the effective per-class factors enter the machine
    /// environment fingerprint (and through it every step- and
    /// run-level cache key), a stack update invalidates every cached
    /// execution on every affected machine simultaneously — and, when
    /// `factor != 1.0`, plants a correlated fleet-wide baseline move
    /// that regression gates must distinguish from per-app noise.
    pub fn stack_update(machines: &[&str], day: i64, factor: f64) -> Vec<SystemEvent> {
        let classes = [
            MetricClass::Compute,
            MetricClass::MemBw,
            MetricClass::Network,
            MetricClass::Io,
        ];
        let mut events = Vec::new();
        for machine in machines {
            for class in classes {
                events.push(SystemEvent {
                    machine: (*machine).to_string(),
                    date: SimTime::from_days(day),
                    class,
                    factor,
                    description: format!("stack update (day {day})"),
                });
            }
        }
        events
    }

    /// The Fig. 4 scenario: an interconnect-firmware update regresses
    /// network performance on `machine` at day 30 and a fix restores it
    /// at day 60.
    pub fn fig4_scenario(machine: &str) -> EventLog {
        let mut log = EventLog::new();
        log.push(SystemEvent {
            machine: machine.into(),
            date: SimTime::from_days(30),
            class: MetricClass::Network,
            factor: 0.72,
            description: "fabric firmware update (regression)".into(),
        });
        log.push(SystemEvent {
            machine: machine.into(),
            date: SimTime::from_days(60),
            class: MetricClass::Network,
            factor: 1.0,
            description: "fabric routing fix deployed (recovery)".into(),
        });
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_lookup() {
        assert_eq!(SoftwareStage::by_name("2025").unwrap().name, "2025");
        assert_eq!(SoftwareStage::by_name("stage-2026").unwrap().name, "2026");
        assert!(SoftwareStage::by_name("1999").is_none());
    }

    #[test]
    fn stage_2025_is_slower_where_it_matters() {
        let s = SoftwareStage::stage_2025();
        assert!(s.factor(MetricClass::Network) < 1.0);
        assert!(s.factor(MetricClass::Compute) < 1.0);
        // memory bandwidth is essentially hardware-bound
        assert!(s.factor(MetricClass::MemBw) > 0.99);
    }

    #[test]
    fn event_log_latest_wins() {
        let log = EventLog::fig4_scenario("jupiter");
        let net = |d: i64| log.factor_at("jupiter", MetricClass::Network, SimTime::from_days(d));
        assert_eq!(net(0), 1.0);
        assert_eq!(net(29), 1.0);
        assert!((net(30) - 0.72).abs() < 1e-12);
        assert!((net(59) - 0.72).abs() < 1e-12);
        assert_eq!(net(60), 1.0);
        assert_eq!(net(89), 1.0);
    }

    #[test]
    fn events_scoped_to_machine_and_class() {
        let log = EventLog::fig4_scenario("jupiter");
        assert_eq!(
            log.factor_at("jedi", MetricClass::Network, SimTime::from_days(40)),
            1.0
        );
        assert_eq!(
            log.factor_at("jupiter", MetricClass::MemBw, SimTime::from_days(40)),
            1.0
        );
    }

    #[test]
    fn stack_update_shifts_every_class_fleet_wide() {
        let mut log = EventLog::new();
        for ev in EventLog::stack_update(&["jedi", "jupiter"], 12, 0.9) {
            log.push(ev);
        }
        assert_eq!(log.events().len(), 8);
        let classes = [
            MetricClass::Compute,
            MetricClass::MemBw,
            MetricClass::Network,
            MetricClass::Io,
        ];
        for m in ["jedi", "jupiter"] {
            for c in classes {
                assert_eq!(log.factor_at(m, c, SimTime::from_days(11)), 1.0);
                assert!((log.factor_at(m, c, SimTime::from_days(12)) - 0.9).abs() < 1e-12);
            }
        }
        // unlisted machines are untouched
        assert_eq!(
            log.factor_at("juwels", MetricClass::Compute, SimTime::from_days(20)),
            1.0
        );
    }

    #[test]
    fn unsorted_pushes_are_ordered() {
        let mut log = EventLog::new();
        log.push(SystemEvent {
            machine: "m".into(),
            date: SimTime::from_days(10),
            class: MetricClass::Io,
            factor: 0.5,
            description: "later".into(),
        });
        log.push(SystemEvent {
            machine: "m".into(),
            date: SimTime::from_days(5),
            class: MetricClass::Io,
            factor: 0.8,
            description: "earlier".into(),
        });
        assert!((log.factor_at("m", MetricClass::Io, SimTime::from_days(7)) - 0.8).abs() < 1e-12);
        assert!((log.factor_at("m", MetricClass::Io, SimTime::from_days(12)) - 0.5).abs() < 1e-12);
    }
}
