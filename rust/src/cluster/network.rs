//! Inter-node network model with UCX-style protocol switching.
//!
//! Fig. 6 of the paper evaluates OSU pt2pt bandwidth under different
//! `UCX_RNDV_THRESH` values. The model reproduces the mechanism:
//!
//! * **eager** protocol — message is copied through pre-posted bounce
//!   buffers: low startup cost, but an extra copy caps bandwidth and the
//!   per-message overhead grows with size.
//! * **rendezvous (rndv)** — an RTS/CTS handshake adds fixed latency,
//!   then zero-copy RDMA streams at near line rate.
//!
//! Small messages favour eager (handshake dominates), large messages
//! favour rendezvous (copy dominates); the crossover is exactly what
//! moving `UCX_RNDV_THRESH` exposes.

/// A point-to-point network link.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkLink {
    pub name: String,
    /// Base one-way latency [µs].
    pub latency_us: f64,
    /// Peak (zero-copy) link bandwidth [GB/s].
    pub bw_gbs: f64,
    /// Rendezvous handshake cost [µs] (RTS/CTS round trip + pin).
    pub rndv_handshake_us: f64,
    /// Eager-path effective bandwidth fraction (extra copy penalty).
    pub eager_bw_fraction: f64,
    /// Per-KB packetisation overhead on the eager path [µs/KB].
    pub eager_per_kb_us: f64,
    /// Default rendezvous threshold [bytes] (UCX_RNDV_THRESH default).
    pub default_rndv_thresh: u64,
}

impl NetworkLink {
    /// Look up a shipped link fingerprint by name — machine definition
    /// files may write `network = "ndr400"` instead of the full table
    /// (DESIGN.md §15). Accepts both the preset short name and the
    /// rendered `name` field.
    pub fn preset(s: &str) -> Option<NetworkLink> {
        match s.to_ascii_lowercase().as_str() {
            "ndr400" | "ib-ndr400" => Some(NetworkLink::ndr400()),
            "hdr200" | "ib-hdr200" => Some(NetworkLink::hdr200()),
            "hdr100" | "ib-hdr100" => Some(NetworkLink::hdr100()),
            _ => None,
        }
    }

    /// InfiniBand NDR (400 Gb/s class — JEDI/JUPITER).
    pub fn ndr400() -> NetworkLink {
        NetworkLink {
            name: "IB-NDR400".into(),
            latency_us: 0.9,
            bw_gbs: 48.0,
            rndv_handshake_us: 2.2,
            eager_bw_fraction: 0.55,
            eager_per_kb_us: 0.012,
            default_rndv_thresh: 8192,
        }
    }

    /// InfiniBand HDR (200 Gb/s — JUWELS Booster).
    pub fn hdr200() -> NetworkLink {
        NetworkLink {
            name: "IB-HDR200".into(),
            latency_us: 1.1,
            bw_gbs: 24.0,
            rndv_handshake_us: 2.6,
            eager_bw_fraction: 0.55,
            eager_per_kb_us: 0.02,
            default_rndv_thresh: 8192,
        }
    }

    /// InfiniBand HDR100 (JURECA-DC class).
    pub fn hdr100() -> NetworkLink {
        NetworkLink {
            name: "IB-HDR100".into(),
            latency_us: 1.2,
            bw_gbs: 12.0,
            rndv_handshake_us: 2.8,
            eager_bw_fraction: 0.55,
            eager_per_kb_us: 0.03,
            default_rndv_thresh: 8192,
        }
    }

    /// A degraded copy of this link: bandwidth scaled by `factor` (in
    /// `(0, 1]`) with the same protocol behaviour. This is what a
    /// fabric-firmware regression or a post-maintenance misconfiguration
    /// looks like at the link level — the network `SystemEvent` factors
    /// in [`crate::cluster::stage`] are the timeline-aware counterpart.
    pub fn degraded(&self, factor: f64) -> NetworkLink {
        let factor = factor.clamp(f64::MIN_POSITIVE, 1.0);
        NetworkLink {
            name: format!("{} (degraded x{factor:.2})", self.name),
            bw_gbs: self.bw_gbs * factor,
            ..self.clone()
        }
    }

    /// Transfer time [µs] for `bytes` with a given rendezvous threshold.
    pub fn pt2pt_time_us(&self, bytes: u64, rndv_thresh: u64) -> f64 {
        let kb = bytes as f64 / 1024.0;
        if bytes < rndv_thresh {
            // eager: base latency + packetisation + copy-limited stream
            self.latency_us
                + self.eager_per_kb_us * kb
                + bytes as f64 / (self.bw_gbs * self.eager_bw_fraction * 1e3)
        } else {
            // rendezvous: handshake + zero-copy stream at line rate
            self.latency_us
                + self.rndv_handshake_us
                + bytes as f64 / (self.bw_gbs * 1e3)
        }
    }

    /// OSU-style bandwidth [MB/s] for a message size under a threshold.
    pub fn pt2pt_bw_mbs(&self, bytes: u64, rndv_thresh: u64) -> f64 {
        let t_us = self.pt2pt_time_us(bytes, rndv_thresh);
        bytes as f64 / t_us // bytes/µs == MB/s
    }

    /// Ring-allreduce time [µs] for `bytes` over `n` ranks (2(n-1)/n data
    /// exchange volume, handshake per step). Used by the scaling models.
    pub fn allreduce_time_us(&self, bytes: u64, ranks: u64) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let steps = 2 * (ranks - 1);
        let chunk = bytes as f64 / ranks as f64;
        steps as f64
            * (self.latency_us + self.rndv_handshake_us + chunk / (self.bw_gbs * 1e3))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eager_wins_small_rndv_wins_large() {
        let link = NetworkLink::ndr400();
        // 256 B: eager faster than forcing rendezvous
        let eager = link.pt2pt_time_us(256, 1 << 20);
        let rndv = link.pt2pt_time_us(256, 0);
        assert!(eager < rndv, "eager={eager} rndv={rndv}");
        // 4 MB: rendezvous faster than forcing eager
        let eager = link.pt2pt_time_us(4 << 20, u64::MAX);
        let rndv = link.pt2pt_time_us(4 << 20, 0);
        assert!(rndv < eager, "eager={eager} rndv={rndv}");
    }

    #[test]
    fn bandwidth_monotone_toward_line_rate() {
        let link = NetworkLink::ndr400();
        let bw_small = link.pt2pt_bw_mbs(1024, link.default_rndv_thresh);
        let bw_large = link.pt2pt_bw_mbs(4 << 20, link.default_rndv_thresh);
        assert!(bw_large > bw_small);
        // large-message bandwidth approaches line rate (within 15%)
        assert!(bw_large > link.bw_gbs * 1e3 * 0.85);
        assert!(bw_large <= link.bw_gbs * 1e3);
    }

    #[test]
    fn threshold_moves_the_crossover() {
        // Fig. 6's observable: at message sizes between two thresholds the
        // protocol (and thus bandwidth) differs.
        let link = NetworkLink::ndr400();
        let msg = 512 * 1024;
        let rndv = link.pt2pt_bw_mbs(msg, 64 * 1024); // rendezvous at 512k
        let eager = link.pt2pt_bw_mbs(msg, 1 << 20); // forced eager at 512k
        assert!(
            (rndv - eager) / eager > 0.30,
            "threshold must visibly change mid-size bandwidth: {rndv} vs {eager}"
        );
    }

    #[test]
    fn allreduce_scales_with_ranks_and_bytes() {
        let link = NetworkLink::hdr200();
        let t2 = link.allreduce_time_us(1 << 20, 2);
        let t8 = link.allreduce_time_us(1 << 20, 8);
        assert!(t8 > t2);
        assert_eq!(link.allreduce_time_us(1 << 20, 1), 0.0);
    }

    #[test]
    fn degraded_link_is_strictly_slower_at_scale() {
        let link = NetworkLink::ndr400();
        let bad = link.degraded(0.5);
        let msg = 4 << 20;
        assert!(bad.pt2pt_bw_mbs(msg, 8192) < link.pt2pt_bw_mbs(msg, 8192));
        assert!(bad.allreduce_time_us(msg, 8) > link.allreduce_time_us(msg, 8));
        // degradation never *improves* a link
        assert_eq!(link.degraded(2.0).bw_gbs, link.bw_gbs);
    }

    #[test]
    fn generation_ordering() {
        let small = 1 << 22;
        let ndr = NetworkLink::ndr400().pt2pt_bw_mbs(small, 8192);
        let hdr = NetworkLink::hdr200().pt2pt_bw_mbs(small, 8192);
        assert!(ndr > 1.5 * hdr);
    }
}
