//! Evidence reconstruction over the report store (DESIGN.md §10).
//!
//! Maturity evidence is rebuilt **only** from artifacts recorded on the
//! `exacb.data` branch — the same read-side discipline as the
//! post-processing orchestrators and the tracking history (§3, §9):
//! never executor, scheduler, or CI-job state. Like
//! [`crate::tracking::History`], the assessment is **digest-keyed** on
//! report content, with the same two tested consequences:
//!
//! * ingestion order does not matter — any permutation of the same
//!   reports reconstructs the identical evidence;
//! * a warm cache replay, which re-commits a byte-identical report under
//!   a new path, never grows an evidence counter. The *only* thing a
//!   replay proves is replayability itself: the duplicate-path footprint
//!   feeds exactly one criterion
//!   ([`super::criteria::Criterion::ReplayVerified`]), and further
//!   replays of the same document change nothing (idempotence,
//!   property-tested).

use std::collections::{BTreeMap, BTreeSet};

use crate::coordinator::BenchmarkRepo;
use crate::protocol::Report;
use crate::store::{DataStore, Snapshot};
use crate::util::timeutil::SimTime;
use crate::util::wide_hash;
use crate::workloads::portfolio::Maturity;

use super::criteria::{earned_level, unmet, CriteriaConfig, Criterion};

/// Monotone evidence counters extracted from a report store. All fields
/// count *distinct report digests* (replays dedupe), and every criterion
/// in [`super::criteria`] is a threshold over them.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Evidence {
    /// Distinct reports of any outcome (the "enough data to judge"
    /// floor: below `min_runs` the gate refuses to re-level).
    pub reports: usize,
    /// Distinct fully-successful reports (≥ 1 data entry, none failed).
    pub successful_runs: usize,
    /// Successful reports whose sibling `results.csv` honours the
    /// Table-I contract (base columns, in order).
    pub csv_ok: usize,
    /// Successful reports carrying an instrumentation metric.
    pub instrumented_runs: usize,
    /// Systems any evidence was recorded on.
    pub systems: BTreeSet<String>,
    /// Systems carrying instrumented evidence.
    pub instrumented_systems: BTreeSet<String>,
    /// Largest group of successful reports agreeing on one nonempty
    /// (system, software-stage) provenance fingerprint.
    pub pinned_runs: usize,
    /// Successful reports recording the reproduction seed.
    pub seeded_runs: usize,
    /// Successful reports committed byte-identically at ≥ 2 distinct
    /// store paths — the warm-replay footprint.
    pub replay_commits: usize,
}

/// Everything the assessor remembers about one distinct report document.
/// All fields are pure functions of the document (plus its content-paired
/// CSV sibling), which is what makes assessment order-independent.
#[derive(Debug, Clone)]
struct ReportFacts {
    success: bool,
    csv_ok: bool,
    instrumented: bool,
    system: String,
    stage: String,
    seeded: bool,
    time: SimTime,
}

/// Digest-keyed evidence accumulator over one application's store.
#[derive(Debug, Clone, Default)]
pub struct Assessment {
    cfg: CriteriaConfig,
    facts: BTreeMap<String, ReportFacts>,
    /// Digest → the distinct store paths it was committed under.
    paths: BTreeMap<String, BTreeSet<String>>,
}

pub use crate::protocol::csv_honours_contract;

impl Assessment {
    pub fn new(cfg: &CriteriaConfig) -> Assessment {
        Assessment {
            cfg: cfg.clone(),
            ..Assessment::default()
        }
    }

    /// Ingest one recorded report (with its sibling CSV, when present)
    /// from `path`. Returns `false` — and ingests nothing — when the
    /// document does not parse (robustness against partial generation,
    /// counted by the caller).
    pub fn ingest(&mut self, path: &str, document: &str, csv: Option<&str>) -> bool {
        let Ok(report) = Report::parse(document) else {
            return false;
        };
        let digest = wide_hash(document.as_bytes());
        let csv_ok = csv.map(csv_honours_contract).unwrap_or(false);
        self.ingest_parsed(path, &digest, &report, csv_ok);
        true
    }

    /// Ingest one already-parsed report with its precomputed content
    /// digest and sibling-CSV verdict — the [`Snapshot`] fast path.
    /// Facts are derived exactly as in [`Assessment::ingest`], so both
    /// paths fold to byte-identical evidence (differentially tested).
    pub fn ingest_parsed(&mut self, path: &str, digest: &str, report: &Report, csv_ok: bool) {
        let entry = self.facts.entry(digest.to_string()).or_insert_with(|| {
            let success =
                !report.data.is_empty() && report.data.iter().all(|e| e.success);
            let instrumented = report.data.iter().any(|e| {
                e.success
                    && self
                        .cfg
                        .instrument_metrics
                        .iter()
                        .any(|m| e.metric(m).is_some())
            });
            ReportFacts {
                success,
                csv_ok,
                instrumented,
                system: report.experiment.system.clone(),
                stage: report.experiment.software_version.clone(),
                seeded: report.reporter.seed != 0,
                time: report.experiment.time().unwrap_or_default(),
            }
        });
        // a replayed document is byte-identical, so its facts agree; the
        // CSV sibling may be absent on one of the paths — OR is both
        // order-independent and monotone (a later sibling-less ingest
        // must not revoke already-earned csv evidence)
        entry.csv_ok |= csv_ok;
        self.paths
            .entry(digest.to_string())
            .or_default()
            .insert(path.to_string());
    }

    /// Reconstruct evidence from every `report.json` under `prefix` on
    /// `branch`, pairing each with its sibling `results.csv`. Returns
    /// the assessment and the count of unparseable documents skipped.
    ///
    /// This is the legacy full-walk path, retained as the executable
    /// differential reference for [`Assessment::from_snapshot`] — the
    /// gate and audits read via the snapshot.
    pub fn from_store(
        store: &DataStore,
        branch: &str,
        prefix: &str,
        cfg: &CriteriaConfig,
    ) -> (Assessment, usize) {
        let mut a = Assessment::new(cfg);
        let mut skipped = 0;
        for (path, content) in store.read_all_iter(branch, prefix) {
            if !path.ends_with("report.json") {
                continue;
            }
            let csv_path = format!("{}results.csv", path.trim_end_matches("report.json"));
            let csv = store.read(branch, &csv_path).ok();
            if !a.ingest(path, content, csv) {
                skipped += 1;
            }
        }
        (a, skipped)
    }

    /// Reconstruct evidence from a [`Snapshot`] — same read discipline
    /// and same evidence as [`Assessment::from_store`] (differentially
    /// tested), with every document parsed and every CSV judged exactly
    /// once, at snapshot build time.
    pub fn from_snapshot(
        snap: &Snapshot,
        prefix: &str,
        cfg: &CriteriaConfig,
    ) -> (Assessment, usize) {
        let mut a = Assessment::new(cfg);
        let mut skipped = 0;
        for (path, digest) in snap.paths_under(prefix) {
            if !path.ends_with("report.json") {
                continue;
            }
            let csv_path = format!("{}results.csv", path.trim_end_matches("report.json"));
            let csv_ok = snap.csv_ok_at(&csv_path);
            match snap.doc(digest).and_then(|d| d.report.as_ref()) {
                Some(report) => a.ingest_parsed(path, digest, report, csv_ok),
                None => skipped += 1,
            }
        }
        (a, skipped)
    }

    /// Fold the per-digest facts into the monotone counters, optionally
    /// restricted to reports from simulated day `since_day` onwards (the
    /// gate's recency window — day-granular, like environment events,
    /// §6, so windowed verdicts never depend on queue-wait jitter).
    pub fn evidence(&self, since_day: Option<i64>) -> Evidence {
        let mut ev = Evidence::default();
        let mut pinned: BTreeMap<(&str, &str), usize> = BTreeMap::new();
        for (digest, f) in &self.facts {
            if let Some(day) = since_day {
                if f.time.day() < day {
                    continue;
                }
            }
            ev.reports += 1;
            ev.systems.insert(f.system.clone());
            if !f.success {
                continue;
            }
            ev.successful_runs += 1;
            if f.csv_ok {
                ev.csv_ok += 1;
            }
            if f.instrumented {
                ev.instrumented_runs += 1;
                ev.instrumented_systems.insert(f.system.clone());
            }
            if !f.stage.is_empty() {
                *pinned.entry((f.system.as_str(), f.stage.as_str())).or_default() += 1;
            }
            if f.seeded {
                ev.seeded_runs += 1;
            }
            if self.paths.get(digest).map(|p| p.len()).unwrap_or(0) >= 2 {
                ev.replay_commits += 1;
            }
        }
        ev.pinned_runs = pinned.values().copied().max().unwrap_or(0);
        ev
    }
}

/// The assessed maturity of one application: evidence + the level it has
/// actually earned, next to the level it declares.
#[derive(Debug, Clone, PartialEq)]
pub struct MaturityState {
    pub app: String,
    /// The level the repository currently *declares*: its onboarding
    /// claim until a maturity gate re-levels it — after a gated
    /// campaign this converges to the earned level (that is the point),
    /// while un-gated repositories keep showing the declared-vs-earned
    /// gap. The original claim survives in the campaign's transition
    /// history.
    pub declared: Maturity,
    /// The highest rung the evidence fully earns; `None` below
    /// runnability.
    pub earned: Option<Maturity>,
    pub evidence: Evidence,
    /// Every unmet criterion up to the top rung, with its shortfall.
    pub unmet: Vec<(Criterion, String)>,
    /// Unparseable documents skipped during reconstruction.
    pub skipped: usize,
}

impl MaturityState {
    /// The level the ladder floors at: an application below runnability
    /// still *is* somewhere — at the bottom rung, re-earning it.
    pub fn effective(&self) -> Maturity {
        self.earned.unwrap_or(Maturity::Runnability)
    }
}

/// Assess one repository's whole recorded history (no recency window).
pub fn assess_repo(repo: &BenchmarkRepo, cfg: &CriteriaConfig) -> MaturityState {
    let (a, skipped) = repo.with_snapshot(|snap| Assessment::from_snapshot(snap, "", cfg));
    let evidence = a.evidence(None);
    MaturityState {
        app: repo.name.clone(),
        declared: repo.maturity,
        earned: earned_level(&evidence, cfg),
        unmet: unmet(&evidence, cfg, Maturity::Reproducibility),
        evidence,
        skipped,
    }
}

/// Assess every repository in the world, sorted by name.
pub fn assess_world(
    world: &crate::coordinator::World,
    cfg: &CriteriaConfig,
) -> Vec<MaturityState> {
    world.repos.values().map(|r| assess_repo(r, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{results_csv, DataEntry, Experiment, Reporter};
    use crate::util::json::Json;

    pub(super) fn report(
        system: &str,
        day: i64,
        pipeline: u64,
        seed: u64,
        stage: &str,
        success: bool,
        instrumented: bool,
    ) -> (String, String) {
        let mut metrics = Json::obj().set("gflops_rate", 12.0);
        if instrumented {
            metrics.insert("tts_file", 4.5);
        }
        let r = Report {
            reporter: Reporter {
                tool: "exacb".into(),
                tool_version: "0.1".into(),
                pipeline_id: pipeline,
                commit: "c0ffee".into(),
                system: system.into(),
                timestamp: SimTime::from_days(day).iso8601(),
                seed,
                ..Default::default()
            },
            parameter: Json::obj(),
            experiment: Experiment {
                system: system.into(),
                software_version: stage.into(),
                timestamp: SimTime::from_days(day).add_secs(3 * 3600).iso8601(),
                ..Default::default()
            },
            data: vec![DataEntry {
                success,
                runtime: 5.0 + day as f64,
                nodes: 1,
                metrics,
                ..Default::default()
            }],
        };
        let csv = results_csv(&[&r]);
        (r.to_document(), csv)
    }

    #[test]
    fn evidence_counts_distinct_successes() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        for day in 0..3 {
            let (doc, csv) = report("jupiter", day, day as u64 + 1, 9, "stage-2026", true, false);
            assert!(a.ingest(&format!("p/{day}/report.json"), &doc, Some(&csv)));
        }
        let (bad, csv) = report("jupiter", 3, 4, 9, "stage-2026", false, false);
        a.ingest("p/3/report.json", &bad, Some(&csv));
        let ev = a.evidence(None);
        assert_eq!(ev.reports, 4);
        assert_eq!(ev.successful_runs, 3);
        assert_eq!(ev.csv_ok, 3);
        assert_eq!(ev.instrumented_runs, 0);
        assert_eq!(ev.pinned_runs, 3);
        assert_eq!(ev.seeded_runs, 3);
        assert_eq!(ev.replay_commits, 0);
        assert_eq!(earned_level(&ev, &cfg), Some(Maturity::Runnability));
    }

    #[test]
    fn instrumented_metrics_flip_the_counter() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        for day in 0..3 {
            let (doc, csv) = report("jupiter", day, day as u64 + 1, 9, "stage-2026", true, true);
            a.ingest(&format!("p/{day}/report.json"), &doc, Some(&csv));
        }
        let ev = a.evidence(None);
        assert_eq!(ev.instrumented_runs, 3);
        assert_eq!(
            ev.instrumented_systems.iter().collect::<Vec<_>>(),
            vec!["jupiter"]
        );
        assert_eq!(earned_level(&ev, &cfg), Some(Maturity::Instrumentability));
    }

    #[test]
    fn replay_footprint_is_a_second_path_not_a_second_report() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        for day in 0..3 {
            let (doc, csv) = report("jupiter", day, day as u64 + 1, 9, "stage-2026", true, true);
            a.ingest(&format!("p/{day}/report.json"), &doc, Some(&csv));
        }
        let before = a.evidence(None);
        assert_eq!(before.replay_commits, 0);
        // warm replay: the day-2 document re-committed under a new path
        let (doc, csv) = report("jupiter", 2, 3, 9, "stage-2026", true, true);
        a.ingest("p/99/report.json", &doc, Some(&csv));
        let after = a.evidence(None);
        assert_eq!(after.successful_runs, before.successful_runs);
        assert_eq!(after.instrumented_runs, before.instrumented_runs);
        assert_eq!(after.replay_commits, 1);
        assert_eq!(earned_level(&after, &cfg), Some(Maturity::Reproducibility));
        // replaying again is idempotent: the state no longer changes
        a.ingest("p/100/report.json", &doc, Some(&csv));
        assert_eq!(a.evidence(None), after);
    }

    #[test]
    fn window_ages_old_evidence_out() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        for day in 0..5 {
            let (doc, csv) = report("jupiter", day, day as u64 + 1, 9, "stage-2026", true, false);
            a.ingest(&format!("p/{day}/report.json"), &doc, Some(&csv));
        }
        assert_eq!(a.evidence(None).successful_runs, 5);
        assert_eq!(a.evidence(Some(3)).successful_runs, 2);
        assert_eq!(a.evidence(Some(6)).reports, 0);
    }

    #[test]
    fn unseeded_or_unpinned_reports_do_not_pin() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        let (doc, csv) = report("jupiter", 0, 1, 0, "", true, false);
        a.ingest("p/0/report.json", &doc, Some(&csv));
        let ev = a.evidence(None);
        assert_eq!(ev.successful_runs, 1);
        assert_eq!(ev.seeded_runs, 0);
        assert_eq!(ev.pinned_runs, 0);
    }

    #[test]
    fn garbage_documents_are_skipped() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        assert!(!a.ingest("p/report.json", "{broken", None));
        assert_eq!(a.evidence(None).reports, 0);
    }

    #[test]
    fn csv_contract_checks_base_columns() {
        assert!(csv_honours_contract(
            "system,version,queue,variant,jobid,nodes,taskspernode,threadspertasks,runtime,success,tts\n"
        ));
        assert!(!csv_honours_contract("system,nodes,runtime\n"));
        assert!(!csv_honours_contract(""));
    }

    #[test]
    fn missing_csv_sibling_fails_the_contract() {
        let cfg = CriteriaConfig::default();
        let mut a = Assessment::new(&cfg);
        let (doc, csv) = report("jupiter", 0, 1, 9, "stage-2026", true, false);
        a.ingest("p/0/report.json", &doc, None);
        let ev = a.evidence(None);
        assert_eq!(ev.successful_runs, 1);
        assert_eq!(ev.csv_ok, 0);
        // …but a sibling-less re-ingest never *revokes* earned csv
        // evidence (monotonicity): OR, not AND
        a.ingest("p/1/report.json", &doc, Some(&csv));
        assert_eq!(a.evidence(None).csv_ok, 1);
        a.ingest("p/2/report.json", &doc, None);
        assert_eq!(a.evidence(None).csv_ok, 1);
    }
}
