//! Multi-day JUREAP-style onboarding campaigns (DESIGN.md §10): drive a
//! [`crate::workloads::onboarding::OnboardingScenario`] through the
//! concurrent event core, day by day, and track every maturity
//! transition the `maturity-check@v1` gate produces.
//!
//! Per simulated day:
//!
//! 1. every application's source tree is synced to the day's scenario
//!    state (instrumentation added, breakage injected/fixed — a changed
//!    definition is a commit, exactly what the team's merge looks like);
//! 2. all pipelines start at the shared 03:00 trigger and are driven
//!    **together** by [`crate::coordinator::event_loop::drive`], so
//!    queue contention between onboarding applications is real;
//! 3. on replay-audit days a fresh execution cache is installed and the
//!    opted-in applications run a *second* wave: the warm replay
//!    re-commits each report byte-identically at a new path — the
//!    [`super::criteria::Criterion::ReplayVerified`] footprint — and is
//!    evidence of nothing else (it dedupes out of every counter). The
//!    cache is dropped afterwards: ordinary campaign days stay
//!    measurement days.

use std::collections::BTreeMap;

use crate::ci::Trigger;
use crate::coordinator::event_loop;
use crate::coordinator::repo::BenchmarkRepo;
use crate::coordinator::world::World;
use crate::store::ExecutionCache;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::table::Table;
use crate::util::timeutil::SimTime;
use crate::workloads::onboarding::OnboardingScenario;
use crate::workloads::portfolio::{Maturity, LEVELS};

/// One gate reading: the state of one application after one pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct MaturityRecord {
    pub day: i64,
    pub app: String,
    pub pipeline_ok: bool,
    /// Gate verdict (`confirmed`/`promoted`/`demoted`/…), `-` when the
    /// gate job produced no artifact.
    pub verdict: String,
    /// The repository's level after the gate ran.
    pub level: Maturity,
}

/// One level change of one application.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Transition {
    pub day: i64,
    pub app: String,
    pub from: Maturity,
    pub to: Maturity,
}

/// What an onboarding campaign produced, day by day.
#[derive(Debug, Clone, Default)]
pub struct OnboardingOutcome {
    pub records: Vec<MaturityRecord>,
    /// Every promotion/demotion, in the order it happened.
    pub transitions: Vec<Transition>,
    pub pipelines_run: usize,
    pub pipelines_succeeded: usize,
}

impl OnboardingOutcome {
    /// The application's level at the end of `day` (its last gate
    /// reading that day), if it ran.
    pub fn level_on(&self, app: &str, day: i64) -> Option<Maturity> {
        self.records
            .iter()
            .rev()
            .find(|r| r.app == app && r.day == day)
            .map(|r| r.level)
    }

    /// First day the application transitioned *to* `level`.
    pub fn transition_day(&self, app: &str, to: Maturity) -> Option<i64> {
        self.transitions
            .iter()
            .find(|t| t.app == app && t.to == to)
            .map(|t| t.day)
    }

    /// Every transition of one application, in order.
    pub fn transitions_of(&self, app: &str) -> Vec<&Transition> {
        self.transitions.iter().filter(|t| t.app == app).collect()
    }
}

/// Sync one repository file to the day's desired content; a change
/// moves the source commit (the framework sees a real merge).
fn sync_source(world: &mut World, repo_name: &str, path: &str, desired: &str, day: i64) {
    let Some(repo) = world.repos.get_mut(repo_name) else {
        return;
    };
    if repo.file(path) == Some(desired) {
        return;
    }
    let mut found = false;
    for (p, content) in repo.files.iter_mut() {
        if p == path {
            *content = desired.to_string();
            found = true;
        }
    }
    if !found {
        // a path the repository never carried is a new file, not a
        // silent no-op with a moved commit
        repo.files.push((path.to_string(), desired.to_string()));
    }
    repo.commit = crate::util::short_hash(format!("{desired}|day{day}").as_bytes());
}

/// Start one wave of pipelines (per-item PRNG streams, shared trigger)
/// and drive them together; record gate readings and transitions.
fn run_wave(
    world: &mut World,
    sc: &OnboardingScenario,
    wave: &[usize],
    day: i64,
    tag: &str,
    levels: &mut BTreeMap<String, Maturity>,
    out: &mut OnboardingOutcome,
) {
    let mut tasks = Vec::new();
    let mut started: Vec<usize> = Vec::new();
    for &i in wave {
        let name = sc.apps[i].app.name.clone();
        out.pipelines_run += 1;
        match world.begin_pipeline(&name, Trigger::Scheduled) {
            Ok(mut task) => {
                task.rng = Some(Prng::new(
                    world.seed
                        ^ crate::util::fnv1a(format!("{day}|{name}|{tag}").as_bytes()),
                ));
                tasks.push(task);
                started.push(i);
            }
            Err(_) => {} // counted as run, never as succeeded
        }
    }
    let pids = event_loop::drive(world, tasks);
    for (&i, pid) in started.iter().zip(pids) {
        let name = sc.apps[i].app.name.clone();
        let pipeline = world.pipeline(pid);
        let ok = pipeline.map(|p| p.succeeded()).unwrap_or(false);
        if ok {
            out.pipelines_succeeded += 1;
        }
        let verdict = pipeline
            .and_then(|p| {
                p.jobs
                    .iter()
                    .find(|j| j.name.ends_with(".maturity-check"))
            })
            .and_then(|j| j.artifact("maturity.json"))
            .and_then(|doc| Json::parse(doc).ok())
            .and_then(|v| v.str_of("verdict").map(str::to_string))
            .unwrap_or_else(|| "-".to_string());
        let level = world
            .repo(&name)
            .map(|r| r.maturity)
            .unwrap_or(sc.apps[i].declared);
        if let Some(prev) = levels.insert(name.clone(), level) {
            if prev != level {
                out.transitions.push(Transition {
                    day,
                    app: name.clone(),
                    from: prev,
                    to: level,
                });
            }
        }
        out.records.push(MaturityRecord {
            day,
            app: name,
            pipeline_ok: ok,
            verdict,
            level,
        });
    }
}

/// Onboard the scenario's portfolio and run the whole multi-day
/// campaign. Applications start at their *declared* levels; every level
/// they hold at the end was earned from recorded evidence.
pub fn run_onboarding(world: &mut World, sc: &OnboardingScenario) -> OnboardingOutcome {
    for (i, oa) in sc.apps.iter().enumerate() {
        world.add_repo(
            BenchmarkRepo::new(&oa.app.name)
                .with_file("benchmark/jube/app.yml", &oa.jube_file(0))
                .with_file(".gitlab-ci.yml", &sc.ci_file(i))
                .with_maturity(oa.declared),
        );
    }
    let mut levels: BTreeMap<String, Maturity> = sc
        .apps
        .iter()
        .map(|oa| (oa.app.name.clone(), oa.declared))
        .collect();
    let mut out = OnboardingOutcome::default();
    let all: Vec<usize> = (0..sc.apps.len()).collect();
    for day in 0..sc.days {
        world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
        for oa in &sc.apps {
            sync_source(
                world,
                &oa.app.name,
                "benchmark/jube/app.yml",
                &oa.jube_file(day),
                day,
            );
        }
        let audit = sc.is_verification_day(day);
        let stashed = if audit {
            let s = world.cache.take();
            world.cache = Some(ExecutionCache::new());
            Some(s)
        } else {
            None
        };
        run_wave(world, sc, &all, day, "run", &mut levels, &mut out);
        if audit {
            let opted: Vec<usize> = (0..sc.apps.len())
                .filter(|&i| sc.apps[i].verifying_on(day))
                .collect();
            run_wave(world, sc, &opted, day, "audit", &mut levels, &mut out);
        }
        if let Some(s) = stashed {
            // the audit cache dies with the day: campaign days stay
            // measurement days
            world.cache = s;
        }
    }
    out
}

/// Cross-application readiness: per-domain distribution of the levels
/// the portfolio currently *holds* (the `exacb jureap` headline table).
pub fn domain_distribution(sc: &OnboardingScenario, world: &World) -> Table {
    let mut t = Table::new(&[
        "domain",
        "apps",
        "runnability",
        "instrumentability",
        "reproducibility",
    ]);
    let mut domains: Vec<&str> = sc.apps.iter().map(|a| a.app.domain.as_str()).collect();
    domains.sort();
    domains.dedup();
    for domain in domains {
        let mut counts = [0usize; 3];
        let mut apps = 0usize;
        for oa in sc.apps.iter().filter(|a| a.app.domain == domain) {
            apps += 1;
            let level = world
                .repo(&oa.app.name)
                .map(|r| r.maturity)
                .unwrap_or(oa.declared);
            counts[LEVELS.iter().position(|l| *l == level).unwrap_or(0)] += 1;
        }
        t.push_row(vec![
            domain.to_string(),
            apps.to_string(),
            counts[0].to_string(),
            counts[1].to_string(),
            counts[2].to_string(),
        ]);
    }
    t
}

/// The promotion/demotion timeline as a table.
pub fn promotion_timeline(out: &OnboardingOutcome) -> Table {
    let mut t = Table::new(&["day", "app", "from", "to", "change"]);
    if out.transitions.is_empty() {
        t.push_placeholder("(no level changes)");
        return t;
    }
    for tr in &out.transitions {
        t.push_row(vec![
            tr.day.to_string(),
            tr.app.clone(),
            tr.from.name().to_string(),
            tr.to.name().to_string(),
            if tr.to > tr.from { "promotion" } else { "demotion" }.to_string(),
        ]);
    }
    t
}

/// Applications currently eligible for energy studies: holding the
/// **reproducibility** rung and nothing less (§VI-B studies compare
/// energy across frequencies, which is meaningless without byte-level
/// replayability and pinned environments).
pub fn energy_eligible(sc: &OnboardingScenario, world: &World) -> Vec<String> {
    sc.apps
        .iter()
        .filter(|oa| {
            world
                .repo(&oa.app.name)
                .map(|r| r.maturity == Maturity::Reproducibility)
                .unwrap_or(false)
        })
        .map(|oa| oa.app.name.clone())
        .collect()
}

/// The complement of [`energy_eligible`]: onboarded applications the
/// reproducibility-only rule skips, with the rung each currently holds
/// — so energy campaigns (DESIGN.md §11) can name every exclusion in
/// their log instead of silently shrinking the study.
pub fn energy_excluded(sc: &OnboardingScenario, world: &World) -> Vec<(String, Maturity)> {
    sc.apps
        .iter()
        .filter_map(|oa| {
            let level = world.repo(&oa.app.name)?.maturity;
            (level != Maturity::Reproducibility).then(|| (oa.app.name.clone(), level))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::onboarding::OnboardingApp;
    use crate::workloads::portfolio::PortfolioApp;
    use crate::workloads::scalable::AppModel;

    fn tiny_app(name: &str, declared: Maturity) -> OnboardingApp {
        OnboardingApp {
            app: PortfolioApp {
                name: name.to_string(),
                domain: "materials".to_string(),
                maturity: declared,
                model: AppModel {
                    name: name.to_string(),
                    gflops_total: 20_000.0,
                    steps: 10,
                    ..AppModel::default()
                },
                failure_rate: 0.0,
                nodes: 1,
            },
            declared,
            instrument_from: None,
            verify_from: None,
            break_day: None,
            fix_day: None,
        }
    }

    fn tiny_scenario(days: i64) -> OnboardingScenario {
        OnboardingScenario {
            apps: vec![],
            days,
            machines: vec!["jupiter".to_string()],
            queue: "all".to_string(),
            seed: 4242,
            verify_every: 4,
            min_runs: 3,
            min_instrumented: 3,
            window_days: 6,
        }
    }

    #[test]
    fn healthy_runnable_app_earns_its_level_and_keeps_it() {
        let mut sc = tiny_scenario(5);
        sc.apps.push(tiny_app("steady", Maturity::Runnability));
        let mut world = World::new(sc.seed);
        let out = run_onboarding(&mut world, &sc);
        // 5 daily runs; the day-3 audit wave is empty (no replay opt-in)
        assert_eq!(out.pipelines_run, 5);
        assert_eq!(out.pipelines_succeeded, 5);
        assert!(out.transitions_of("steady").is_empty(), "{:?}", out.transitions);
        assert_eq!(out.level_on("steady", 4), Some(Maturity::Runnability));
        // gate verdicts move from insufficient-evidence to confirmed
        let verdicts: Vec<&str> = out
            .records
            .iter()
            .filter(|r| r.app == "steady")
            .map(|r| r.verdict.as_str())
            .collect();
        assert_eq!(verdicts[0], "insufficient-evidence");
        assert!(verdicts[2..].iter().all(|v| *v == "confirmed"), "{verdicts:?}");
    }

    #[test]
    fn overclaimed_app_demotes_on_first_judgeable_day() {
        // declared instrumentability, but the definition never extracts
        // an instrumentation metric: the claim cannot be re-earned
        let mut sc = tiny_scenario(5);
        sc.apps
            .push(tiny_app("claims-too-much", Maturity::Instrumentability));
        let mut world = World::new(sc.seed);
        let out = run_onboarding(&mut world, &sc);
        assert_eq!(
            out.transition_day("claims-too-much", Maturity::Runnability),
            Some(sc.min_runs as i64 - 1),
            "{:?}",
            out.transitions
        );
        assert_eq!(
            world.repo("claims-too-much").unwrap().maturity,
            Maturity::Runnability
        );
    }

    #[test]
    fn instrumented_app_with_audit_reaches_the_top_rung() {
        let mut sc = tiny_scenario(6);
        let mut app = tiny_app("golden", Maturity::Reproducibility);
        app.instrument_from = Some(0);
        app.verify_from = Some(0);
        sc.apps.push(app);
        let mut world = World::new(sc.seed);
        let out = run_onboarding(&mut world, &sc);
        // earns instrumentability on day 2, demoting from the declared
        // top rung, then proves replay on the day-3 audit
        assert_eq!(
            out.transition_day("golden", Maturity::Instrumentability),
            Some(2),
            "{:?}",
            out.transitions
        );
        assert_eq!(
            out.transition_day("golden", Maturity::Reproducibility),
            Some(3),
            "{:?}",
            out.transitions
        );
        assert_eq!(energy_eligible(&sc, &world), vec!["golden".to_string()]);
        // the audit wave replayed: cache evidence exists in the store.
        // Read through the shared snapshot — a byte-identical replay is
        // two store paths mapping to one content digest.
        let repo = world.repo("golden").unwrap();
        let digests: Vec<String> = repo.with_snapshot(|snap| {
            snap.paths_under("")
                .filter(|(p, _)| p.ends_with("report.json"))
                .map(|(_, d)| d.to_string())
                .collect()
        });
        let mut sorted = digests.clone();
        sorted.sort();
        sorted.dedup();
        assert!(sorted.len() < digests.len(), "a byte-identical replay was committed");
    }

    /// Pinning test for the gate's snapshot read path: on a real
    /// onboarding store the legacy full walk and the snapshot fold to
    /// the same evidence and the same skip count.
    #[test]
    fn snapshot_and_full_walk_assessments_agree() {
        use crate::maturity::assess::Assessment;
        use crate::maturity::criteria::CriteriaConfig;
        let mut sc = tiny_scenario(6);
        let mut app = tiny_app("golden", Maturity::Reproducibility);
        app.instrument_from = Some(0);
        app.verify_from = Some(0);
        sc.apps.push(app);
        let mut world = World::new(sc.seed);
        run_onboarding(&mut world, &sc);
        let repo = world.repo("golden").unwrap();
        let cfg = CriteriaConfig::default();
        let (walk, walk_skipped) =
            Assessment::from_store(&repo.store, "exacb.data", "", &cfg);
        let (snap_a, snap_skipped) =
            repo.with_snapshot(|snap| Assessment::from_snapshot(snap, "", &cfg));
        assert!(walk.evidence(None).reports > 0, "campaign recorded nothing");
        assert_eq!(walk.evidence(None), snap_a.evidence(None));
        assert_eq!(walk_skipped, snap_skipped);
    }

    #[test]
    fn distribution_and_timeline_render() {
        let mut sc = tiny_scenario(4);
        sc.apps.push(tiny_app("a1", Maturity::Runnability));
        let mut world = World::new(sc.seed);
        let out = run_onboarding(&mut world, &sc);
        let dist = domain_distribution(&sc, &world);
        assert_eq!(dist.len(), 1);
        assert_eq!(dist.rows[0][0], "materials");
        assert_eq!(dist.rows[0][2], "1"); // holds runnability
        let tl = promotion_timeline(&out);
        assert_eq!(tl.rows.len(), 1); // placeholder
        assert!(tl.rows[0][0].contains("no level changes"));
    }
}
