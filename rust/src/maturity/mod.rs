//! Evidence-based maturity ladder: assessment, promotion gates, and
//! JUREAP-style onboarding campaigns (DESIGN.md §10).
//!
//! The paper's second contribution is the incremental-adoption pathway —
//! benchmarks "evolve from basic runnability to more advanced
//! instrumentation and reproducibility". Everywhere else in the crate a
//! [`crate::workloads::portfolio::Maturity`] level is a *declaration*
//! assigned at generation; this top-layer module makes it **earned from
//! recorded evidence**:
//!
//! * [`criteria`] — each rung as a typed checklist of monotone
//!   thresholds over evidence counters;
//! * [`assess`] — digest-keyed evidence reconstruction from `exacb.data`
//!   artifacts only (order-independent, replay-deduped — the same
//!   properties as the tracking history, §9);
//! * [`gate`] — the `maturity-check@v1` CI component: blocks or grants
//!   promotion, re-levels repositories in assess mode, and emits the
//!   `maturity.json` sidecar (never in `report.json`);
//! * [`campaign`] — multi-day onboarding over the concurrent event
//!   core: apps start at declared levels but must re-earn them, flaky
//!   apps demote, fixed ones re-promote, and replay-audit days produce
//!   the byte-identical cache-replay proof reproducibility demands.
//!
//! [`maturity_table`] and [`crate::coordinator::World::maturity_table`]
//! are the a-posteriori entry points behind `exacb jureap`.

pub mod assess;
pub mod campaign;
pub mod criteria;
pub mod gate;

pub use assess::{
    assess_repo, assess_world, csv_honours_contract, Assessment, Evidence, MaturityState,
};
pub use campaign::{
    domain_distribution, energy_eligible, energy_excluded, promotion_timeline, run_onboarding,
    MaturityRecord, OnboardingOutcome, Transition,
};
pub use criteria::{
    checklist, earned_level, parse_metric_list, unmet, CriteriaConfig, Criterion, CRITERIA,
};
pub use gate::{run_maturity_gate, GatePolicy};

use crate::coordinator::World;
use crate::util::table::Table;

/// Cross-application readiness table: one row per repository with its
/// declared vs earned level and the evidence counters behind it.
/// Labelled empty row when nothing is onboarded yet.
pub fn maturity_table(world: &World, cfg: &CriteriaConfig) -> Table {
    let mut t = Table::new(&[
        "benchmark",
        "declared",
        "earned",
        "runs_ok",
        "instrumented",
        "systems",
        "replay",
        "unmet",
    ]);
    let states = assess_world(world, cfg);
    if states.is_empty() {
        t.push_placeholder("(no onboarded repositories)");
        return t;
    }
    for s in states {
        t.push_row(vec![
            s.app.clone(),
            s.declared.name().to_string(),
            s.earned.map(|l| l.name()).unwrap_or("-").to_string(),
            s.evidence.successful_runs.to_string(),
            s.evidence.instrumented_runs.to_string(),
            s.evidence.systems.len().to_string(),
            s.evidence.replay_commits.to_string(),
            s.unmet
                .first()
                .map(|(c, _)| c.name().to_string())
                .unwrap_or_else(|| "-".to_string()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::Trigger;
    use crate::coordinator::BenchmarkRepo;

    #[test]
    fn maturity_table_labels_empty_world() {
        let world = World::new(1);
        let t = maturity_table(&world, &CriteriaConfig::default());
        assert_eq!(t.rows.len(), 1);
        assert!(t.rows[0][0].contains("no onboarded"), "{:?}", t.rows);
    }

    #[test]
    fn maturity_table_over_recorded_history() {
        use crate::util::timeutil::SimTime;
        let mut world = World::new(7);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        for d in 0..3 {
            world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
            world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
        }
        let t = world.maturity_table();
        assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
        assert_eq!(t.rows[0][0], "logmap");
        assert_eq!(t.rows[0][1], "reproducibility"); // declared
        // earned: logmap extracts kernel_time, so three successful runs
        // reach instrumentability — but nothing has replay-proven it
        assert_eq!(t.rows[0][2], "instrumentability");
        assert_eq!(t.rows[0][3], "3");
        assert_eq!(t.rows[0][7], "replay-verified");
    }
}
