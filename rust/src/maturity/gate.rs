//! The `maturity-check@v1` CI component: the gate that blocks or grants
//! promotion on the evidence ladder (DESIGN.md §10).
//!
//! Policy:
//!
//! 1. Rebuild the application's evidence from its `exacb.data` branch
//!    under the gate's `prefix`, restricted to the last `window_days`
//!    simulated days when a recency window is configured (0 = whole
//!    history). The window is what lets levels *decay*: evidence ages
//!    out, flaky applications demote, fixed ones re-earn.
//! 2. Compute the earned level against the typed criteria checklist.
//!    Fewer than `min_runs` distinct reports in the window → verdict
//!    `insufficient-evidence`: the gate passes and **never touches** the
//!    declared level (young repositories must not be graded on noise —
//!    the same young-repo discipline as the regression gate's
//!    `no-baseline` rule, §9).
//! 3. With a `target` level set, the gate **blocks**: earned < target
//!    fails the pipeline, naming every unmet criterion and its
//!    shortfall. Without a target (assess mode) it **re-levels**: the
//!    repository's maturity becomes the earned level (floored at
//!    runnability), whether that is a promotion, a demotion, or a
//!    confirmation.
//! 4. The verdict lands in a `maturity.json` artifact — a sidecar like
//!    `cache.json` and `regressions.json`, **never** part of
//!    `report.json` (recorded history must not contain opinions about
//!    itself).

use crate::ci::{CiJob, CiJobState};
use crate::coordinator::repo::BenchmarkRepo;
use crate::coordinator::world::World;
use crate::util::json::Json;
use crate::workloads::portfolio::Maturity;

use super::assess::Assessment;
use super::criteria::{
    earned_level, parse_metric_list, unmet, CriteriaConfig, CRITERIA,
};

/// Resolved gate policy (post component-schema validation).
#[derive(Debug, Clone, PartialEq)]
pub struct GatePolicy {
    /// Level the gate demands; `None` = assess mode (re-level, never
    /// block).
    pub target: Option<Maturity>,
    pub cfg: CriteriaConfig,
    /// Evidence recency window in simulated days; 0 = whole history.
    pub window_days: u64,
    /// Whether the gate writes the earned level back to the repository.
    pub update: bool,
}

impl GatePolicy {
    /// Resolve policy inputs, falling back to the canonical catalog
    /// defaults ([`crate::ci::component::maturity_check_defaults`]).
    /// An unknown `target` level string is a loud error surfaced through
    /// the CI validation job (mirroring `Launcher::parse`).
    pub fn from_inputs(inputs: &Json) -> Result<GatePolicy, String> {
        use crate::ci::component::maturity_check_defaults as d;
        let target = match inputs.str_of("target").unwrap_or(d::TARGET) {
            "" => None,
            s => Some(Maturity::parse(s).map_err(|e| e.to_string())?),
        };
        let cfg = CriteriaConfig {
            min_runs: inputs.u64_of("min_runs").unwrap_or(d::MIN_RUNS).max(1) as usize,
            min_instrumented: inputs
                .u64_of("min_instrumented")
                .unwrap_or(d::MIN_INSTRUMENTED)
                .max(1) as usize,
            min_systems: inputs
                .u64_of("min_systems")
                .unwrap_or(d::MIN_SYSTEMS)
                .max(1) as usize,
            instrument_metrics: parse_metric_list(
                inputs
                    .str_of("instrument_metrics")
                    .unwrap_or(d::INSTRUMENT_METRICS),
            ),
        };
        Ok(GatePolicy {
            target,
            cfg,
            window_days: inputs.u64_of("window_days").unwrap_or(d::WINDOW_DAYS),
            update: inputs.bool_of("update").unwrap_or(true)
                && inputs.str_of("update") != Some("false"),
        })
    }
}

fn level_json(level: Option<Maturity>) -> Json {
    match level {
        Some(l) => Json::Str(l.name().to_string()),
        None => Json::Null,
    }
}

/// Run the maturity gate for one pipeline. Returns the single gate CI
/// job; the `maturity.json` sidecar is attached as its artifact.
pub fn run_maturity_gate(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    inputs: &Json,
    pipeline_id: u64,
) -> Vec<CiJob> {
    let policy = match GatePolicy::from_inputs(inputs) {
        Ok(p) => p,
        Err(e) => {
            let mut job = CiJob::new(world.ids.job_id(), "maturity-check@v1.validate");
            job.log_line(format!("input validation failed: {e}"));
            job.state = CiJobState::Failed;
            return vec![job];
        }
    };
    let prefix = inputs.str_of("prefix").unwrap_or("").to_string();
    let mut job = CiJob::new(world.ids.job_id(), &format!("{prefix}.maturity-check"));
    job.state = CiJobState::Running;

    // evidence: recorded artifacts only, optionally recency-windowed
    // (day-granular, like environment events — §6)
    let since_day = if policy.window_days > 0 {
        Some(world.now().day() - policy.window_days as i64 + 1)
    } else {
        None
    };
    // read via the shared snapshot (DESIGN.md §12): a gate firing
    // through the event loop pays O(delta since last reader), not a
    // full store re-walk per firing
    let (assessment, skipped) = repo.with_snapshot(|snap| {
        Assessment::from_snapshot(snap, &format!("{prefix}/"), &policy.cfg)
    });
    let evidence = assessment.evidence(since_day);
    let earned = earned_level(&evidence, &policy.cfg);
    let declared = repo.maturity;

    // ---- decide ------------------------------------------------------
    let judgeable = evidence.reports >= policy.cfg.min_runs;
    let new_level = earned.unwrap_or(Maturity::Runnability);
    let (verdict, failed) = if let Some(target) = policy.target {
        // an explicit promotion request is always judged: asking for a
        // rung without the evidence for it is a denial, however young
        // the repository
        if earned.map_or(false, |e| e >= target) {
            ("granted", false)
        } else {
            ("denied", true)
        }
    } else if !judgeable {
        // assess mode on a young repository: never grade on noise (the
        // same young-repo discipline as the regression gate, §9)
        ("insufficient-evidence", false)
    } else if new_level > declared {
        ("promoted", false)
    } else if new_level < declared {
        ("demoted", false)
    } else {
        ("confirmed", false)
    };
    // Assess mode re-levels freely (promotion, demotion, confirmation);
    // a *target* gate only ever blocks or grants — on grant it may
    // promote, but never silently demote a repository declared above
    // the requested rung.
    let written_level = if policy.target.is_some() {
        declared.max(new_level)
    } else {
        new_level
    };
    let relevel = policy.update
        && !failed
        && verdict != "insufficient-evidence"
        && written_level != declared;
    if relevel {
        repo.maturity = written_level;
    }
    if crate::obs::metrics_on() {
        crate::obs::count_app(&repo.name, crate::obs::Ctr::MaturityChecks, 1);
        if verdict == "promoted" {
            crate::obs::count_app(&repo.name, crate::obs::Ctr::MaturityPromotions, 1);
        } else if verdict == "demoted" {
            crate::obs::count_app(&repo.name, crate::obs::Ctr::MaturityDemotions, 1);
        }
    }

    // ---- maturity.json sidecar ---------------------------------------
    let judge_through = policy.target.unwrap_or(Maturity::Reproducibility);
    let missing = unmet(&evidence, &policy.cfg, judge_through);
    let mut criteria_json = Json::arr();
    for c in CRITERIA {
        let result = c.check(&evidence, &policy.cfg);
        criteria_json.push(
            Json::obj()
                .set("criterion", c.name())
                .set("level", c.level().name())
                .set("satisfied", result.is_ok())
                .set(
                    "detail",
                    result.err().unwrap_or_else(|| "met".to_string()).as_str(),
                ),
        );
    }
    let mut systems = Json::arr();
    for s in &evidence.systems {
        systems.push(s.as_str());
    }
    let mut unmet_json = Json::arr();
    for (c, reason) in &missing {
        unmet_json.push(
            Json::obj()
                .set("criterion", c.name())
                .set("missing", reason.as_str()),
        );
    }
    let doc = Json::obj()
        .set("component", "maturity-check@v1")
        .set("prefix", prefix.as_str())
        .set("pipeline_id", pipeline_id)
        .set("commit", repo.commit.as_str())
        .set("declared", declared.name())
        .set("earned", level_json(earned))
        .set("level", repo.maturity.name())
        .set("target", level_json(policy.target))
        .set("verdict", verdict)
        .set("window_days", policy.window_days)
        .set(
            "evidence",
            Json::obj()
                .set("reports", evidence.reports)
                .set("successful_runs", evidence.successful_runs)
                .set("csv_ok", evidence.csv_ok)
                .set("instrumented_runs", evidence.instrumented_runs)
                .set("systems", systems)
                .set("instrumented_systems", evidence.instrumented_systems.len())
                .set("pinned_runs", evidence.pinned_runs)
                .set("seeded_runs", evidence.seeded_runs)
                .set("replay_commits", evidence.replay_commits)
                .set("unparseable_skipped", skipped),
        )
        .set("criteria", criteria_json)
        .set("unmet", unmet_json);
    job.add_artifact("maturity.json", &doc.pretty());
    job.output = Json::obj()
        .set("verdict", verdict)
        .set("level", repo.maturity.name());

    job.log_line(format!(
        "evidence under {prefix}/: {} reports ({} successful, {} instrumented, \
         {} replay-proven) on {} system(s){}",
        evidence.reports,
        evidence.successful_runs,
        evidence.instrumented_runs,
        evidence.replay_commits,
        evidence.systems.len(),
        match since_day {
            Some(d) => format!(", window from day {d}"),
            None => String::new(),
        }
    ));
    for (c, reason) in &missing {
        job.log_line(format!("unmet [{}] {}: {}", c.level(), c.name(), reason));
    }
    job.log_line(format!(
        "declared {declared}, earned {}: {verdict}{}",
        earned.map(|l| l.name()).unwrap_or("none"),
        if failed { " → FAIL" } else { "" }
    ));
    job.state = if failed {
        CiJobState::Failed
    } else {
        CiJobState::Success
    };
    vec![job]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_resolves_defaults_and_bounds() {
        let p = GatePolicy::from_inputs(&Json::obj()).unwrap();
        assert_eq!(p.target, None);
        assert_eq!(p.cfg.min_runs, 3);
        assert_eq!(p.cfg.min_instrumented, 3);
        assert_eq!(p.cfg.min_systems, 1);
        assert_eq!(p.window_days, 0);
        assert!(p.update);
        assert!(p.cfg.is_instrument_metric("kernel_time"));

        let p = GatePolicy::from_inputs(
            &Json::obj()
                .set("target", "Reproducibility")
                .set("min_runs", 0u64)
                .set("update", "false"),
        )
        .unwrap();
        assert_eq!(p.target, Some(Maturity::Reproducibility));
        assert_eq!(p.cfg.min_runs, 1); // clamped up
        assert!(!p.update);
    }

    #[test]
    fn unknown_target_is_a_loud_error() {
        let err = GatePolicy::from_inputs(&Json::obj().set("target", "reproducable"))
            .unwrap_err();
        assert!(err.contains("unknown maturity level"), "{err}");
        assert!(err.contains("reproducable"), "{err}");
    }

    #[test]
    fn empty_store_passes_without_touching_the_level() {
        let mut world = World::new(1);
        let mut repo =
            BenchmarkRepo::new("young").with_maturity(Maturity::Instrumentability);
        let jobs = run_maturity_gate(
            &mut world,
            &mut repo,
            &Json::obj().set("prefix", "jupiter.young"),
            1,
        );
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].state, CiJobState::Success);
        let doc = Json::parse(jobs[0].artifact("maturity.json").unwrap()).unwrap();
        assert_eq!(doc.str_of("verdict"), Some("insufficient-evidence"));
        assert_eq!(repo.maturity, Maturity::Instrumentability, "level untouched");
    }

    #[test]
    fn bad_target_fails_validation_job() {
        let mut world = World::new(1);
        let mut repo = BenchmarkRepo::new("r");
        let jobs = run_maturity_gate(
            &mut world,
            &mut repo,
            &Json::obj().set("prefix", "p").set("target", "wat"),
            1,
        );
        assert_eq!(jobs[0].state, CiJobState::Failed);
        assert!(jobs[0].log[0].contains("input validation failed"));
    }
}
