//! Typed maturity criteria: each ladder rung as an explicit checklist
//! over recorded evidence (DESIGN.md §10).
//!
//! Every [`Criterion`] is a **monotone** predicate over the evidence
//! counters in [`super::assess::Evidence`] — thresholds on counts, never
//! universally-quantified conditions over all reports. Monotonicity is
//! what makes promotion monotone in evidence (property-tested in
//! `tests/integration_maturity.rs`): recording more evidence can only
//! keep or raise the earned level, never silently lower it. Levels
//! *decay* only through the gate's recency window
//! ([`super::gate::GatePolicy::window_days`]), where old evidence ages
//! out — which is how flaky applications demote.

use crate::ci::component::maturity_check_defaults as defaults;
use crate::workloads::portfolio::{Maturity, LEVELS};

use super::assess::Evidence;

/// Resolved criteria thresholds (post component-schema validation).
/// `Default` mirrors the `maturity-check@v1` catalog defaults
/// ([`crate::ci::component::maturity_check_defaults`]) so schema-resolved
/// and direct callers can never drift apart.
#[derive(Debug, Clone, PartialEq)]
pub struct CriteriaConfig {
    /// Distinct successful reports required for runnability.
    pub min_runs: usize,
    /// Distinct instrumented successful reports for instrumentability.
    pub min_instrumented: usize,
    /// Distinct systems that must carry instrumented evidence.
    pub min_systems: usize,
    /// Metric names that count as instrumentation (beyond the Table-I
    /// baseline): analysis extractions, kernel timings, energy.
    pub instrument_metrics: Vec<String>,
}

impl Default for CriteriaConfig {
    fn default() -> Self {
        CriteriaConfig {
            min_runs: defaults::MIN_RUNS as usize,
            min_instrumented: defaults::MIN_INSTRUMENTED as usize,
            min_systems: defaults::MIN_SYSTEMS as usize,
            instrument_metrics: parse_metric_list(defaults::INSTRUMENT_METRICS),
        }
    }
}

/// Split a comma-separated metric list (the `instrument_metrics` input).
pub fn parse_metric_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|m| !m.is_empty())
        .map(str::to_string)
        .collect()
}

impl CriteriaConfig {
    pub fn is_instrument_metric(&self, name: &str) -> bool {
        self.instrument_metrics.iter().any(|m| m == name)
    }
}

/// One checklist item of the evidence-based ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Criterion {
    /// ≥ `min_runs` distinct successful reports recorded.
    SuccessfulRuns,
    /// ≥ `min_runs` of them paired with a Table-I-conformant
    /// `results.csv` sibling.
    CsvContract,
    /// ≥ `min_instrumented` distinct successful reports carrying an
    /// instrumentation metric.
    InstrumentedRuns,
    /// Instrumented evidence on ≥ `min_systems` distinct systems.
    InstrumentedSystems,
    /// ≥ `min_runs` successful reports pinning the *same*
    /// (system, software-stage) fingerprint in their provenance.
    PinnedStage,
    /// ≥ `min_runs` successful reports recording the reproduction seed.
    SeededProvenance,
    /// ≥ 1 report re-committed byte-identically at a second store path —
    /// the footprint only a warm cache replay leaves (`cache.json` hits
    /// with an unchanged recorded document).
    ReplayVerified,
}

/// Every criterion, grouped by rung, lowest rung first.
pub const CRITERIA: [Criterion; 7] = [
    Criterion::SuccessfulRuns,
    Criterion::CsvContract,
    Criterion::InstrumentedRuns,
    Criterion::InstrumentedSystems,
    Criterion::PinnedStage,
    Criterion::SeededProvenance,
    Criterion::ReplayVerified,
];

impl Criterion {
    /// The rung this criterion belongs to.
    pub fn level(&self) -> Maturity {
        match self {
            Criterion::SuccessfulRuns | Criterion::CsvContract => Maturity::Runnability,
            Criterion::InstrumentedRuns | Criterion::InstrumentedSystems => {
                Maturity::Instrumentability
            }
            Criterion::PinnedStage
            | Criterion::SeededProvenance
            | Criterion::ReplayVerified => Maturity::Reproducibility,
        }
    }

    /// Stable kebab-case identifier (used in `maturity.json` and denial
    /// messages).
    pub fn name(&self) -> &'static str {
        match self {
            Criterion::SuccessfulRuns => "successful-runs",
            Criterion::CsvContract => "csv-contract",
            Criterion::InstrumentedRuns => "instrumented-runs",
            Criterion::InstrumentedSystems => "instrumented-systems",
            Criterion::PinnedStage => "pinned-stage",
            Criterion::SeededProvenance => "seeded-provenance",
            Criterion::ReplayVerified => "replay-verified",
        }
    }

    /// Check this criterion against the evidence. `Err` carries the
    /// human-readable shortfall (what is missing, with the counts).
    pub fn check(&self, ev: &Evidence, cfg: &CriteriaConfig) -> Result<(), String> {
        let need = |have: usize, want: usize, what: &str| {
            if have >= want {
                Ok(())
            } else {
                Err(format!("{have}/{want} {what}"))
            }
        };
        match self {
            Criterion::SuccessfulRuns => need(
                ev.successful_runs,
                cfg.min_runs,
                "distinct successful reports",
            ),
            Criterion::CsvContract => need(
                ev.csv_ok,
                cfg.min_runs,
                "successful reports with a Table-I results.csv",
            ),
            Criterion::InstrumentedRuns => need(
                ev.instrumented_runs,
                cfg.min_instrumented,
                "instrumented successful reports",
            ),
            Criterion::InstrumentedSystems => need(
                ev.instrumented_systems.len(),
                cfg.min_systems,
                "systems with instrumented evidence",
            ),
            Criterion::PinnedStage => need(
                ev.pinned_runs,
                cfg.min_runs,
                "successful reports pinning one (system, stage) fingerprint",
            ),
            Criterion::SeededProvenance => need(
                ev.seeded_runs,
                cfg.min_runs,
                "successful reports with seeded provenance",
            ),
            Criterion::ReplayVerified => need(
                ev.replay_commits,
                1,
                "byte-identical cache-replay commits",
            ),
        }
    }
}

/// The cumulative checklist for earning `level`: every criterion of that
/// rung and of all rungs below it.
pub fn checklist(level: Maturity) -> Vec<Criterion> {
    CRITERIA
        .iter()
        .filter(|c| c.level() <= level)
        .copied()
        .collect()
}

/// The highest rung whose full (cumulative) checklist the evidence
/// satisfies; `None` when even runnability is unearned.
pub fn earned_level(ev: &Evidence, cfg: &CriteriaConfig) -> Option<Maturity> {
    let mut earned = None;
    for level in LEVELS {
        let rung_ok = CRITERIA
            .iter()
            .filter(|c| c.level() == level)
            .all(|c| c.check(ev, cfg).is_ok());
        if rung_ok {
            earned = Some(level);
        } else {
            break;
        }
    }
    earned
}

/// Every unmet criterion up to and including `through`, with its named
/// shortfall — the gate's denial detail.
pub fn unmet(ev: &Evidence, cfg: &CriteriaConfig, through: Maturity) -> Vec<(Criterion, String)> {
    checklist(through)
        .into_iter()
        .filter_map(|c| c.check(ev, cfg).err().map(|reason| (c, reason)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn ev(successful: usize, instrumented: usize, replay: usize) -> Evidence {
        let mut systems = BTreeSet::new();
        systems.insert("jupiter".to_string());
        Evidence {
            reports: successful,
            successful_runs: successful,
            csv_ok: successful,
            instrumented_runs: instrumented,
            systems: systems.clone(),
            instrumented_systems: if instrumented > 0 {
                systems
            } else {
                BTreeSet::new()
            },
            pinned_runs: successful,
            seeded_runs: successful,
            replay_commits: replay,
        }
    }

    #[test]
    fn levels_earn_in_order() {
        let cfg = CriteriaConfig::default();
        assert_eq!(earned_level(&ev(0, 0, 0), &cfg), None);
        assert_eq!(earned_level(&ev(2, 2, 0), &cfg), None);
        assert_eq!(
            earned_level(&ev(3, 0, 0), &cfg),
            Some(Maturity::Runnability)
        );
        assert_eq!(
            earned_level(&ev(5, 3, 0), &cfg),
            Some(Maturity::Instrumentability)
        );
        assert_eq!(
            earned_level(&ev(5, 3, 1), &cfg),
            Some(Maturity::Reproducibility)
        );
        // a higher rung never rescues a broken lower one
        let mut broken = ev(5, 3, 1);
        broken.csv_ok = 0;
        assert_eq!(earned_level(&broken, &cfg), None);
    }

    #[test]
    fn unmet_names_the_shortfall() {
        let cfg = CriteriaConfig::default();
        let missing = unmet(&ev(3, 0, 0), &cfg, Maturity::Reproducibility);
        let names: Vec<&str> = missing.iter().map(|(c, _)| c.name()).collect();
        assert_eq!(
            names,
            vec!["instrumented-runs", "instrumented-systems", "replay-verified"]
        );
        assert!(missing[0].1.contains("0/3"), "{:?}", missing[0]);
        assert!(unmet(&ev(5, 3, 1), &cfg, Maturity::Reproducibility).is_empty());
    }

    #[test]
    fn checklist_is_cumulative() {
        assert_eq!(checklist(Maturity::Runnability).len(), 2);
        assert_eq!(checklist(Maturity::Instrumentability).len(), 4);
        assert_eq!(checklist(Maturity::Reproducibility).len(), CRITERIA.len());
        for c in CRITERIA {
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    fn default_config_mirrors_catalog() {
        let cfg = CriteriaConfig::default();
        assert_eq!(cfg.min_runs, 3);
        assert_eq!(cfg.min_instrumented, 3);
        assert_eq!(cfg.min_systems, 1);
        assert!(cfg.is_instrument_metric("tts_file"));
        assert!(cfg.is_instrument_metric("energy_j"));
        assert!(!cfg.is_instrument_metric("runtime"));
        assert_eq!(parse_metric_list(" a, b ,,c "), vec!["a", "b", "c"]);
    }
}
