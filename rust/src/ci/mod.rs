//! CI/CD engine substrate (paper §IV-C, §V-A; DESIGN.md §2 — GitLab
//! replacement).
//!
//! * [`config`] — `.gitlab-ci.yml`-style parsing (includes, components,
//!   inputs, schedules).
//! * [`component`] — the component catalog with typed-input validation.
//! * [`pipeline`] — pipelines, CI jobs, artifacts, triggers.
//! * [`runner`] — the Jacamar-like login-node runner bridging CI jobs to
//!   the batch scheduler.
//!
//! The engine is passive: *executing* a component (interpreting its
//! resolved inputs against the cluster/scheduler/harness) is the
//! orchestrators' job (`coordinator`), keeping front end and back end
//! decoupled exactly as the protocol prescribes.

pub mod component;
pub mod config;
pub mod pipeline;
pub mod runner;

pub use component::{ComponentError, ComponentRegistry, ComponentSpec, InputSpec, InputType};
pub use config::{CiConfig, ComponentInvocation, ConfigError, Schedule};
pub use pipeline::{CiJob, CiJobState, IdAllocator, Pipeline, Trigger};
pub use runner::{Runner, RunnerError};
