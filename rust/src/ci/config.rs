//! `.gitlab-ci.yml`-style pipeline configuration parsing (paper §II-C).
//!
//! Two accepted shapes, both used in the paper:
//!
//! ```yaml
//! include:
//!   - component: example/jube@v3.2
//!     inputs:
//!       prefix: "jedi.strong.tiny"
//! ```
//!
//! and the single-component form:
//!
//! ```yaml
//! component: execution@v3
//! inputs:
//!   prefix: "jureca.single"
//! ```
//!
//! Plus an optional `schedule:` block for recurring pipelines (the daily
//! BabelStream/Graph500 runs behind Figs. 3–4).

use crate::util::json::Json;
use crate::util::yamlite;

#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    Yaml(String),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Yaml(e) => write!(f, "yaml: {e}"),
            ConfigError::Invalid(e) => write!(f, "config: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// One component invocation from the CI file.
#[derive(Debug, Clone, PartialEq)]
pub struct ComponentInvocation {
    pub component: String,
    pub inputs: Json,
}

/// When pipelines run.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    /// Every day at the given hour (0-23).
    Daily { hour: u8 },
    /// Every `n` days at the given hour.
    EveryDays { n: u32, hour: u8 },
}

impl Schedule {
    /// Seconds-of-epoch of the first firing strictly after `after`.
    pub fn next_fire(
        &self,
        after: crate::util::timeutil::SimTime,
    ) -> crate::util::timeutil::SimTime {
        use crate::util::timeutil::{SimTime, SECS_PER_DAY};
        let (period, hour) = match self {
            Schedule::Daily { hour } => (1i64, *hour as i64),
            Schedule::EveryDays { n, hour } => (*n as i64, *hour as i64),
        };
        let mut day = after.0.div_euclid(SECS_PER_DAY);
        loop {
            let candidate = SimTime(day * SECS_PER_DAY + hour * 3600);
            if candidate > after && day % period == 0 {
                return candidate;
            }
            day += 1;
        }
    }
}

/// A parsed CI configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CiConfig {
    pub invocations: Vec<ComponentInvocation>,
    pub schedule: Option<Schedule>,
}

impl CiConfig {
    pub fn parse(text: &str) -> Result<CiConfig, ConfigError> {
        let doc = yamlite::parse(text).map_err(|e| ConfigError::Yaml(e.to_string()))?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<CiConfig, ConfigError> {
        let mut invocations = Vec::new();
        if let Some(includes) = doc.get("include").and_then(Json::as_arr) {
            for (i, inc) in includes.iter().enumerate() {
                invocations.push(parse_invocation(inc, &format!("include[{i}]"))?);
            }
        }
        if doc.get("component").is_some() {
            invocations.push(parse_invocation(doc, "top-level")?);
        }
        // `- component:` directly at top level (a bare list document)
        if let Json::Arr(items) = doc {
            for (i, item) in items.iter().enumerate() {
                if item.get("component").is_some() {
                    invocations.push(parse_invocation(item, &format!("[{i}]"))?);
                }
            }
        }
        if invocations.is_empty() {
            return Err(ConfigError::Invalid(
                "no component invocations found".into(),
            ));
        }
        let schedule = match doc.get("schedule") {
            None => None,
            Some(s) => Some(parse_schedule(s)?),
        };
        Ok(CiConfig {
            invocations,
            schedule,
        })
    }
}

fn parse_invocation(v: &Json, at: &str) -> Result<ComponentInvocation, ConfigError> {
    let component = v
        .str_of("component")
        .ok_or_else(|| ConfigError::Invalid(format!("{at}: missing 'component'")))?
        .to_string();
    let inputs = match v.get("inputs") {
        None => Json::obj(),
        Some(o @ Json::Obj(_)) => o.clone(),
        Some(_) => {
            return Err(ConfigError::Invalid(format!(
                "{at}: 'inputs' must be a mapping"
            )))
        }
    };
    Ok(ComponentInvocation { component, inputs })
}

fn parse_schedule(v: &Json) -> Result<Schedule, ConfigError> {
    let hour = v.u64_of("hour").unwrap_or(3) as u8;
    if hour > 23 {
        return Err(ConfigError::Invalid("schedule hour must be 0-23".into()));
    }
    match v.str_of("every") {
        Some("day") | None => Ok(Schedule::Daily { hour }),
        Some(other) => {
            if let Some(days) = other
                .strip_suffix("days")
                .map(str::trim)
                .and_then(|n| n.parse::<u32>().ok())
            {
                Ok(Schedule::EveryDays { n: days, hour })
            } else {
                Err(ConfigError::Invalid(format!(
                    "unsupported schedule '{other}'"
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timeutil::SimTime;

    #[test]
    fn parses_include_form() {
        let text = r#"
include:
  - component: example/jube@v3.2
    inputs:
      prefix: "jedi.strong.tiny"
      variant: "large-intensity"
      machine: "jedi"
      queue: "all"
      project: "cjsc"
      budget: "zam"
      jube_file: "simple.yaml"
"#;
        let cfg = CiConfig::parse(text).unwrap();
        assert_eq!(cfg.invocations.len(), 1);
        assert_eq!(cfg.invocations[0].component, "example/jube@v3.2");
        assert_eq!(
            cfg.invocations[0].inputs.str_of("budget"),
            Some("zam")
        );
        assert!(cfg.schedule.is_none());
    }

    #[test]
    fn parses_single_component_form() {
        let text = "component: execution@v3\ninputs:\n  prefix: p\n  machine: jedi\n";
        let cfg = CiConfig::parse(text).unwrap();
        assert_eq!(cfg.invocations[0].component, "execution@v3");
    }

    #[test]
    fn parses_schedule() {
        let text = "component: execution@v3\ninputs:\n  prefix: p\nschedule:\n  every: day\n  hour: 4\n";
        let cfg = CiConfig::parse(text).unwrap();
        assert_eq!(cfg.schedule, Some(Schedule::Daily { hour: 4 }));
    }

    #[test]
    fn schedule_next_fire() {
        let s = Schedule::Daily { hour: 3 };
        let t0 = SimTime(0);
        let f1 = s.next_fire(t0);
        assert_eq!(f1.iso8601(), "2026-01-01T03:00:00Z");
        let f2 = s.next_fire(f1);
        assert_eq!(f2.iso8601(), "2026-01-02T03:00:00Z");
        let e = Schedule::EveryDays { n: 7, hour: 0 };
        let f = e.next_fire(SimTime(1));
        assert_eq!(f.date_string(), "2026-01-08");
    }

    #[test]
    fn multiple_includes() {
        let text = r#"
include:
  - component: execution@v3
    inputs:
      prefix: a
  - component: time-series@v3
    inputs:
      prefix: b
"#;
        let cfg = CiConfig::parse(text).unwrap();
        assert_eq!(cfg.invocations.len(), 2);
    }

    #[test]
    fn empty_config_rejected() {
        assert!(CiConfig::parse("stages: [build]\n").is_err());
    }

    #[test]
    fn bad_schedule_rejected() {
        let text = "component: c\nschedule:\n  every: fortnight\n";
        assert!(CiConfig::parse(text).is_err());
        let text = "component: c\nschedule:\n  hour: 99\n";
        assert!(CiConfig::parse(text).is_err());
    }
}
