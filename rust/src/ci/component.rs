//! CI/CD component registry with typed inputs (paper §V-A).
//!
//! exaCB ships "a growing catalog of CI/CD components" invoked from
//! pipeline configs as `component: execution@v3` plus an `inputs:` map.
//! Each component declares its input schema; invocation resolves
//! defaults and rejects unknown/missing inputs — the "strong coupling"
//! half of the design (§III quadrant 2).

use crate::util::json::Json;

/// Canonical `regression-check@v1` policy defaults — the single source
/// for the catalog schema below and for
/// `tracking::GatePolicy::from_inputs` (direct, non-schema callers), so
/// the two resolution paths can never drift apart. The scenario in
/// `workloads::regression` pins the same values into its generated CI
/// config (it cannot import upward from the simulation layer).
pub mod regression_check_defaults {
    pub const METRIC: &str = "runtime";
    pub const THRESHOLD_PCT: u64 = 5;
    pub const CONFIDENCE_PCT: u64 = 95;
    /// 4, not 3: a candidate of 3+ degrees of freedom keeps the variance
    /// estimate out of the chi-square tail, so a truly stable series
    /// decides at the adaptive minimum without refinement rounds
    /// (verified over 300 seeded 30-day campaigns).
    pub const MIN_REPETITIONS: u64 = 4;
    pub const MAX_EXTRA_REPETITIONS: u64 = 6;
    pub const BASELINE_WINDOW: u64 = 10;
    pub const MIN_BASELINE: u64 = 4;
}

/// Canonical `energy-sweep@v1` policy defaults — the single source for
/// the catalog schema below and for
/// `energy::study::SweepPolicy::from_inputs` (direct, non-schema
/// callers), so the two resolution paths can never drift apart.
pub mod energy_sweep_defaults {
    /// Grid size of the default sweep over the machine's settable
    /// frequency range (the paper's Fig. 9 studies sample 8 clocks).
    pub const POINTS: u64 = 8;
    /// The metric the study optimises (recorded in the sidecar).
    pub const METRIC: &str = "energy_j";
    /// Interleave every frequency point on the shared batch timeline
    /// (discrete-event dispatch); `false` = legacy sequential path.
    pub const CONCURRENT: bool = true;
}

/// Canonical `maturity-check@v1` policy defaults — the single source for
/// the catalog schema below and for
/// `maturity::GatePolicy::from_inputs` / `maturity::CriteriaConfig`
/// (direct, non-schema callers), so the resolution paths can never
/// drift apart. The scenario in `workloads::onboarding` pins the same
/// values into its generated CI config (it cannot import upward from
/// the simulation layer).
pub mod maturity_check_defaults {
    /// Empty target = assess mode: re-level the repository, never block.
    pub const TARGET: &str = "";
    /// Distinct successful reports required for runnability — and the
    /// evidence floor below which the gate refuses to (de)grade at all
    /// (young repositories keep their declared level).
    pub const MIN_RUNS: u64 = 3;
    /// Distinct instrumented successful reports for instrumentability.
    pub const MIN_INSTRUMENTED: u64 = 3;
    /// Distinct systems carrying instrumented evidence.
    pub const MIN_SYSTEMS: u64 = 1;
    /// Evidence recency window in days; 0 = whole recorded history.
    pub const WINDOW_DAYS: u64 = 0;
    /// Comma-separated metric names that count as instrumentation
    /// (beyond the Table-I baseline): analysis extractions and the
    /// jpwr energy metrics.
    pub const INSTRUMENT_METRICS: &str =
        "tts_file,kernel_time,app_time,energy_j,node_energy_j,avg_power_w,edp";
}

#[derive(Debug, Clone, PartialEq)]
pub enum ComponentError {
    Unknown(String),
    MissingInput { component: String, input: String },
    UnknownInput { component: String, input: String },
    BadType {
        component: String,
        input: String,
        expected: String,
    },
}

impl std::fmt::Display for ComponentError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ComponentError::Unknown(c) => write!(f, "unknown component '{c}'"),
            ComponentError::MissingInput { component, input } => {
                write!(f, "component '{component}': missing required input '{input}'")
            }
            ComponentError::UnknownInput { component, input } => {
                write!(f, "component '{component}': unknown input '{input}'")
            }
            ComponentError::BadType {
                component,
                input,
                expected,
            } => write!(f, "component '{component}': input '{input}' must be {expected}"),
        }
    }
}

impl std::error::Error for ComponentError {}

/// Expected JSON shape of one input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputType {
    Str,
    Int,
    Bool,
    List,
}

impl InputType {
    fn matches(&self, v: &Json) -> bool {
        match self {
            InputType::Str => v.as_str().is_some(),
            InputType::Int => v.as_u64().is_some(),
            // CI configs often quote booleans: accept "true"/"false" too.
            InputType::Bool => {
                v.as_bool().is_some() || matches!(v.as_str(), Some("true" | "false"))
            }
            InputType::List => v.as_arr().is_some(),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            InputType::Str => "a string",
            InputType::Int => "an integer",
            InputType::Bool => "a boolean",
            InputType::List => "a list",
        }
    }
}

/// Declared input of a component.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: &'static str,
    pub ty: InputType,
    pub required: bool,
    pub default: Option<Json>,
}

impl InputSpec {
    fn req(name: &'static str, ty: InputType) -> InputSpec {
        InputSpec {
            name,
            ty,
            required: true,
            default: None,
        }
    }

    fn opt(name: &'static str, ty: InputType, default: Json) -> InputSpec {
        InputSpec {
            name,
            ty,
            required: false,
            default: Some(default),
        }
    }
}

/// A registered component (name@version + input schema).
#[derive(Debug, Clone)]
pub struct ComponentSpec {
    /// Full reference, e.g. `execution@v3`.
    pub reference: String,
    pub inputs: Vec<InputSpec>,
}

impl ComponentSpec {
    /// Validate raw invocation inputs against the schema, filling
    /// defaults. Returns the resolved input object.
    pub fn resolve(&self, raw: &Json) -> Result<Json, ComponentError> {
        let mut resolved = Json::obj();
        let raw_pairs = raw.as_obj().unwrap_or(&[]);
        for (k, _) in raw_pairs {
            if !self.inputs.iter().any(|i| i.name == k) {
                return Err(ComponentError::UnknownInput {
                    component: self.reference.clone(),
                    input: k.clone(),
                });
            }
        }
        for input in &self.inputs {
            match raw.get(input.name) {
                Some(v) => {
                    if !input.ty.matches(v) {
                        return Err(ComponentError::BadType {
                            component: self.reference.clone(),
                            input: input.name.to_string(),
                            expected: input.ty.name().to_string(),
                        });
                    }
                    resolved.insert(input.name, v.clone());
                }
                None if input.required => {
                    return Err(ComponentError::MissingInput {
                        component: self.reference.clone(),
                        input: input.name.to_string(),
                    });
                }
                None => {
                    if let Some(d) = &input.default {
                        resolved.insert(input.name, d.clone());
                    }
                }
            }
        }
        Ok(resolved)
    }
}

/// The built-in component catalog (paper §V-A).
#[derive(Debug, Clone)]
pub struct ComponentRegistry {
    components: Vec<ComponentSpec>,
}

impl Default for ComponentRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

impl ComponentRegistry {
    pub fn builtin() -> ComponentRegistry {
        use InputType::*;
        let execution_inputs = vec![
            InputSpec::req("prefix", Str),
            InputSpec::req("machine", Str),
            InputSpec::req("jube_file", Str),
            InputSpec::opt("usecase", Str, Json::Str(String::new())),
            InputSpec::opt("variant", Str, Json::Str(String::new())),
            InputSpec::opt("queue", Str, Json::Str("all".into())),
            InputSpec::opt("project", Str, Json::Str("default".into())),
            InputSpec::opt("budget", Str, Json::Str("default".into())),
            InputSpec::opt("fixture", Str, Json::Str(String::new())),
            InputSpec::opt("record", Bool, Json::Bool(true)),
            InputSpec::opt("tags", List, Json::arr()),
            InputSpec::opt("stage", Str, Json::Str("2026".into())),
            InputSpec::opt("launcher", Str, Json::Str("srun".into())),
            InputSpec::opt("freq_mhz", Int, Json::Num(0.0)),
            InputSpec::opt("nodes", Int, Json::Num(0.0)),
        ];
        let mut feature_injection_inputs = execution_inputs.clone();
        feature_injection_inputs.push(InputSpec::req("in_command", Str));
        let execution_inputs2 = execution_inputs.clone();
        // regression gate: execution-like (it schedules extra repetition
        // runs) plus the detection policy (DESIGN.md §9)
        use regression_check_defaults as gate;
        let mut regression_check_inputs = execution_inputs.clone();
        regression_check_inputs.extend([
            InputSpec::opt("metric", Str, Json::Str(gate::METRIC.into())),
            InputSpec::opt("threshold_pct", Int, Json::Num(gate::THRESHOLD_PCT as f64)),
            InputSpec::opt("confidence_pct", Int, Json::Num(gate::CONFIDENCE_PCT as f64)),
            InputSpec::opt("min_repetitions", Int, Json::Num(gate::MIN_REPETITIONS as f64)),
            InputSpec::opt(
                "max_extra_repetitions",
                Int,
                Json::Num(gate::MAX_EXTRA_REPETITIONS as f64),
            ),
            InputSpec::opt("baseline_window", Int, Json::Num(gate::BASELINE_WINDOW as f64)),
            InputSpec::opt("min_baseline", Int, Json::Num(gate::MIN_BASELINE as f64)),
        ]);

        ComponentRegistry {
            components: vec![
                ComponentSpec {
                    reference: "execution@v3".into(),
                    inputs: execution_inputs.clone(),
                },
                // the paper's §II-C example uses a namespaced alias
                ComponentSpec {
                    reference: "example/jube@v3.2".into(),
                    inputs: execution_inputs,
                },
                ComponentSpec {
                    reference: "feature-injection@v3".into(),
                    inputs: feature_injection_inputs,
                },
                ComponentSpec {
                    reference: "machine-comparison@v3".into(),
                    inputs: vec![
                        InputSpec::req("prefix", Str),
                        InputSpec::req("selector", List),
                        InputSpec::opt("pipeline", List, Json::arr()),
                        InputSpec::opt("metric", Str, Json::Str("runtime".into())),
                        InputSpec::opt("xaxis", Str, Json::Str("nodes".into())),
                        InputSpec::opt("scaling_band", Int, Json::Num(80.0)),
                    ],
                },
                ComponentSpec {
                    reference: "scalability@v3".into(),
                    inputs: vec![
                        InputSpec::req("prefix", Str),
                        InputSpec::req("selector", Str),
                        InputSpec::opt("metric", Str, Json::Str("runtime".into())),
                        InputSpec::opt("mode", Str, Json::Str("strong".into())),
                    ],
                },
                ComponentSpec {
                    reference: "time-series@v3".into(),
                    inputs: vec![
                        InputSpec::req("prefix", Str),
                        InputSpec::opt("pipeline", List, Json::arr()),
                        InputSpec::req("data_labels", List),
                        InputSpec::opt("ylabel", List, Json::arr()),
                        InputSpec::opt("plot_labels", List, Json::arr()),
                        InputSpec::opt("time_span", List, Json::arr()),
                    ],
                },
                ComponentSpec {
                    reference: "regression-check@v1".into(),
                    inputs: regression_check_inputs,
                },
                // the maturity gate (DESIGN.md §10): reads recorded
                // evidence only, so unlike regression-check it needs no
                // execution inputs — just the store prefix + policy
                ComponentSpec {
                    reference: "maturity-check@v1".into(),
                    inputs: {
                        use maturity_check_defaults as m;
                        vec![
                            InputSpec::req("prefix", Str),
                            InputSpec::opt("target", Str, Json::Str(m::TARGET.into())),
                            InputSpec::opt("min_runs", Int, Json::Num(m::MIN_RUNS as f64)),
                            InputSpec::opt(
                                "min_instrumented",
                                Int,
                                Json::Num(m::MIN_INSTRUMENTED as f64),
                            ),
                            InputSpec::opt(
                                "min_systems",
                                Int,
                                Json::Num(m::MIN_SYSTEMS as f64),
                            ),
                            InputSpec::opt(
                                "window_days",
                                Int,
                                Json::Num(m::WINDOW_DAYS as f64),
                            ),
                            InputSpec::opt(
                                "instrument_metrics",
                                Str,
                                Json::Str(m::INSTRUMENT_METRICS.into()),
                            ),
                            InputSpec::opt("update", Bool, Json::Bool(true)),
                        ]
                    },
                },
                ComponentSpec {
                    reference: "jureap/energy@v3".into(),
                    inputs: {
                        // execution-like: energy studies *run* the benchmark
                        // per frequency through the jpwr launcher (§VI-B)
                        let mut v = execution_inputs2.clone();
                        v.push(InputSpec::opt("frequencies", List, Json::arr()));
                        v.push(InputSpec::opt(
                            "metric",
                            Str,
                            Json::Str("energy_j".into()),
                        ));
                        v
                    },
                },
                // the concurrent energy sweep (DESIGN.md §11): like
                // jureap/energy@v3 but every frequency point is a fresh
                // task on the shared batch timeline, dispatched from the
                // coordinator event loop like regression-check@v1
                ComponentSpec {
                    reference: "energy-sweep@v1".into(),
                    inputs: {
                        use energy_sweep_defaults as e;
                        let mut v = execution_inputs2.clone();
                        v.push(InputSpec::opt("frequencies", List, Json::arr()));
                        v.push(InputSpec::opt("points", Int, Json::Num(e::POINTS as f64)));
                        v.push(InputSpec::opt("metric", Str, Json::Str(e::METRIC.into())));
                        v.push(InputSpec::opt(
                            "concurrent",
                            Bool,
                            Json::Bool(e::CONCURRENT),
                        ));
                        v
                    },
                },
            ],
        }
    }

    pub fn get(&self, reference: &str) -> Result<&ComponentSpec, ComponentError> {
        self.components
            .iter()
            .find(|c| c.reference == reference)
            .ok_or_else(|| ComponentError::Unknown(reference.to_string()))
    }

    pub fn references(&self) -> Vec<&str> {
        self.components
            .iter()
            .map(|c| c.reference.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_execution_invocation_resolves() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("execution@v3").unwrap();
        let raw = Json::obj()
            .set("prefix", "jureca.single")
            .set("usecase", "bigproblem")
            .set("variant", "single")
            .set("jube_file", "benchmark/jube/shell.yml")
            .set("machine", "jureca")
            .set("queue", "dc-gpu")
            .set("project", "cexalab")
            .set("budget", "exalab")
            .set("record", "true");
        let resolved = spec.resolve(&raw).unwrap();
        assert_eq!(resolved.str_of("machine"), Some("jureca"));
        // defaults filled
        assert_eq!(resolved.str_of("stage"), Some("2026"));
        assert_eq!(resolved.str_of("launcher"), Some("srun"));
    }

    #[test]
    fn missing_required_input_fails() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("execution@v3").unwrap();
        let raw = Json::obj().set("prefix", "x");
        let err = spec.resolve(&raw).unwrap_err();
        assert!(matches!(err, ComponentError::MissingInput { .. }));
    }

    #[test]
    fn unknown_input_fails() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("time-series@v3").unwrap();
        let raw = Json::obj()
            .set("prefix", "p")
            .set("data_labels", Json::arr())
            .set("typo_input", 1u64);
        assert!(matches!(
            spec.resolve(&raw).unwrap_err(),
            ComponentError::UnknownInput { .. }
        ));
    }

    #[test]
    fn type_mismatch_fails() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("machine-comparison@v3").unwrap();
        let raw = Json::obj()
            .set("prefix", "p")
            .set("selector", "not-a-list");
        assert!(matches!(
            spec.resolve(&raw).unwrap_err(),
            ComponentError::BadType { .. }
        ));
    }

    #[test]
    fn unknown_component() {
        let reg = ComponentRegistry::builtin();
        assert!(matches!(
            reg.get("nope@v1").unwrap_err(),
            ComponentError::Unknown(_)
        ));
    }

    #[test]
    fn catalog_contains_paper_components() {
        let reg = ComponentRegistry::builtin();
        for c in [
            "execution@v3",
            "feature-injection@v3",
            "machine-comparison@v3",
            "scalability@v3",
            "time-series@v3",
            "jureap/energy@v3",
            "example/jube@v3.2",
            "regression-check@v1",
            "maturity-check@v1",
            "energy-sweep@v1",
        ] {
            assert!(reg.get(c).is_ok(), "{c}");
        }
    }

    #[test]
    fn energy_sweep_resolves_defaults() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("energy-sweep@v1").unwrap();
        // execution-like: the sweep runs the benchmark per frequency
        let err = spec
            .resolve(&Json::obj().set("prefix", "jedi.app"))
            .unwrap_err();
        assert!(
            matches!(err, ComponentError::MissingInput { ref input, .. } if input == "machine")
        );
        let resolved = spec
            .resolve(
                &Json::obj()
                    .set("prefix", "jedi.app")
                    .set("machine", "jedi")
                    .set("jube_file", "b.yml"),
            )
            .unwrap();
        assert_eq!(resolved.u64_of("points"), Some(8));
        assert_eq!(resolved.str_of("metric"), Some("energy_j"));
        assert_eq!(resolved.bool_of("concurrent"), Some(true));
        assert!(resolved.get("frequencies").and_then(Json::as_arr).unwrap().is_empty());
        // unknown inputs stay loud
        let err = spec
            .resolve(
                &Json::obj()
                    .set("prefix", "p")
                    .set("machine", "jedi")
                    .set("jube_file", "b.yml")
                    .set("frequencys", Json::arr()),
            )
            .unwrap_err();
        assert!(matches!(err, ComponentError::UnknownInput { .. }));
    }

    #[test]
    fn maturity_check_resolves_defaults() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("maturity-check@v1").unwrap();
        // prefix is the only required input: the gate reads evidence,
        // it never executes
        let err = spec.resolve(&Json::obj()).unwrap_err();
        assert!(
            matches!(err, ComponentError::MissingInput { ref input, .. } if input == "prefix")
        );
        let resolved = spec
            .resolve(&Json::obj().set("prefix", "jupiter.app"))
            .unwrap();
        assert_eq!(resolved.str_of("target"), Some(""));
        assert_eq!(resolved.u64_of("min_runs"), Some(3));
        assert_eq!(resolved.u64_of("window_days"), Some(0));
        assert!(resolved
            .str_of("instrument_metrics")
            .unwrap()
            .contains("energy_j"));
    }

    #[test]
    fn feature_injection_requires_in_command() {
        let reg = ComponentRegistry::builtin();
        let spec = reg.get("feature-injection@v3").unwrap();
        let raw = Json::obj()
            .set("prefix", "jupiter.single")
            .set("machine", "jupiter")
            .set("jube_file", "f.yml");
        let err = spec.resolve(&raw).unwrap_err();
        assert!(
            matches!(err, ComponentError::MissingInput { ref input, .. } if input == "in_command")
        );
    }
}
