//! The Jacamar-like CI runner (paper §II-C, §IV-A).
//!
//! "The component uses the Jacamar runner to start a CI/CD job on the
//! login node of the target HPC system and sets up the directories and
//! environment to execute the benchmark. During the setup of the
//! environment, the component also ensures that the compute account ...
//! is enabled."
//!
//! The runner is the bridge between a CI job and the target machine's
//! batch system: it validates account/budget/queue up front (failing the
//! CI job *before* burning scheduler time) and forwards batch
//! submissions.

use crate::scheduler::{BatchSystem, JobPayload, JobSpec, SubmitError};

#[derive(Debug, Clone, PartialEq)]
pub enum RunnerError {
    NoRunner(String),
    Setup { machine: String, reason: String },
    Submit(SubmitError),
}

impl std::fmt::Display for RunnerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunnerError::NoRunner(m) => write!(f, "no runner registered for machine '{m}'"),
            RunnerError::Setup { machine, reason } => {
                write!(f, "environment setup failed on '{machine}': {reason}")
            }
            RunnerError::Submit(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RunnerError {}

impl From<SubmitError> for RunnerError {
    fn from(e: SubmitError) -> RunnerError {
        RunnerError::Submit(e)
    }
}

/// A runner bound to one machine's login node.
#[derive(Debug, Clone)]
pub struct Runner {
    pub machine: String,
    /// Login-node environment is healthy (simulated failure injection).
    pub healthy: bool,
}

impl Runner {
    pub fn new(machine: &str) -> Runner {
        Runner {
            machine: machine.to_string(),
            healthy: true,
        }
    }

    /// Environment + account preflight (the §II-C setup step).
    pub fn setup(
        &self,
        bs: &BatchSystem,
        account: &str,
        budget: &str,
        queue: &str,
    ) -> Result<(), RunnerError> {
        if !self.healthy {
            return Err(RunnerError::Setup {
                machine: self.machine.clone(),
                reason: "login node unavailable".into(),
            });
        }
        if bs.total_nodes(queue).is_none() {
            return Err(RunnerError::Setup {
                machine: self.machine.clone(),
                reason: format!("queue '{queue}' does not exist"),
            });
        }
        bs.accounts
            .authorize(account, budget, queue)
            .map_err(|e| RunnerError::Setup {
                machine: self.machine.clone(),
                reason: e.to_string(),
            })
    }

    /// Digest of the runner-visible execution environment identity:
    /// machine + account + budget + queue. Part of the execution-cache
    /// key, so a cached result never replays across account or queue
    /// contexts (different partitions run different hardware). Reuses
    /// [`CacheKeyBuilder`]'s canonical encoding so the no-aliasing rule
    /// lives in one tested place.
    pub fn environment_fingerprint(
        &self,
        account: &str,
        budget: &str,
        queue: &str,
    ) -> String {
        crate::store::CacheKeyBuilder::new("runner-env", &self.machine)
            .field("account", account)
            .field("budget", budget)
            .field("queue", queue)
            .build()
            .digest
    }

    /// Submit a batch job through this runner.
    pub fn submit(
        &self,
        bs: &mut BatchSystem,
        spec: JobSpec,
        payload: JobPayload,
    ) -> Result<u64, RunnerError> {
        if !self.healthy {
            return Err(RunnerError::Setup {
                machine: self.machine.clone(),
                reason: "login node unavailable".into(),
            });
        }
        Ok(bs.submit(spec, payload)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{AccountManager, JobResult};
    use crate::util::json::Json;

    fn bs() -> BatchSystem {
        let mut bs = BatchSystem::new("jedi", 288, AccountManager::open("cjsc", "zam", 1e9));
        bs.add_partition("all", 48);
        bs
    }

    #[test]
    fn setup_validates_queue_and_account() {
        let bs = bs();
        let r = Runner::new("jedi");
        assert!(r.setup(&bs, "cjsc", "zam", "all").is_ok());
        assert!(matches!(
            r.setup(&bs, "cjsc", "zam", "ghost-queue"),
            Err(RunnerError::Setup { .. })
        ));
        assert!(matches!(
            r.setup(&bs, "intruder", "zam", "all"),
            Err(RunnerError::Setup { .. })
        ));
    }

    #[test]
    fn unhealthy_runner_fails_fast() {
        let mut bs = bs();
        let mut r = Runner::new("jedi");
        r.healthy = false;
        assert!(r.setup(&bs, "cjsc", "zam", "all").is_err());
        let err = r
            .submit(
                &mut bs,
                JobSpec::default(),
                Box::new(|_| JobResult {
                    duration_s: 1.0,
                    success: true,
                    metrics: Json::obj(),
                    files: vec![],
                }),
            )
            .unwrap_err();
        assert!(matches!(err, RunnerError::Setup { .. }));
    }

    #[test]
    fn environment_fingerprint_distinguishes_contexts() {
        let r = Runner::new("jedi");
        let a = r.environment_fingerprint("cjsc", "zam", "all");
        let b = r.environment_fingerprint("cjsc", "zam", "all");
        assert_eq!(a, b);
        assert_ne!(a, r.environment_fingerprint("cjsc", "zam", "develop"));
        assert_ne!(a, r.environment_fingerprint("cexalab", "exalab", "all"));
        assert_ne!(a, Runner::new("jureca").environment_fingerprint("cjsc", "zam", "all"));
    }

    #[test]
    fn submit_forwards_to_batch_system() {
        let mut bs = bs();
        let r = Runner::new("jedi");
        let id = r
            .submit(
                &mut bs,
                JobSpec {
                    account: "cjsc".into(),
                    budget: "zam".into(),
                    partition: "all".into(),
                    ..Default::default()
                },
                Box::new(|_| JobResult {
                    duration_s: 5.0,
                    success: true,
                    metrics: Json::obj(),
                    files: vec![],
                }),
            )
            .unwrap();
        bs.run_until_idle();
        assert!(bs.record(id).unwrap().state == crate::scheduler::JobState::Completed);
    }
}
