//! Pipelines, CI jobs, and artifacts (the GitLab-shaped execution model).
//!
//! Each orchestrator stage "is realized as an individual CI job. The jobs
//! communicate between themselves primarily through the CI/CD's native
//! artifact management capabilities" (paper §IV-C). A pipeline is a run
//! of a repository's CI config; its jobs carry artifacts (named text
//! files) and a log.

use crate::protocol::StepProvenance;
use crate::util::json::Json;
use crate::util::timeutil::SimTime;

/// Why a pipeline ran.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    Manual,
    Scheduled,
    Push,
    /// Cross-triggered by another repository's pipeline (§IV-C).
    Cross { from_pipeline: u64 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CiJobState {
    Created,
    Running,
    Success,
    Failed,
}

/// One CI job (one orchestrator stage execution).
#[derive(Debug, Clone)]
pub struct CiJob {
    pub id: u64,
    /// `<prefix>.<stage>` naming, e.g. `jureca.single.execute`.
    pub name: String,
    pub state: CiJobState,
    pub artifacts: Vec<(String, String)>,
    pub log: Vec<String>,
    /// Structured outcome for downstream jobs (beyond raw artifacts).
    pub output: Json,
    /// Per-step execution-cache provenance (empty when caching is off or
    /// the job is not an execute stage). Also mirrored in the
    /// `cache.json` artifact for external consumers.
    pub provenance: Vec<StepProvenance>,
}

impl CiJob {
    pub fn new(id: u64, name: &str) -> CiJob {
        CiJob {
            id,
            name: name.to_string(),
            state: CiJobState::Created,
            artifacts: Vec::new(),
            log: Vec::new(),
            output: Json::obj(),
            provenance: Vec::new(),
        }
    }

    pub fn artifact(&self, name: &str) -> Option<&str> {
        self.artifacts
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c.as_str())
    }

    pub fn add_artifact(&mut self, name: &str, content: &str) {
        self.artifacts.push((name.to_string(), content.to_string()));
    }

    pub fn log_line(&mut self, line: impl Into<String>) {
        self.log.push(line.into());
    }
}

/// A pipeline: one run of a repository's CI configuration.
#[derive(Debug, Clone)]
pub struct Pipeline {
    pub id: u64,
    pub repo: String,
    pub trigger: Trigger,
    pub created: SimTime,
    pub jobs: Vec<CiJob>,
}

impl Pipeline {
    pub fn succeeded(&self) -> bool {
        !self.jobs.is_empty() && self.jobs.iter().all(|j| j.state == CiJobState::Success)
    }

    pub fn job(&self, name: &str) -> Option<&CiJob> {
        self.jobs.iter().find(|j| j.name == name)
    }

    /// Artifacts from all jobs, job-name-qualified.
    pub fn all_artifacts(&self) -> Vec<(String, &str)> {
        self.jobs
            .iter()
            .flat_map(|j| {
                j.artifacts
                    .iter()
                    .map(move |(n, c)| (format!("{}/{}", j.name, n), c.as_str()))
            })
            .collect()
    }

    /// Cache provenance tallied over all jobs: (hits, misses,
    /// invalidated). A warm pipeline reads `(n, 0, 0)`.
    pub fn cache_summary(&self) -> (usize, usize, usize) {
        let mut t = (0, 0, 0);
        for j in &self.jobs {
            let (h, m, i) = crate::protocol::provenance::tally(&j.provenance);
            t.0 += h;
            t.1 += m;
            t.2 += i;
        }
        t
    }
}

/// Monotonic id allocation for pipelines and CI jobs.
#[derive(Debug, Clone)]
pub struct IdAllocator {
    next_pipeline: u64,
    next_job: u64,
}

impl Default for IdAllocator {
    fn default() -> Self {
        // GitLab-flavoured id ranges (the paper shows pipeline 221622).
        IdAllocator {
            next_pipeline: 221_600,
            next_job: 900_000,
        }
    }
}

impl IdAllocator {
    pub fn new() -> IdAllocator {
        IdAllocator::default()
    }

    pub fn pipeline_id(&mut self) -> u64 {
        let id = self.next_pipeline;
        self.next_pipeline += 1;
        id
    }

    pub fn job_id(&mut self) -> u64 {
        let id = self.next_job;
        self.next_job += 1;
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_success_requires_all_jobs() {
        let mut p = Pipeline {
            id: 1,
            repo: "logmap".into(),
            trigger: Trigger::Manual,
            created: SimTime(0),
            jobs: vec![CiJob::new(1, "a"), CiJob::new(2, "b")],
        };
        assert!(!p.succeeded());
        p.jobs[0].state = CiJobState::Success;
        p.jobs[1].state = CiJobState::Success;
        assert!(p.succeeded());
        p.jobs[1].state = CiJobState::Failed;
        assert!(!p.succeeded());
    }

    #[test]
    fn artifacts_are_job_scoped() {
        let mut p = Pipeline {
            id: 1,
            repo: "r".into(),
            trigger: Trigger::Scheduled,
            created: SimTime(0),
            jobs: vec![CiJob::new(1, "execute")],
        };
        p.jobs[0].add_artifact("results.csv", "a,b\n1,2\n");
        assert_eq!(p.job("execute").unwrap().artifact("results.csv").unwrap(), "a,b\n1,2\n");
        assert!(p.job("execute").unwrap().artifact("nope").is_none());
        let all = p.all_artifacts();
        assert_eq!(all[0].0, "execute/results.csv");
    }

    #[test]
    fn id_allocation_is_monotonic() {
        let mut ids = IdAllocator::new();
        let a = ids.pipeline_id();
        let b = ids.pipeline_id();
        assert_eq!(b, a + 1);
        assert_ne!(ids.job_id(), ids.job_id());
    }

    #[test]
    fn cache_summary_tallies_across_jobs() {
        use crate::protocol::{CacheOutcome, StepProvenance};
        let mut p = Pipeline {
            id: 1,
            repo: "r".into(),
            trigger: Trigger::Scheduled,
            created: SimTime(0),
            jobs: vec![CiJob::new(1, "a.execute"), CiJob::new(2, "b.execute")],
        };
        p.jobs[0].provenance = vec![
            StepProvenance::new("compile", "k1", CacheOutcome::Hit),
            StepProvenance::new("execute", "k2", CacheOutcome::Miss),
        ];
        p.jobs[1].provenance = vec![StepProvenance::new(
            "execute",
            "k3",
            CacheOutcome::Invalidated,
        )];
        assert_eq!(p.cache_summary(), (1, 1, 1));
    }

    #[test]
    fn empty_pipeline_not_successful() {
        let p = Pipeline {
            id: 1,
            repo: "r".into(),
            trigger: Trigger::Push,
            created: SimTime(0),
            jobs: vec![],
        };
        assert!(!p.succeeded());
    }
}
