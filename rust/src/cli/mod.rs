//! The `exacb` command-line interface.
//!
//! ```text
//! exacb quickstart  [--machine jedi] [--queue all]
//! exacb pipeline    --repo <name> [--machine jedi]   (built-in demo repos)
//! exacb collection  [--apps 72] [--days 14] [--machine jupiter]
//! exacb figures     [--days 90] [--out out/] [--only fig3]
//! exacb ablation    [--benchmarks 70]
//! exacb components
//! exacb validate    <report.json>...
//! exacb artifacts
//! ```

pub mod args;

pub use args::{Args, ArgsError};

use crate::ci::Trigger;
use crate::coordinator::{collection, BenchmarkRepo, World};
use crate::workloads::portfolio;

pub const USAGE: &str = "\
exacb — reproducible continuous benchmark collections at scale

USAGE: exacb <command> [flags]

COMMANDS:
  quickstart    run the paper's §II logmap example end to end
  collection    run a JUREAP-scale campaign (--apps N --days D --machine M
                --machines M1,M2 --cache --sweeps K for incremental re-runs;
                --concurrent interleaves all pipelines on the shared
                timeline via the discrete-event loop)
  figures       regenerate every paper table/figure (--days D --out DIR --only ID)
  ablation      run the §III integration-mode ablation (--benchmarks N)
  components    list the CI/CD component catalog
  validate      validate protocol documents (files as arguments)
  artifacts     show the AOT artifact manifest + PJRT smoke test
";

/// Run the CLI; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return 2;
        }
    };
    match args.subcommand.as_deref() {
        Some("quickstart") => cmd_quickstart(&args),
        Some("collection") => cmd_collection(&args),
        Some("figures") => cmd_figures(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("components") => cmd_components(),
        Some("validate") => cmd_validate(&args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            println!("{USAGE}");
            0
        }
    }
}

fn cmd_quickstart(args: &Args) -> i32 {
    let machine = args.str("machine", "jedi");
    let queue = args.str("queue", "all");
    let mut world = World::new(args.u64("seed", 42));
    let attached = world.try_attach_engine();
    println!(
        "PJRT engine: {}",
        if attached {
            "attached (real kernel execution)"
        } else {
            "not available (run `make artifacts`); using analytic models"
        }
    );
    world.add_repo(BenchmarkRepo::logmap_example(&machine, &queue));
    let pid = match world.run_pipeline("logmap", Trigger::Manual) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("pipeline failed: {e}");
            return 1;
        }
    };
    let p = world.pipeline(pid).unwrap();
    println!("pipeline {pid} on {machine}: succeeded={}", p.succeeded());
    for job in &p.jobs {
        println!("  job {} [{}]", job.name, match job.state {
            crate::ci::CiJobState::Success => "success",
            crate::ci::CiJobState::Failed => "FAILED",
            _ => "?",
        });
        for l in &job.log {
            println!("    {l}");
        }
    }
    if let Some(csv) = p
        .job(&format!("{machine}.logmap.execute"))
        .and_then(|j| j.artifact("results.csv"))
    {
        println!("\nresults.csv (Table I):");
        if let Some(t) = crate::util::table::Table::from_csv(csv) {
            print!("{}", t.render());
        }
    }
    if p.succeeded() {
        0
    } else {
        1
    }
}

fn cmd_collection(args: &Args) -> i32 {
    let n = args.u64("apps", 72) as usize;
    let days = args.i64("days", 14);
    let machine = args.str("machine", "jupiter");
    let machines_arg = args.str("machines", "");
    let queue = args.str("queue", "all");
    let seed = args.u64("seed", 20260101);
    let sweeps = args.u64("sweeps", 1).max(1);
    let cache = args.str("cache", "false") == "true";
    let concurrent = args.str("concurrent", "false") == "true";
    let mut world = World::new(seed);
    if cache || sweeps > 1 {
        world.enable_cache();
    }
    world.try_attach_engine();
    let apps = portfolio::generate(n, seed);
    let machine_list: Vec<String> = if machines_arg.trim().is_empty() {
        vec![machine]
    } else {
        machines_arg
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    };
    if machine_list.is_empty() {
        eprintln!("error: --machines needs at least one machine name (e.g. jupiter,jedi)");
        return 2;
    }
    let machine_refs: Vec<&str> = machine_list.iter().map(String::as_str).collect();
    collection::onboard_multi(&mut world, &apps, &machine_refs, &queue);
    println!(
        "onboarded {n} applications on {}; running {days} simulated day(s) x {sweeps} sweep(s){}…",
        machine_list.join(","),
        if concurrent { " [concurrent]" } else { "" }
    );
    let mut summary = None;
    for s in 0..sweeps {
        let t = std::time::Instant::now();
        let sum = if concurrent {
            collection::run_campaign_concurrent(&mut world, &apps, &machine_refs, days)
        } else {
            collection::run_campaign_queued(&mut world, &apps, &machine_refs, days)
        };
        println!(
            "sweep {}: {:.1} ms wall, {} cumulative cache hits",
            s + 1,
            t.elapsed().as_secs_f64() * 1e3,
            sum.cache.hits
        );
        summary = Some(sum);
    }
    let summary = summary.expect("sweeps >= 1");
    println!(
        "\npipelines: {}/{} succeeded; {} protocol reports recorded; {:.0} core-hours",
        summary.pipelines_succeeded,
        summary.pipelines_run,
        summary.reports_recorded,
        summary.core_hours
    );
    print!("{}", summary.table().render());
    println!("\nqueue-wait statistics (per machine):");
    print!(
        "{}",
        crate::coordinator::postproc::queue_stats(&world).render()
    );
    println!("{}", summary.to_json().pretty());
    0
}

fn cmd_figures(args: &Args) -> i32 {
    let days = args.i64("days", 90);
    let seed = args.u64("seed", 2026);
    let out = args.str("out", "out");
    let only = args.flags.get("only").cloned();
    let results = crate::experiments::run_all(days, seed);
    let dir = std::path::Path::new(&out);
    let mut failures = 0;
    for r in results {
        if let Some(only) = &only {
            if !r.id.to_lowercase().replace(' ', "") .contains(&only.to_lowercase()) {
                continue;
            }
        }
        r.print();
        if let Err(e) = r.save(dir) {
            eprintln!("save failed: {e}");
            failures += 1;
        }
    }
    println!("\nartifacts written to {out}/");
    failures
}

fn cmd_ablation(args: &Args) -> i32 {
    let n = args.u64("benchmarks", 70) as usize;
    let (_, table) = crate::coordinator::ablation::run_ablation(n, 10, args.u64("seed", 2026));
    print!("{}", table.render());
    0
}

fn cmd_components() -> i32 {
    let reg = crate::ci::ComponentRegistry::builtin();
    println!("CI/CD component catalog:");
    for r in reg.references() {
        let spec = reg.get(r).unwrap();
        let required: Vec<&str> = spec
            .inputs
            .iter()
            .filter(|i| i.required)
            .map(|i| i.name)
            .collect();
        println!("  {r:<28} required inputs: {}", required.join(", "));
    }
    0
}

fn cmd_validate(args: &Args) -> i32 {
    let mut failures = 0;
    if args.positional.is_empty() {
        eprintln!("usage: exacb validate <report.json>...");
        return 2;
    }
    for path in &args.positional {
        match std::fs::read_to_string(path) {
            Ok(text) => match crate::protocol::Report::parse(&text) {
                Ok(r) => println!(
                    "{path}: OK (v{}, system {}, {} data entries)",
                    crate::protocol::PROTOCOL_VERSION,
                    r.experiment.system,
                    r.data.len()
                ),
                Err(e) => {
                    println!("{path}: INVALID — {e}");
                    failures += 1;
                }
            },
            Err(e) => {
                println!("{path}: unreadable — {e}");
                failures += 1;
            }
        }
    }
    failures
}

fn cmd_artifacts() -> i32 {
    match crate::runtime::Engine::load_default() {
        Ok(mut engine) => {
            println!("artifacts ({}):", engine.manifest.dir.display());
            for e in engine.manifest.entries.clone() {
                println!(
                    "  {:<24} kind={:<7} flops={:>12} bytes={:>9} file={}",
                    e.name, e.kind, e.flops, e.bytes, e.file
                );
            }
            match crate::workloads::HostCalibration::measure(&mut engine) {
                Ok(c) => {
                    println!(
                        "host calibration: logmap {:.2} GFLOP/s, stream {:.2} GB/s",
                        c.logmap_gflops, c.stream_gbs
                    );
                    0
                }
                Err(e) => {
                    eprintln!("calibration failed: {e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("no artifacts: {e}\nrun `make artifacts` first");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_str(s: &str) -> i32 {
        run(s.split_whitespace().map(str::to_string).collect())
    }

    #[test]
    fn no_args_prints_usage() {
        assert_eq!(run(vec![]), 0);
    }

    #[test]
    fn unknown_subcommand_prints_usage() {
        assert_eq!(run_str("frobnicate"), 0);
    }

    #[test]
    fn components_lists_catalog() {
        assert_eq!(run_str("components"), 0);
    }

    #[test]
    fn quickstart_runs() {
        assert_eq!(run_str("quickstart --machine jedi --seed 5"), 0);
    }

    #[test]
    fn ablation_runs() {
        assert_eq!(run_str("ablation --benchmarks 10"), 0);
    }

    #[test]
    fn validate_flags_bad_files() {
        assert_eq!(run_str("validate /nonexistent.json"), 1);
        assert_eq!(run_str("validate"), 2);
    }

    #[test]
    fn small_collection_runs() {
        assert_eq!(run_str("collection --apps 3 --days 1 --seed 6"), 0);
    }

    #[test]
    fn cached_multi_machine_collection_runs() {
        assert_eq!(
            run_str(
                "collection --apps 2 --days 1 --seed 6 --cache --sweeps 2 --machines jupiter,jedi"
            ),
            0
        );
    }

    #[test]
    fn concurrent_collection_runs() {
        assert_eq!(
            run_str(
                "collection --apps 4 --days 1 --seed 9 --machines jupiter,jedi,jureca --concurrent true"
            ),
            0
        );
    }
}
