//! The `exacb` command-line interface.
//!
//! ```text
//! exacb quickstart  [--machine jedi] [--queue all]
//! exacb collection  [--apps 72] [--days 14] [--machine jupiter]
//! exacb track       [--days 20] [--inject-day 12] [--shift-pct 15]
//! exacb cmp         [--by machine] [--machines jupiter,jedi]
//! exacb rank        [--machines jupiter,jedi,jureca]
//! exacb jureap      [--apps 72] [--days 12] [--machines jupiter]
//! exacb trace       [--apps 24] [--days 3] [--export-trace trace.json]
//! exacb chaos       [--apps 8] [--days 30] [--inert true]
//! exacb measure     -d benchmarks [--validate-only] [--apps 24] [--days 3]
//! exacb figures     [--days 90] [--out out/] [--only fig3]
//! exacb ablation    [--benchmarks 70]
//! exacb components
//! exacb validate    <report.json>...
//! exacb artifacts
//! ```
//!
//! Every subcommand must be listed in [`USAGE`] with a one-line
//! description (tested below); unknown commands print the usage and
//! exit 2.

pub mod args;

pub use args::{Args, ArgsError};

use crate::ci::Trigger;
use crate::coordinator::{collection, BenchmarkRepo, World};
use crate::workloads::portfolio;

pub const USAGE: &str = "\
exacb — reproducible continuous benchmark collections at scale

USAGE: exacb <command> [flags]

COMMANDS:
  quickstart    run the paper's §II logmap example end to end
  collection    run a JUREAP-scale campaign (--apps N --days D --machine M
                --machines M1,M2 --cache --sweeps K for incremental re-runs;
                --concurrent interleaves all pipelines on the shared
                timeline via the discrete-event loop)
  track         run the injected-regression scenario through the
                regression gate and render longitudinal verdict tables
                (--days D --inject-day K --shift-pct P --machine M
                --metric NAME; --shift-pct 0 is the unchanged control;
                --expect regression|clean sets the exit code for CI)
  cmp           compare two machines — or two source commits — over the
                same workload portfolio from recorded reports: a Welch
                interval and speedup per (workload, metric, nodes) group
                plus the collection geomean (--by machine|commit
                --machines M1,M2 --apps N --days D --confidence C
                --shards K --export-json F --export-csv F; commit mode
                reuses the track scenario flags and --expect
                regression|clean sets the exit code for CI)
  rank          rank machines across every shared workload group from
                recorded reports, rebar-style: per-group competition
                ranks flattened to mean rank + geomean ratio-to-best
                (--machines M1,M2,M3 --apps N --days D --shards K
                --groups true for the per-group table; --export-json F
                --export-csv F)
  jureap        run the seeded onboarding campaign through the maturity
                gate and render the cross-application readiness report
                (--apps N --days D --machines M1,M2 --seed S; apps start
                at declared levels and must re-earn them from evidence;
                --expect-promotions fails when no level was ever earned)
  energy        onboard the seeded energy portfolio, then sweep every
                reproducibility-eligible app across GPU frequencies
                through the jpwr launcher — all points concurrently on
                the shared timeline — and render sweet-spot + projected
                savings tables (--apps N --onboard-days D --points K
                --machines M1,M2 --seed S --sequential true for the
                legacy dispatch; --expect-savings fails when no swept
                app shows a positive sweet-spot saving)
  trace         run a concurrent campaign with deterministic sim-time
                tracing + metrics armed and render the critical-path
                views: top-N longest queue waits, slowest execute
                stages, and gate-scheduled repetitions per app
                (--apps N --days D --machines M1,M2 --seed S --top N
                --export-trace trace.json --export-metrics obs.json;
                exports are sidecars, never part of report.json)
  chaos         run a collection campaign under the seeded fault model —
                node failures, preemption + requeue, a scheduler outage,
                a maintenance drain, and a fleet-wide stack-update day —
                and render the fault-labelled summary, queue, and results
                tables (--apps N --days D --machines M1,M2 --seed S;
                --inert true arms the zero-rate plan that must change
                nothing; --expect-faults fails when nothing faulted)
  measure       load a BYOB definition directory (apps, machines, engines
                as *.toml data — DESIGN.md §15) and run it through the
                concurrent campaign core (-d DIR --apps N --days D
                --machines M1,M2 --queue Q --seed S --sweeps K
                --cache true|false --metric NAME; --validate-only lints
                the definitions and exits — the CI gate for collections;
                unknown or empty directories exit 2 naming the path)
  figures       regenerate every paper table/figure (--days D --out DIR --only ID)
  ablation      run the §III integration-mode ablation (--benchmarks N)
  components    list the CI/CD component catalog
  validate      validate protocol documents (files as arguments)
  artifacts     show the AOT artifact manifest + PJRT smoke test
  help          show this usage (also: --help)

Unknown commands print this usage and exit 2.
";

/// Run the CLI; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return 2;
        }
    };
    // narration verbosity: `--quiet` silences the obs::log facade for
    // every subcommand; result tables still go to stdout untouched
    if args.bool("quiet") {
        crate::obs::log::set_quiet();
    }
    match args.subcommand.as_deref() {
        Some("quickstart") => cmd_quickstart(&args),
        Some("collection") => cmd_collection(&args),
        Some("track") => cmd_track(&args),
        Some("cmp") => cmd_cmp(&args),
        Some("rank") => cmd_rank(&args),
        Some("jureap") => cmd_jureap(&args),
        Some("energy") => cmd_energy(&args),
        Some("trace") => cmd_trace(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("measure") => cmd_measure(&args),
        Some("figures") => cmd_figures(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("components") => cmd_components(),
        Some("validate") => cmd_validate(&args),
        Some("artifacts") => cmd_artifacts(),
        // explicit success paths: `exacb help`, `exacb --help`, bare `exacb`
        Some("help") | None => {
            println!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("error: unknown command '{other}'\n\n{USAGE}");
            2
        }
    }
}

fn cmd_quickstart(args: &Args) -> i32 {
    let machine = args.str("machine", "jedi");
    let queue = args.str("queue", "all");
    let mut world = World::new(args.u64("seed", 42));
    let attached = world.try_attach_engine();
    println!(
        "PJRT engine: {}",
        if attached {
            "attached (real kernel execution)"
        } else {
            "not available (run `make artifacts`); using analytic models"
        }
    );
    world.add_repo(BenchmarkRepo::logmap_example(&machine, &queue));
    let pid = match world.run_pipeline("logmap", Trigger::Manual) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("pipeline failed: {e}");
            return 1;
        }
    };
    let p = world.pipeline(pid).unwrap();
    println!("pipeline {pid} on {machine}: succeeded={}", p.succeeded());
    for job in &p.jobs {
        println!("  job {} [{}]", job.name, match job.state {
            crate::ci::CiJobState::Success => "success",
            crate::ci::CiJobState::Failed => "FAILED",
            _ => "?",
        });
        for l in &job.log {
            println!("    {l}");
        }
    }
    if let Some(csv) = p
        .job(&format!("{machine}.logmap.execute"))
        .and_then(|j| j.artifact("results.csv"))
    {
        println!("\nresults.csv (Table I):");
        if let Some(t) = crate::util::table::Table::from_csv(csv) {
            print!("{}", t.render());
        }
    }
    if p.succeeded() {
        0
    } else {
        1
    }
}

fn cmd_collection(args: &Args) -> i32 {
    let n = args.u64("apps", 72) as usize;
    let days = args.i64("days", 14);
    let machine = args.str("machine", "jupiter");
    let machines_arg = args.str("machines", "");
    let queue = args.str("queue", "all");
    let seed = args.u64("seed", 20260101);
    let sweeps = args.u64("sweeps", 1).max(1);
    let cache = args.str("cache", "false") == "true";
    let concurrent = args.str("concurrent", "false") == "true";
    let mut world = World::new(seed);
    if cache || sweeps > 1 {
        world.enable_cache();
    }
    world.try_attach_engine();
    let apps = portfolio::generate(n, seed);
    let machine_list: Vec<String> = if machines_arg.trim().is_empty() {
        vec![machine]
    } else {
        machines_arg
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    };
    if machine_list.is_empty() {
        eprintln!("error: --machines needs at least one machine name (e.g. jupiter,jedi)");
        return 2;
    }
    let machine_refs: Vec<&str> = machine_list.iter().map(String::as_str).collect();
    collection::onboard_multi(&mut world, &apps, &machine_refs, &queue);
    println!(
        "onboarded {n} applications on {}; running {days} simulated day(s) x {sweeps} sweep(s){}…",
        machine_list.join(","),
        if concurrent { " [concurrent]" } else { "" }
    );
    let mut summary = None;
    for s in 0..sweeps {
        let t = std::time::Instant::now();
        let sum = if concurrent {
            collection::run_campaign_concurrent(&mut world, &apps, &machine_refs, days)
        } else {
            collection::run_campaign_queued(&mut world, &apps, &machine_refs, days)
        };
        println!(
            "sweep {}: {:.1} ms wall, {} cumulative cache hits",
            s + 1,
            t.elapsed().as_secs_f64() * 1e3,
            sum.cache.hits
        );
        summary = Some(sum);
    }
    let summary = summary.expect("sweeps >= 1");
    println!(
        "\npipelines: {}/{} succeeded; {} protocol reports recorded; {:.0} core-hours",
        summary.pipelines_succeeded,
        summary.pipelines_run,
        summary.reports_recorded,
        summary.core_hours
    );
    print!("{}", summary.table().render());
    println!("\nqueue-wait statistics (per machine):");
    print!(
        "{}",
        crate::coordinator::postproc::queue_stats(&world).render()
    );
    println!("{}", summary.to_json().pretty());
    0
}

/// Run the seeded injected-regression scenario end to end through the
/// `regression-check@v1` gate and render its verdict tables
/// (DESIGN.md §9). `--shift-pct 0` (or no inject day in range) runs the
/// unchanged control that must stay green; `--expect regression|clean`
/// turns the outcome into a CI-friendly exit code.
fn cmd_track(args: &Args) -> i32 {
    use crate::tracking;
    use crate::workloads::regression::RegressionScenario;

    let days = args.i64("days", 20);
    let inject = args.i64("inject-day", 12);
    let shift_arg = args.str("shift-pct", "15");
    let shift: f64 = match shift_arg.parse() {
        Ok(v) => v,
        Err(_) => {
            // a typo'd shift must not silently run a different scenario
            eprintln!("error: --shift-pct must be a number, got '{shift_arg}'");
            return 2;
        }
    };
    let machine = args.str("machine", "jedi");
    let metric = args.str("metric", "runtime");
    let seed = args.u64("seed", 20260301);
    let expect = args.str("expect", "");
    if !matches!(expect.as_str(), "" | "regression" | "clean") {
        // validate before burning the whole campaign; a typo must not
        // turn the CI gate into an unconditional pass
        eprintln!("error: --expect must be 'regression' or 'clean', got '{expect}'");
        return 2;
    }

    let planted = shift > 0.0 && (0..days).contains(&inject);
    let mut sc = if planted {
        RegressionScenario::planted(&machine, days, inject, shift, seed)
    } else {
        RegressionScenario::control(&machine, days, seed)
    };
    // gate the same metric the longitudinal table shows
    sc.metric = metric.clone();
    println!(
        "scenario: {} days on {}, {} (seed {seed})",
        days,
        machine,
        if planted {
            format!("{shift}% slowdown planted on day {inject}")
        } else {
            "unchanged control (0% shift)".to_string()
        }
    );
    let mut world = World::new(seed);
    let outcome = tracking::run_scenario(&mut world, &sc);

    let mut t = crate::util::table::Table::new(&[
        "day", "pipeline", "status", "verdict", "extra_reps",
    ]);
    for (day, pid, ok) in &outcome.pipelines {
        t.push_row(vec![
            day.to_string(),
            pid.to_string(),
            if *ok { "pass" } else { "FAIL" }.to_string(),
            outcome.verdict_on(*day).unwrap_or("-").to_string(),
            outcome
                .extra_reps_on(*day)
                .map(|e| e.to_string())
                .unwrap_or_else(|| "-".to_string()),
        ]);
    }
    print!("{}", t.render());
    println!("\nlongitudinal series ({metric}):");
    print!("{}", world.track_table(&metric).render());

    match expect.as_str() {
        "regression" => {
            // the *gate verdict* must say regression — a pipeline that
            // fails on the inject day for an unrelated reason (or a
            // detector degraded to no-data) must not count as caught
            let caught = outcome.failed_days.contains(&inject)
                && outcome.verdict_on(inject) == Some("regression")
                && outcome.failed_days.iter().all(|d| *d >= inject);
            if caught {
                println!(
                    "\nexpected regression: gate verdict 'regression' on day {inject}, \
                     no earlier failure"
                );
                0
            } else {
                eprintln!(
                    "\nexpected a 'regression' gate verdict on day {inject}; \
                     failed days: {:?}, verdict: {:?}",
                    outcome.failed_days,
                    outcome.verdict_on(inject)
                );
                1
            }
        }
        "clean" => {
            if outcome.failed_days.is_empty() {
                println!("\nexpected clean: every pipeline passed");
                0
            } else {
                eprintln!(
                    "\nexpected a green campaign; failed days: {:?}",
                    outcome.failed_days
                );
                1
            }
        }
        // "" (validated up front): informational run, no expectation
        _ => 0,
    }
}

/// Parse a `--machines a,b,c` flag into a cleaned list.
fn machine_list(args: &Args, default: &str) -> Vec<String> {
    args.str("machines", default)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Run the same generated portfolio on each machine separately (same
/// seed, whole portfolio per machine — `onboard_multi` would round-robin
/// apps so no workload would be shared) and return every recorded
/// observation in canonical order.
fn campaign_rows(machines: &[String], n: usize, days: i64, seed: u64) -> Vec<crate::store::Row> {
    let apps = portfolio::generate(n, seed);
    let mut rows = Vec::new();
    for machine in machines {
        let mut world = World::new(seed);
        collection::onboard_multi(&mut world, &apps, &[machine.as_str()], "all");
        collection::run_campaign_concurrent(&mut world, &apps, &[machine.as_str()], days);
        rows.extend(crate::query::world_rows(&world));
    }
    crate::store::sort_rows(&mut rows);
    rows
}

/// Honour `--export-json F` / `--export-csv F`: dump the exact row set
/// a query ran over. Returns false on an unwritable path.
fn export_rows(args: &Args, rows: &[crate::store::Row]) -> bool {
    let mut ok = true;
    if let Some(path) = args.flags.get("export-json") {
        match std::fs::write(path, crate::query::rows_to_json(rows).pretty()) {
            Ok(()) => println!("exported {} row(s) to {path} (JSON)", rows.len()),
            Err(e) => {
                eprintln!("error: writing {path}: {e}");
                ok = false;
            }
        }
    }
    if let Some(path) = args.flags.get("export-csv") {
        match std::fs::write(path, crate::query::rows_to_csv(rows)) {
            Ok(()) => println!("exported {} row(s) to {path} (CSV)", rows.len()),
            Err(e) => {
                eprintln!("error: writing {path}: {e}");
                ok = false;
            }
        }
    }
    ok
}

/// Turn a comparison into a CI exit code under `--expect`.
fn cmp_expectation(report: &crate::query::CmpReport, expect: &str) -> i32 {
    match expect {
        "regression" => {
            if report.count("slower") > 0 {
                println!("\nexpected regression: {} group(s) slower", report.count("slower"));
                0
            } else {
                eprintln!("\nexpected at least one 'slower' group; none found");
                1
            }
        }
        "clean" => {
            let moved = report.count("slower") + report.count("faster");
            if moved == 0 {
                println!("\nexpected clean: no group moved at this confidence");
                0
            } else {
                eprintln!("\nexpected clean; {moved} group(s) moved");
                1
            }
        }
        _ => 0,
    }
}

/// Compare two machines (or the pre-/post-change commits of a planted
/// regression scenario) from recorded reports via the snapshot query
/// layer (DESIGN.md §12): per-(workload, metric, nodes) Welch intervals
/// and speedups, a collection geomean, optional portable export, and a
/// CI exit code under `--expect`.
fn cmd_cmp(args: &Args) -> i32 {
    use crate::query::{self, Engine};

    let by = args.str("by", "machine");
    let confidence_arg = args.str("confidence", "0.95");
    let confidence: f64 = match confidence_arg.parse() {
        Ok(c) if (0.5..1.0).contains(&c) => c,
        _ => {
            eprintln!("error: --confidence must be in [0.5, 1.0), got '{confidence_arg}'");
            return 2;
        }
    };
    let shards = args.u64("shards", 4).clamp(1, 64) as usize;
    let expect = args.str("expect", "");
    if !matches!(expect.as_str(), "" | "regression" | "clean") {
        eprintln!("error: --expect must be 'regression' or 'clean', got '{expect}'");
        return 2;
    }

    let (rows, report) = match by.as_str() {
        "machine" => {
            let machines = machine_list(args, "jupiter,jedi");
            if machines.len() != 2 || machines[0] == machines[1] {
                eprintln!(
                    "error: --by machine needs exactly two distinct machines \
                     (--machines baseline,candidate), got {machines:?}"
                );
                return 2;
            }
            let n = args.u64("apps", 6) as usize;
            let days = args.i64("days", 3);
            let seed = args.u64("seed", 20260101);
            println!(
                "comparing {} (candidate) against {} (baseline): {n} app(s) x {days} day(s), \
                 seed {seed}, {shards} shard(s)…",
                machines[1], machines[0]
            );
            let rows = campaign_rows(&machines, n, days, seed);
            let report = query::compare(
                &rows,
                Engine::Machine,
                &machines[0],
                &machines[1],
                confidence,
                shards,
            );
            (rows, report)
        }
        "commit" => {
            use crate::workloads::regression::RegressionScenario;
            let days = args.i64("days", 12);
            let inject = args.i64("inject-day", 7);
            let shift_arg = args.str("shift-pct", "10");
            let Ok(shift) = shift_arg.parse::<f64>() else {
                eprintln!("error: --shift-pct must be a number, got '{shift_arg}'");
                return 2;
            };
            let machine = args.str("machine", "jedi");
            let metric = args.str("metric", "runtime");
            let seed = args.u64("seed", 20260301);
            let planted = shift > 0.0 && (0..days).contains(&inject);
            let sc = if planted {
                RegressionScenario::planted(&machine, days, inject, shift, seed)
            } else {
                RegressionScenario::control(&machine, days, seed)
            };
            println!(
                "comparing the commits of a {} scenario: {days} day(s) on {machine}, \
                 seed {seed}…",
                if planted {
                    format!("{shift}% slowdown (day {inject})")
                } else {
                    "control".to_string()
                }
            );
            let mut world = World::new(seed);
            crate::tracking::run_scenario(&mut world, &sc);
            let mut rows = query::world_rows(&world);
            rows.retain(|r| r.metric == metric);
            let commits = query::commits_by_first_seen(&rows);
            if commits.len() < 2 {
                // a control scenario records a single commit: nothing to
                // compare, which is exactly what a clean history claims
                println!("only {} distinct commit(s) recorded — nothing moved", commits.len());
                return match expect.as_str() {
                    "regression" => {
                        eprintln!("expected a regression but the history has one commit");
                        1
                    }
                    _ => 0,
                };
            }
            let (baseline, candidate) =
                (commits[0].clone(), commits[commits.len() - 1].clone());
            println!(
                "baseline commit {baseline} (first seen), candidate {candidate} (last seen)"
            );
            let report =
                query::compare(&rows, Engine::Commit, &baseline, &candidate, confidence, shards);
            (rows, report)
        }
        other => {
            eprintln!("error: --by must be 'machine' or 'commit', got '{other}'");
            return 2;
        }
    };

    print!("{}", report.table().render());
    println!(
        "\n{} group(s) compared ({} baseline-only, {} candidate-only): \
         {} faster, {} slower, {} indistinguishable at {:.0}% confidence",
        report.rows.len(),
        report.only_baseline,
        report.only_candidate,
        report.count("faster"),
        report.count("slower"),
        report.count("indistinguishable"),
        confidence * 100.0
    );
    if let Some(g) = report.geomean_speedup() {
        println!("geomean speedup (candidate vs baseline): {g:.3}x");
    }
    if !export_rows(args, &rows) {
        return 1;
    }
    cmp_expectation(&report, &expect)
}

/// Rank machines across every shared workload group from recorded
/// reports (DESIGN.md §12): rebar-style per-group competition ranks
/// flattened to mean rank + geomean ratio-to-best per machine.
fn cmd_rank(args: &Args) -> i32 {
    use crate::query::{self, Engine};

    let machines = machine_list(args, "jupiter,jedi,jureca");
    if machines.len() < 2 {
        eprintln!("error: ranking needs at least two machines (--machines a,b,…)");
        return 2;
    }
    let n = args.u64("apps", 6) as usize;
    let days = args.i64("days", 3);
    let seed = args.u64("seed", 20260101);
    let shards = args.u64("shards", 4).clamp(1, 64) as usize;
    println!(
        "ranking {} over {n} app(s) x {days} day(s), seed {seed}, {shards} shard(s)…",
        machines.join(",")
    );
    let rows = campaign_rows(&machines, n, days, seed);
    let report = query::rank(&rows, Engine::Machine, shards);
    if args.str("groups", "false") == "true" {
        print!("{}", report.groups_table().render());
        println!();
    }
    print!("{}", report.table().render());
    println!(
        "\n{} workload group(s) ranked across {} machine(s)",
        report.groups.len(),
        report.aggregate.len()
    );
    if !export_rows(args, &rows) {
        return 1;
    }
    if report.groups.is_empty() {
        eprintln!("no shared workload groups — nothing was ranked");
        return 1;
    }
    0
}

/// Run the seeded JUREAP-style onboarding campaign end to end through
/// the `maturity-check@v1` gate (DESIGN.md §10) and render the
/// cross-application readiness report: per-domain maturity
/// distribution, promotion timeline, energy-study eligibility
/// (reproducibility-only), and the full per-app maturity table.
fn cmd_jureap(args: &Args) -> i32 {
    use crate::maturity::{self, campaign};
    use crate::workloads::onboarding::OnboardingScenario;

    let n = args.u64("apps", 72) as usize;
    let days = args.i64("days", 12);
    let seed = args.u64("seed", 20260101);
    let machines_arg = args.str("machines", "jupiter");
    let expect_promotions = args.bool("expect-promotions");
    let mut sc = OnboardingScenario::generate(n, days, seed);
    sc.machines = machines_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if sc.machines.is_empty() {
        eprintln!("error: --machines needs at least one machine name (e.g. jupiter,jedi)");
        return 2;
    }
    println!(
        "onboarding {n} applications on {} for {days} simulated day(s) \
         (seed {seed}, replay audit every {} days)…",
        sc.machines.join(","),
        sc.verify_every
    );
    let mut world = World::new(seed);
    let t0 = std::time::Instant::now();
    let outcome = campaign::run_onboarding(&mut world, &sc);
    println!(
        "pipelines: {}/{} succeeded in {:.1} ms wall",
        outcome.pipelines_succeeded,
        outcome.pipelines_run,
        t0.elapsed().as_secs_f64() * 1e3
    );

    let promotions = outcome
        .transitions
        .iter()
        .filter(|t| t.to > t.from)
        .count();
    let demotions = outcome.transitions.len() - promotions;
    println!("\nper-domain maturity distribution (levels currently held):");
    print!("{}", campaign::domain_distribution(&sc, &world).render());
    println!("\npromotion timeline ({promotions} promotion(s), {demotions} demotion(s)):");
    print!("{}", campaign::promotion_timeline(&outcome).render());
    let eligible = campaign::energy_eligible(&sc, &world);
    println!(
        "\nenergy-study eligibility (reproducibility only): {} of {} app(s)",
        eligible.len(),
        n
    );
    for app in eligible.iter().take(10) {
        println!("  {app}");
    }
    if eligible.len() > 10 {
        println!("  … and {} more", eligible.len() - 10);
    }
    println!("\ncross-application readiness (declared vs earned, evidence):");
    print!(
        "{}",
        maturity::maturity_table(&world, &maturity::CriteriaConfig::default()).render()
    );
    if expect_promotions && promotions == 0 {
        eprintln!("\nexpected at least one earned promotion; none happened");
        return 1;
    }
    0
}

/// Run the seeded system-wide energy study end to end (DESIGN.md §11):
/// onboard the energy portfolio through the maturity gate so levels are
/// *earned*, then sweep every reproducibility-eligible application
/// across its machine's frequency range — every point of every app
/// interleaved on the shared batch timeline — and render the per-app
/// sweet-spot table plus the projected collection-wide savings.
/// `--expect-savings` turns the outcome into a CI-friendly exit code.
fn cmd_energy(args: &Args) -> i32 {
    use crate::energy::study;
    use crate::maturity::campaign;

    let n = args.u64("apps", 24) as usize;
    let onboard_days = args.i64("onboard-days", 8);
    let points = args.u64("points", 8).clamp(2, 64) as usize;
    let seed = args.u64("seed", 20260101);
    let machines_arg = args.str("machines", "jupiter");
    let sequential = args.str("sequential", "false") == "true";
    let expect_savings = args.bool("expect-savings");
    if onboard_days < 4 {
        // the first replay-audit day is day 3: fewer onboarding days can
        // never earn reproducibility, so the study would be vacuous
        eprintln!("error: --onboard-days must be at least 4 (first replay audit is day 3)");
        return 2;
    }
    let mut sc = study::energy_scenario(n, onboard_days, seed);
    sc.machines = machines_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if sc.machines.is_empty() {
        eprintln!("error: --machines needs at least one machine name (e.g. jupiter,jedi)");
        return 2;
    }
    println!(
        "onboarding {n} applications on {} for {onboard_days} day(s), then sweeping \
         eligible apps over {points} frequencies{}…",
        sc.machines.join(","),
        if sequential { " [sequential]" } else { " [concurrent]" }
    );
    let mut world = World::new(seed);
    let t0 = std::time::Instant::now();
    let onboarding = campaign::run_onboarding(&mut world, &sc);
    println!(
        "onboarding: {}/{} pipelines succeeded; {} of {n} app(s) energy-eligible \
         (reproducibility only)",
        onboarding.pipelines_succeeded,
        onboarding.pipelines_run,
        campaign::energy_eligible(&sc, &world).len(),
    );

    let sweep_start = world.now();
    let outcome = study::run_energy_campaign(&mut world, &sc, points, !sequential);
    let sim_s = (world.now().0 - sweep_start.0).max(0);
    for l in &outcome.log {
        println!("  {l}");
    }
    println!(
        "\nswept {} app(s) ({} excluded) in {sim_s} simulated s, {:.1} ms wall",
        outcome.swept.len(),
        outcome.excluded.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("\nper-app sweet spots:");
    print!("{}", outcome.sweet_spot_table().render());
    println!("\nprojected collection-wide savings at the sweet spots:");
    print!("{}", outcome.savings_table().render());
    println!("\nrecorded-sweep view (exacb.data only):");
    print!("{}", world.energy_table().render());

    let saving = outcome.projected_saving_frac();
    println!(
        "\nprojected collection saving: {:.1}% of nominal energy ({} of {} swept app(s) \
         with a positive sweet-spot saving)",
        saving * 100.0,
        outcome.apps_with_saving(),
        outcome.swept.len()
    );
    if expect_savings && (outcome.swept.is_empty() || outcome.apps_with_saving() == 0) {
        eprintln!("\nexpected at least one positive sweet-spot saving; none found");
        return 1;
    }
    0
}

/// Run a concurrent campaign with the deterministic observability layer
/// armed (DESIGN.md §13) and render the critical-path views over the
/// drained trace: top-N longest queue waits, slowest execute stages, and
/// gate-scheduled repetitions per app. `--export-trace` writes Chrome
/// trace-event JSON (Perfetto-loadable, sim-time µs); `--export-metrics`
/// writes the `obs.json` counters sidecar. Arming never changes what the
/// campaign produces — reports, sacct records, and store bytes are
/// byte-identical armed or disarmed (pinned by `tests/integration_obs.rs`).
fn cmd_trace(args: &Args) -> i32 {
    let n = args.u64("apps", 24) as usize;
    let days = args.i64("days", 3);
    let seed = args.u64("seed", 20260101);
    let top_n = args.u64("top", 10).max(1) as usize;
    let queue = args.str("queue", "all");
    let machines = machine_list(args, "jupiter,jedi,jureca");
    if machines.is_empty() {
        eprintln!("error: --machines needs at least one machine name (e.g. jupiter,jedi)");
        return 2;
    }
    let machine_refs: Vec<&str> = machines.iter().map(String::as_str).collect();

    let mut world = World::new(seed);
    let apps = portfolio::generate(n, seed);
    collection::onboard_multi(&mut world, &apps, &machine_refs, &queue);
    println!(
        "tracing {n} application(s) on {} over {days} simulated day(s) (seed {seed})…",
        machines.join(",")
    );

    // arm both recorders for exactly the campaign; drop anything a prior
    // caller left behind so the exports cover only this run
    crate::obs::trace::drain();
    crate::obs::metrics::drain();
    let prior_tracing = crate::obs::set_tracing(true);
    let prior_metrics = crate::obs::set_metrics(true);
    let summary = collection::run_campaign_concurrent(&mut world, &apps, &machine_refs, days);
    crate::obs::set_tracing(prior_tracing);
    crate::obs::set_metrics(prior_metrics);
    let events = crate::obs::trace::drain();
    let metrics = crate::obs::metrics::drain();

    println!(
        "pipelines: {}/{} succeeded; {} trace event(s), {} task wake(s), {} job(s) started \
         ({} backfilled)",
        summary.pipelines_succeeded,
        summary.pipelines_run,
        events.len(),
        metrics.counter(crate::obs::Ctr::TaskWakes),
        metrics.counter(crate::obs::Ctr::JobsStarted),
        metrics.counter(crate::obs::Ctr::JobsBackfilled),
    );

    let (waits, steps, gates) =
        crate::coordinator::postproc::critical_path_tables(&events, &metrics, top_n);
    println!("\ntop-{top_n} longest queue waits:");
    print!("{}", waits.render());
    println!("\ntop-{top_n} slowest job runs:");
    print!("{}", steps.render());
    println!("\ngate-scheduled repetitions per app:");
    print!("{}", gates.render());

    // exports may name not-yet-existing directories (e.g. out/trace.json)
    fn write_export(path: &str, content: String) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, content)
    }

    let mut ok = true;
    if let Some(path) = args.flags.get("export-trace") {
        match write_export(path, crate::obs::trace::chrome_trace_json(&events)) {
            Ok(()) => {
                println!(
                    "\nexported {} event(s) to {path} (Chrome trace JSON)",
                    events.len()
                )
            }
            Err(e) => {
                eprintln!("error: writing {path}: {e}");
                ok = false;
            }
        }
    }
    if let Some(path) = args.flags.get("export-metrics") {
        match write_export(path, metrics.to_json().pretty()) {
            Ok(()) => println!("exported metrics to {path} (obs.json sidecar)"),
            Err(e) => {
                eprintln!("error: writing {path}: {e}");
                ok = false;
            }
        }
    }
    if ok {
        0
    } else {
        1
    }
}

/// Run a collection campaign under the armed seeded fault model
/// (DESIGN.md §14) and render how the fleet degraded: the campaign
/// summary, the fault-labelled queue statistics, and the per-entry
/// results table where failed repetitions are named, not dropped.
/// `--inert true` arms the zero-rate plan whose timeline must be
/// byte-identical to never arming anything (pinned by
/// `tests/integration_chaos.rs`); `--expect-faults` turns "something
/// actually faulted" into a CI-friendly exit code.
fn cmd_chaos(args: &Args) -> i32 {
    use crate::workloads::chaos::{self, ChaosScenario};

    let n = args.u64("apps", 8) as usize;
    let days = args.i64("days", 30);
    let seed = args.u64("seed", 20260101);
    let inert = args.str("inert", "false") == "true";
    let expect_faults = args.bool("expect-faults");
    if inert && expect_faults {
        eprintln!("error: --inert arms the zero-rate plan; it cannot --expect-faults");
        return 2;
    }
    let mut sc = if inert {
        ChaosScenario::quiet(n, days, seed)
    } else {
        ChaosScenario::generate(n, days, seed)
    };
    let machines_arg = args.str("machines", "");
    if !machines_arg.trim().is_empty() {
        sc.machines = machines_arg
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    if sc.machines.is_empty() {
        eprintln!("error: --machines needs at least one machine name (e.g. jedi,jupiter)");
        return 2;
    }
    println!(
        "chaos campaign: {n} application(s) on {} over {days} simulated day(s) \
         (seed {seed}){}…",
        sc.machines.join(","),
        if inert {
            " [inert: zero-rate fault plan]".to_string()
        } else {
            format!(
                " [node-fail {:.0}%, preempt {:.0}%, outage day {}, maintenance day {}, \
                 stack update day {}, '{}' forced flaky days {}..{}]",
                sc.node_fail_rate * 100.0,
                sc.preempt_rate * 100.0,
                sc.outage_day,
                sc.maintenance_day,
                sc.stack_update_day,
                sc.flaky_app,
                sc.flaky_from_day,
                sc.flaky_from_day + sc.flaky_days
            )
        }
    );

    let mut world = World::new(seed);
    let t0 = std::time::Instant::now();
    let summary = chaos::run_chaos_campaign(&mut world, &sc);
    println!(
        "\npipelines: {}/{} succeeded in {:.1} ms wall; {} protocol reports recorded",
        summary.pipelines_succeeded,
        summary.pipelines_run,
        t0.elapsed().as_secs_f64() * 1e3,
        summary.reports_recorded
    );
    print!("{}", summary.table().render());

    let (mut node_fail, mut preempted) = (0usize, 0usize);
    for m in &sc.machines {
        if let Some(bs) = world.batch.get(m) {
            for r in bs.records_iter() {
                match r.state {
                    crate::scheduler::JobState::NodeFail => node_fail += 1,
                    crate::scheduler::JobState::Preempted => preempted += 1,
                    _ => {}
                }
            }
        }
    }
    println!(
        "\nfaults suffered: {node_fail} node failure(s), {preempted} preemption(s) \
         (every preempted job was requeued)"
    );
    println!("\nqueue statistics with fault accounting (per machine):");
    print!(
        "{}",
        crate::coordinator::postproc::queue_stats(&world).render()
    );
    if expect_faults && node_fail + preempted == 0 {
        eprintln!("\nexpected the armed campaign to fault; nothing did");
        return 1;
    }
    0
}

/// Load a BYOB definition directory (DESIGN.md §15) and run it through
/// the concurrent campaign core. `-d`/`--dir` names the directory;
/// unknown or empty paths exit 2 naming the path, invalid definitions
/// print every file/table/key-named error and exit 1, and
/// `--validate-only` stops after the lint — the CI gate for community
/// collection directories.
fn cmd_measure(args: &Args) -> i32 {
    use crate::defs::{self, DefsError, MeasurePlan};

    let dir = {
        let short = args.str("d", "");
        if short.is_empty() {
            args.str("dir", "")
        } else {
            short
        }
    };
    if dir.is_empty() {
        eprintln!("error: exacb measure needs a definition directory: -d <dir>\n\n{USAGE}");
        return 2;
    }
    let set = match defs::load_dir(&dir) {
        Ok(set) => set,
        Err(e @ (DefsError::Io { .. } | DefsError::Empty { .. })) => {
            eprintln!("error: {e}");
            return 2;
        }
        Err(e) => {
            eprintln!("error: invalid definitions in '{dir}':\n{e}");
            return 1;
        }
    };
    println!(
        "loaded {} app(s), {} machine(s), {} engine(s) from {dir}",
        set.apps.len(),
        set.machines.len(),
        set.engines.len()
    );
    if args.bool("validate-only") {
        println!("definitions valid");
        return 0;
    }
    let plan = MeasurePlan {
        apps: args.u64("apps", 0) as usize,
        days: args.i64("days", 3),
        machines: machine_list(args, ""),
        queue: args.str("queue", "all"),
        seed: args.u64("seed", 20260101),
        cache: args.str("cache", "true") == "true",
        sweeps: args.u64("sweeps", 1).max(1) as u32,
    };
    let t0 = std::time::Instant::now();
    let (world, summaries) = match defs::run_measure(&set, &plan) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let summary = summaries.last().expect("sweeps >= 1");
    println!(
        "pipelines: {}/{} succeeded over {} sweep(s) in {:.1} ms wall; \
         {} protocol reports recorded; {} cumulative cache hits",
        summary.pipelines_succeeded,
        summary.pipelines_run,
        summaries.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        summary.reports_recorded,
        summary.cache.hits
    );
    print!("{}", summary.table().render());
    println!("\nqueue-wait statistics (per machine):");
    print!(
        "{}",
        crate::coordinator::postproc::queue_stats(&world).render()
    );
    let metric = args.str("metric", "tts");
    println!("\nper-entry results ({metric}):");
    print!(
        "{}",
        crate::coordinator::postproc::collection_results_table(&world, &metric).render()
    );
    0
}

fn cmd_figures(args: &Args) -> i32 {
    let days = args.i64("days", 90);
    let seed = args.u64("seed", 2026);
    let out = args.str("out", "out");
    let only = args.flags.get("only").cloned();
    let results = crate::experiments::run_all(days, seed);
    let dir = std::path::Path::new(&out);
    let mut failures = 0;
    for r in results {
        if let Some(only) = &only {
            if !r.id.to_lowercase().replace(' ', "").contains(&only.to_lowercase()) {
                continue;
            }
        }
        r.print();
        if let Err(e) = r.save(dir) {
            eprintln!("save failed: {e}");
            failures += 1;
        }
    }
    println!("\nartifacts written to {out}/");
    failures
}

fn cmd_ablation(args: &Args) -> i32 {
    let n = args.u64("benchmarks", 70) as usize;
    let (_, table) = crate::coordinator::ablation::run_ablation(n, 10, args.u64("seed", 2026));
    print!("{}", table.render());
    0
}

fn cmd_components() -> i32 {
    let reg = crate::ci::ComponentRegistry::builtin();
    println!("CI/CD component catalog:");
    for r in reg.references() {
        let spec = reg.get(r).unwrap();
        let required: Vec<&str> = spec
            .inputs
            .iter()
            .filter(|i| i.required)
            .map(|i| i.name)
            .collect();
        println!("  {r:<28} required inputs: {}", required.join(", "));
    }
    0
}

fn cmd_validate(args: &Args) -> i32 {
    let mut failures = 0;
    if args.positional.is_empty() {
        eprintln!("usage: exacb validate <report.json>...");
        return 2;
    }
    for path in &args.positional {
        match std::fs::read_to_string(path) {
            Ok(text) => match crate::protocol::Report::parse(&text) {
                Ok(r) => println!(
                    "{path}: OK (v{}, system {}, {} data entries)",
                    crate::protocol::PROTOCOL_VERSION,
                    r.experiment.system,
                    r.data.len()
                ),
                Err(e) => {
                    println!("{path}: INVALID — {e}");
                    failures += 1;
                }
            },
            Err(e) => {
                println!("{path}: unreadable — {e}");
                failures += 1;
            }
        }
    }
    failures
}

fn cmd_artifacts() -> i32 {
    match crate::runtime::Engine::load_default() {
        Ok(mut engine) => {
            println!("artifacts ({}):", engine.manifest.dir.display());
            for e in engine.manifest.entries.clone() {
                println!(
                    "  {:<24} kind={:<7} flops={:>12} bytes={:>9} file={}",
                    e.name, e.kind, e.flops, e.bytes, e.file
                );
            }
            match crate::workloads::HostCalibration::measure(&mut engine) {
                Ok(c) => {
                    println!(
                        "host calibration: logmap {:.2} GFLOP/s, stream {:.2} GB/s",
                        c.logmap_gflops, c.stream_gbs
                    );
                    0
                }
                Err(e) => {
                    eprintln!("calibration failed: {e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("no artifacts: {e}\nrun `make artifacts` first");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_str(s: &str) -> i32 {
        run(s.split_whitespace().map(str::to_string).collect())
    }

    #[test]
    fn no_args_prints_usage() {
        assert_eq!(run(vec![]), 0);
    }

    #[test]
    fn help_is_the_explicit_success_path() {
        assert_eq!(run_str("help"), 0);
        assert_eq!(run_str("--help"), 0);
    }

    #[test]
    fn unknown_subcommand_errors_with_exit_2() {
        assert_eq!(run_str("frobnicate"), 2);
        assert_eq!(run_str("colection --apps 3"), 2); // typo'd command
    }

    #[test]
    fn components_lists_catalog() {
        assert_eq!(run_str("components"), 0);
    }

    #[test]
    fn quickstart_runs() {
        assert_eq!(run_str("quickstart --machine jedi --seed 5"), 0);
    }

    #[test]
    fn ablation_runs() {
        assert_eq!(run_str("ablation --benchmarks 10"), 0);
    }

    #[test]
    fn validate_flags_bad_files() {
        assert_eq!(run_str("validate /nonexistent.json"), 1);
        assert_eq!(run_str("validate"), 2);
    }

    #[test]
    fn small_collection_runs() {
        assert_eq!(run_str("collection --apps 3 --days 1 --seed 6"), 0);
    }

    #[test]
    fn cached_multi_machine_collection_runs() {
        assert_eq!(
            run_str(
                "collection --apps 2 --days 1 --seed 6 --cache --sweeps 2 --machines jupiter,jedi"
            ),
            0
        );
    }

    #[test]
    fn track_detects_planted_regression() {
        assert_eq!(
            run_str("track --days 7 --inject-day 5 --shift-pct 18 --seed 11 --expect regression"),
            0
        );
    }

    #[test]
    fn track_control_stays_clean() {
        assert_eq!(
            run_str("track --days 6 --shift-pct 0 --seed 12 --expect clean"),
            0
        );
    }

    #[test]
    fn track_rejects_typoed_expectation() {
        // a typo must not turn the CI gate into an unconditional pass
        assert_eq!(
            run_str("track --days 1 --shift-pct 0 --seed 13 --expect regressions"),
            2
        );
        assert_eq!(run_str("track --days 1 --shift-pct 1O"), 2); // typo'd digit
    }

    #[test]
    fn cmp_commit_mode_catches_the_planted_regression() {
        assert_eq!(
            run_str(
                "cmp --by commit --days 10 --inject-day 6 --shift-pct 12 --seed 11 \
                 --expect regression"
            ),
            0
        );
        // a control history has one commit: clean passes, regression fails
        assert_eq!(
            run_str("cmp --by commit --days 4 --shift-pct 0 --seed 12 --expect clean"),
            0
        );
        assert_eq!(
            run_str("cmp --by commit --days 4 --shift-pct 0 --seed 12 --expect regression"),
            1
        );
    }

    #[test]
    fn cmp_machine_mode_compares_two_machines() {
        assert_eq!(
            run_str("cmp --machines jupiter,jedi --apps 2 --days 2 --seed 7 --shards 3"),
            0
        );
    }

    #[test]
    fn cmp_validates_its_flags_before_running() {
        assert_eq!(run_str("cmp --by flavour"), 2);
        assert_eq!(run_str("cmp --machines jupiter"), 2);
        assert_eq!(run_str("cmp --machines jupiter,jupiter"), 2);
        assert_eq!(run_str("cmp --confidence 1.5"), 2);
        assert_eq!(run_str("cmp --by commit --shift-pct 1O"), 2); // typo'd digit
        assert_eq!(run_str("cmp --expect regressions"), 2);
    }

    #[test]
    fn rank_ranks_machines_and_exports() {
        let dir = std::env::temp_dir().join("exacb-rank-test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("rows.json");
        let csv = dir.join("rows.csv");
        assert_eq!(
            run_str(&format!(
                "rank --machines jupiter,jedi --apps 2 --days 2 --seed 7 --groups true \
                 --export-json {} --export-csv {}",
                json.display(),
                csv.display()
            )),
            0
        );
        let doc = std::fs::read_to_string(&json).unwrap();
        assert!(crate::util::json::Json::parse(&doc).unwrap().as_arr().unwrap().len() > 0);
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("app,machine,metric,nodes,"));
        assert_eq!(run_str("rank --machines jupiter"), 2);
    }

    #[test]
    fn trace_small_campaign_renders_and_exports() {
        let dir = std::env::temp_dir().join("exacb-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.json");
        let obs = dir.join("obs.json");
        assert_eq!(
            run_str(&format!(
                "trace --apps 3 --days 1 --seed 8 --machines jedi --top 5 \
                 --export-trace {} --export-metrics {}",
                trace.display(),
                obs.display()
            )),
            0
        );
        let doc = crate::util::json::Json::parse(&std::fs::read_to_string(&trace).unwrap())
            .unwrap();
        assert!(
            doc.get("traceEvents")
                .and_then(crate::util::json::Json::as_arr)
                .map(|a| !a.is_empty())
                .unwrap_or(false),
            "trace export should carry events"
        );
        let m = crate::util::json::Json::parse(&std::fs::read_to_string(&obs).unwrap()).unwrap();
        assert_eq!(m.str_of("component"), Some("obs"));
        assert!(m.get("counters").unwrap().u64_of("jobs_started").unwrap_or(0) > 0);
        // arming is scoped to the campaign: nothing left armed afterwards
        assert!(!crate::obs::tracing());
        assert!(!crate::obs::metrics_on());
    }

    #[test]
    fn chaos_small_campaign_runs_and_validates_flags() {
        // a short armed campaign with the standard forced-flaky window
        // always faults, so --expect-faults exits 0
        assert_eq!(
            run_str("chaos --apps 3 --days 4 --seed 13 --expect-faults true"),
            0
        );
        // the inert variant runs clean and cannot expect faults
        assert_eq!(run_str("chaos --apps 2 --days 2 --seed 13 --inert true"), 0);
        assert_eq!(
            run_str("chaos --apps 2 --days 2 --inert true --expect-faults true"),
            2
        );
        assert_eq!(run_str("chaos --apps 2 --days 2 --machines ,"), 2);
    }

    #[test]
    fn concurrent_collection_runs() {
        assert_eq!(
            run_str(
                "collection --apps 4 --days 1 --seed 9 --machines jupiter,jedi,jureca --concurrent true"
            ),
            0
        );
    }

    #[test]
    fn energy_small_study_finds_savings() {
        // the pinned eligible third (energy_scenario) earns
        // reproducibility on the day-3 audit, so a ≥4-day onboarding
        // guarantees swept apps — and bowls on the standard machines
        // have positive sweet-spot savings
        assert_eq!(
            run_str(
                "energy --apps 5 --onboard-days 5 --points 4 --seed 20260101 \
                 --expect-savings true"
            ),
            0
        );
        // too few onboarding days can never earn eligibility: loud exit 2
        assert_eq!(run_str("energy --apps 2 --onboard-days 2"), 2);
        assert_eq!(run_str("energy --apps 2 --onboard-days 5 --machines ,"), 2);
    }

    #[test]
    fn jureap_small_onboarding_earns_levels() {
        // small but long enough to pass the first audit day: levels are
        // earned, so --expect-promotions must exit 0
        assert_eq!(
            run_str("jureap --apps 6 --days 5 --seed 20260101 --expect-promotions true"),
            0
        );
        assert_eq!(run_str("jureap --apps 2 --days 1 --machines ,"), 2);
    }

    #[test]
    fn measure_fails_loudly_without_a_usable_directory() {
        // no -d flag at all
        assert_eq!(run_str("measure"), 2);
        // unknown path: exit 2, naming the path (stderr)
        assert_eq!(run_str("measure -d /no/such/definition/dir"), 2);
        assert_eq!(run_str("measure --dir /no/such/definition/dir"), 2);
        // empty directory: exit 2 too
        let dir = std::env::temp_dir().join("exacb-measure-empty-test");
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(run_str(&format!("measure -d {}", dir.display())), 2);
    }

    #[test]
    fn measure_runs_a_rendered_definition_directory() {
        // render the built-in set to a temp dir and measure it: the
        // full loader → validator → campaign path under the CLI
        let dir = std::env::temp_dir().join("exacb-measure-run-test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (name, text) in crate::defs::render(&crate::defs::builtin()) {
            std::fs::write(dir.join(name), text).unwrap();
        }
        let d = dir.display();
        assert_eq!(run_str(&format!("measure -d {d} --validate-only true")), 0);
        assert_eq!(
            run_str(&format!("measure -d {d} --apps 2 --days 1 --seed 6 --sweeps 2")),
            0
        );
        // bad campaign flags over valid definitions: loud exit 2
        assert_eq!(
            run_str(&format!("measure -d {d} --apps 1 --days 1 --machines frontier")),
            2
        );
        // corrupt one definition: every error names file/table/key, exit 1
        let jureap = dir.join("jureap.toml");
        let text = std::fs::read_to_string(&jureap).unwrap();
        std::fs::write(&jureap, text.replace("steps = ", "steps = -")).unwrap();
        assert_eq!(run_str(&format!("measure -d {d} --validate-only true")), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Satellite contract: every dispatched subcommand is listed in
    /// `exacb help` with a one-line description — a new subcommand
    /// cannot silently stay undocumented.
    #[test]
    fn help_lists_every_subcommand_with_a_description() {
        // keep in sync with the dispatcher match in `run` (that is the
        // point: this list fails loudly when the two drift apart)
        const SUBCOMMANDS: [&str; 16] = [
            "quickstart",
            "collection",
            "track",
            "cmp",
            "rank",
            "jureap",
            "energy",
            "trace",
            "chaos",
            "measure",
            "figures",
            "ablation",
            "components",
            "validate",
            "artifacts",
            "help",
        ];
        for name in SUBCOMMANDS {
            let line = USAGE
                .lines()
                .find(|l| {
                    l.strip_prefix("  ")
                        .and_then(|l| l.strip_prefix(name))
                        .map(|rest| rest.starts_with(' '))
                        .unwrap_or(false)
                })
                .unwrap_or_else(|| panic!("'{name}' missing from USAGE"));
            let description = line[2 + name.len()..].trim();
            assert!(
                !description.is_empty(),
                "'{name}' listed without a description"
            );
        }
        // …and nothing else: USAGE command lines (two-space indent, a
        // word, a description) match the list exactly, so adding a
        // subcommand to either side without the other fails here
        let usage_commands = USAGE
            .lines()
            .skip_while(|l| !l.starts_with("COMMANDS:"))
            .filter(|l| l.len() > 2 && l.starts_with("  ") && !l[2..3].contains(' '))
            .count();
        assert_eq!(
            usage_commands,
            SUBCOMMANDS.len(),
            "USAGE lists a command this test does not cover (or vice versa)"
        );
        assert_eq!(run_str("help"), 0);
    }
}
