//! Tiny argument parser (substrate; no `clap` vendored offline).
//!
//! Supports `exacb <subcommand> [--flag value]... [--switch]...` with
//! typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum ArgsError {
    Duplicate(String),
    MissingValue(String),
}

impl std::fmt::Display for ArgsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgsError::Duplicate(k) => write!(f, "flag '--{k}' given twice"),
            ArgsError::MissingValue(k) => write!(f, "flag '--{k}' expects a value"),
        }
    }
}

impl std::error::Error for ArgsError {}

/// Single-letter short flag: `-d` or `-d=value` (no bundling). Returns
/// the flag name and, for the `=` form, the inline value. `-5` and the
/// like are not flags (second byte must be alphabetic).
fn short_name(arg: &str) -> Option<(String, Option<String>)> {
    let rest = arg.strip_prefix('-')?;
    if rest.starts_with('-') {
        return None; // long flag, handled elsewhere
    }
    let mut chars = rest.chars();
    let c = chars.next()?;
    if !c.is_ascii_alphabetic() {
        return None;
    }
    match chars.next() {
        None => Some((c.to_string(), None)),
        Some('=') => Some((c.to_string(), Some(chars.collect()))),
        _ => None,
    }
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, ArgsError> {
        let mut it = argv.into_iter().peekable();
        let mut subcommand = None;
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(arg) = it.next() {
            if let Some((name, inline)) = short_name(&arg) {
                let value = match inline {
                    Some(v) => v,
                    None => match it.peek() {
                        Some(next) if !next.starts_with('-') => it.next().unwrap(),
                        _ => "true".to_string(),
                    },
                };
                if flags.insert(name.clone(), value).is_some() {
                    return Err(ArgsError::Duplicate(name));
                }
            } else if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    if flags.insert(k.to_string(), v.to_string()).is_some() {
                        return Err(ArgsError::Duplicate(k.to_string()));
                    }
                } else {
                    let value = match it.peek() {
                        Some(next) if !next.starts_with("--") => it.next().unwrap(),
                        _ => "true".to_string(),
                    };
                    if flags.insert(name.to_string(), value).is_some() {
                        return Err(ArgsError::Duplicate(name.to_string()));
                    }
                }
            } else if subcommand.is_none() {
                subcommand = Some(arg);
            } else {
                positional.push(arg);
            }
        }
        Ok(Args {
            subcommand,
            flags,
            positional,
        })
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn i64(&self, name: &str, default: i64) -> i64 {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(String::as_str), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_flags_positional() {
        // NB: a bare switch consumes the next token as its value unless it
        // is another flag, so switches go last or use `=`.
        let a = parse("collection extra --apps 72 --days=14 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("collection"));
        assert_eq!(a.u64("apps", 0), 72);
        assert_eq!(a.u64("days", 0), 14);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.str("machine", "jedi"), "jedi");
        assert_eq!(a.u64("days", 7), 7);
        assert!(!a.bool("quick"));
    }

    #[test]
    fn duplicates_rejected() {
        let e = Args::parse(
            "x --a 1 --a 2".split_whitespace().map(str::to_string),
        )
        .unwrap_err();
        assert!(matches!(e, ArgsError::Duplicate(_)));
    }

    #[test]
    fn short_flags() {
        let a = parse("measure -d benchmarks --days 2");
        assert_eq!(a.subcommand.as_deref(), Some("measure"));
        assert_eq!(a.str("d", ""), "benchmarks");
        assert_eq!(a.i64("days", 0), 2);
        let a = parse("measure -d=bench/dir -v");
        assert_eq!(a.str("d", ""), "bench/dir");
        assert!(a.bool("v")); // trailing short switch takes "true"
        // a negative number is a value, not a short flag
        let a = parse("rank --shift -5");
        assert_eq!(a.i64("shift", 0), -5);
        // bundles like -xvf are not flags and stay positional
        let a = parse("run -xvf");
        assert_eq!(a.positional, vec!["-xvf"]);
        let e = Args::parse("x -d a -d b".split_whitespace().map(str::to_string))
            .unwrap_err();
        assert!(matches!(e, ArgsError::Duplicate(_)));
    }

    #[test]
    fn empty_is_fine() {
        let a = Args::parse(std::iter::empty()).unwrap();
        assert!(a.subcommand.is_none());
    }
}
