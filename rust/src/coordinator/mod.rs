//! The exaCB coordinator — the paper's system contribution (§IV–§V;
//! DESIGN.md §3 data flow, §5 concurrent runner).
//!
//! * [`repo`] — benchmark repositories: JUBE-style definitions + CI
//!   config + the `exacb.data` branch (§IV-A).
//! * [`executor`] — the harness↔batch bridge with jpwr launcher and
//!   feature injection (§IV-D, §VI-B).
//! * [`execution`] — the Execution Orchestrator: setup → execute →
//!   record, each an individual CI job (§V-A.1).
//! * [`postproc`] — machine-comparison / scalability / time-series /
//!   energy post-processing orchestrators (§V-A.2).
//! * [`collection`] — JUREAP-scale campaign management over portfolios
//!   at heterogeneous maturity (§VI-A).
//! * [`event_loop`] — the discrete-event core: resumable pipeline tasks
//!   interleaved across all machines on one shared virtual timeline.
//! * [`ablation`] — the §III / Fig. 2 integration-mode trade-off model.
//! * [`world`] — the deployment container + component dispatcher.

pub mod ablation;
pub mod collection;
pub mod event_loop;
pub mod execution;
pub mod executor;
pub mod postproc;
pub mod repo;
pub mod world;

pub use collection::{
    assign, dispatch_item, onboard, onboard_multi, repo_for_app, run_campaign,
    run_campaign_concurrent, run_campaign_concurrent_with, run_campaign_queued,
    CollectionSummary, WorkItem, WorkQueue,
};
pub use event_loop::{drive, drive_reference, PipelineTask, TaskPoll};
pub use execution::{run_execution, ExecPoll, ExecutionParams, ExecutionTask};
pub use executor::{env_fingerprint, BatchStepExecutor, Launcher, LauncherError, PendingStep};
pub use repo::BenchmarkRepo;
pub use world::World;
