//! Benchmark repositories (paper §IV-A): "each benchmark in exaCB is
//! encapsulated in an individual Git repository, which serves as the
//! primary user-facing interface".
//!
//! A repository carries its benchmark definition (a JUBE-style script),
//! its CI configuration, optional platform configuration (e.g. launcher
//! selection for energy studies), and its own `exacb.data` branch.

use std::cell::RefCell;

use crate::ci::CiConfig;
use crate::harness::BenchmarkSpec;
use crate::store::{DataStore, Snapshot};
use crate::workloads::portfolio::Maturity;

/// One benchmark repository.
#[derive(Debug, Clone)]
pub struct BenchmarkRepo {
    pub name: String,
    /// Repository files: path -> content (`benchmark/jube/*.yml`,
    /// `.gitlab-ci.yml`, `platform.yml`, …).
    pub files: Vec<(String, String)>,
    /// The data branch (results live here, never in the source tree).
    pub store: DataStore,
    /// Incremental-adoption level (§VI-A).
    pub maturity: Maturity,
    /// Current HEAD commit hash of the source tree (provenance).
    pub commit: String,
    /// Lazily built, incrementally refreshed read-side view of the
    /// `exacb.data` branch (DESIGN.md §12). Interior-mutable so every
    /// reader — gates firing through the event loop, a-posteriori
    /// tables, audits — shares one snapshot and pays O(delta), not a
    /// full store re-walk, per access.
    snapshot: RefCell<Option<Snapshot>>,
}

impl BenchmarkRepo {
    pub fn new(name: &str) -> BenchmarkRepo {
        BenchmarkRepo {
            name: name.to_string(),
            files: Vec::new(),
            store: DataStore::new(),
            maturity: Maturity::Runnability,
            commit: crate::util::short_hash(name.as_bytes()),
            snapshot: RefCell::new(None),
        }
    }

    /// Run `f` against an up-to-date [`Snapshot`] of this repository's
    /// `exacb.data` branch: built O(history) on first use, then
    /// refreshed O(delta) (only commits newer than the snapshot's
    /// recorded head are consumed). `f` must not re-enter the snapshot
    /// of the same repository (interior mutability).
    pub fn with_snapshot<R>(&self, f: impl FnOnce(&Snapshot) -> R) -> R {
        let mut guard = self.snapshot.borrow_mut();
        match guard.as_mut() {
            Some(snap) => {
                snap.refresh(&self.store);
            }
            None => *guard = Some(Snapshot::build(&self.store, "exacb.data")),
        }
        f(guard.as_ref().expect("snapshot populated above"))
    }

    /// Incrementality counters of the cached snapshot:
    /// `(scratch builds, fresh commits consumed)`; `(0, 0)` before any
    /// reader touched it. Over append-only histories the first
    /// component stays 1 — the observable the O(delta) tests pin.
    pub fn snapshot_stats(&self) -> (usize, usize) {
        self.snapshot
            .borrow()
            .as_ref()
            .map(|s| (s.rebuilds(), s.commits_consumed()))
            .unwrap_or((0, 0))
    }

    pub fn with_file(mut self, path: &str, content: &str) -> BenchmarkRepo {
        self.files.push((path.to_string(), content.to_string()));
        self.commit = crate::util::short_hash(
            format!("{}{}", self.commit, content).as_bytes(),
        );
        self
    }

    pub fn with_maturity(mut self, m: Maturity) -> BenchmarkRepo {
        self.maturity = m;
        self
    }

    pub fn file(&self, path: &str) -> Option<&str> {
        self.files
            .iter()
            .find(|(p, _)| p == path)
            .map(|(_, c)| c.as_str())
    }

    /// Parse the repository's CI configuration (`.gitlab-ci.yml`).
    pub fn ci_config(&self) -> Result<CiConfig, String> {
        let text = self
            .file(".gitlab-ci.yml")
            .ok_or_else(|| format!("repo '{}': no .gitlab-ci.yml", self.name))?;
        CiConfig::parse(text).map_err(|e| format!("repo '{}': {e}", self.name))
    }

    /// Parse a benchmark definition by path (the `jube_file` input).
    pub fn benchmark_spec(&self, path: &str) -> Result<BenchmarkSpec, String> {
        let text = self
            .file(path)
            .ok_or_else(|| format!("repo '{}': no file '{path}'", self.name))?;
        BenchmarkSpec::parse(text).map_err(|e| format!("repo '{}': {e}", self.name))
    }

    /// Build the paper's §II example repository: the logmap benchmark
    /// with its JUBE script and CI pipeline.
    pub fn logmap_example(machine: &str, queue: &str) -> BenchmarkRepo {
        let jube = r#"
name: logmap
parametersets:
  - name: run
    parameters:
      - name: workload
        value: 2
      - name: workload
        values: [6]
        tag: large-workload
      - name: intensity
        value: 0.8
      - name: intensity
        values: [2.4]
        tag: large-intensity
      - name: nodes
        value: 1
      - name: nodes
        values: [1, 2, 4, 8, 16, 32]
        tag: scaling
steps:
  - name: compile
    do:
      - cmake -S . -B build -DPROJECT_FEATURE=feature
      - cmake --build build
      - cmake --install build --prefix /opt/logmap/
  - name: execute
    depends: [compile]
    use: [run]
    remote: true
    do:
      - logmap --workload $workload --intensity $intensity
analysis:
  - name: app_time
    file: logmap.out
    regex: "time: ([0-9.eE+-]+)"
    type: float
  - name: kernel_time
    file: logmap.stats
    regex: "kernel_time: ([0-9.eE+-]+)"
    type: float
"#;
        let ci = format!(
            r#"
include:
  - component: example/jube@v3.2
    inputs:
      prefix: "{machine}.logmap"
      variant: "large-intensity"
      usecase: "large-workload"
      machine: "{machine}"
      queue: "{queue}"
      project: "cjsc"
      budget: "zam"
      jube_file: "benchmark/jube/logmap.yml"
"#
        );
        BenchmarkRepo::new("logmap")
            .with_file("benchmark/jube/logmap.yml", jube)
            .with_file(".gitlab-ci.yml", &ci)
            .with_maturity(Maturity::Reproducibility)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logmap_example_parses_end_to_end() {
        let repo = BenchmarkRepo::logmap_example("jedi", "all");
        let cfg = repo.ci_config().unwrap();
        assert_eq!(cfg.invocations.len(), 1);
        let inputs = &cfg.invocations[0].inputs;
        assert_eq!(inputs.str_of("machine"), Some("jedi"));
        let spec = repo
            .benchmark_spec(inputs.str_of("jube_file").unwrap())
            .unwrap();
        assert_eq!(spec.name, "logmap");
        assert_eq!(spec.steps.len(), 2);
        assert!(spec.steps[1].remote);
    }

    #[test]
    fn tags_switch_parameters() {
        use crate::harness::expand_for_step;
        let repo = BenchmarkRepo::logmap_example("jedi", "all");
        let spec = repo.benchmark_spec("benchmark/jube/logmap.yml").unwrap();
        let base = expand_for_step(&spec, "execute", &[]);
        assert_eq!(base.len(), 1);
        assert_eq!(base[0]["workload"], "2");
        let tagged = expand_for_step(
            &spec,
            "execute",
            &["large-workload".into(), "large-intensity".into()],
        );
        assert_eq!(tagged[0]["workload"], "6");
        assert_eq!(tagged[0]["intensity"], "2.4");
        let scaling = expand_for_step(&spec, "execute", &["scaling".into()]);
        assert_eq!(scaling.len(), 6);
    }

    #[test]
    fn missing_files_error() {
        let repo = BenchmarkRepo::new("empty");
        assert!(repo.ci_config().is_err());
        assert!(repo.benchmark_spec("nope.yml").is_err());
    }

    #[test]
    fn with_snapshot_builds_once_then_refreshes_o_delta() {
        use crate::util::timeutil::SimTime;
        let mut repo = BenchmarkRepo::new("snap");
        repo.store.commit(
            "exacb.data",
            &[("a/1/report.json".into(), "{}".into())],
            "one",
            SimTime(0),
        );
        assert_eq!(repo.snapshot_stats(), (0, 0));
        assert_eq!(repo.with_snapshot(|s| s.path_count()), 1);
        assert_eq!(repo.snapshot_stats(), (1, 0));
        repo.store.commit(
            "exacb.data",
            &[("a/2/report.json".into(), "{}".into())],
            "two",
            SimTime(1),
        );
        assert_eq!(repo.with_snapshot(|s| s.path_count()), 2);
        // one scratch build ever; the second read consumed one commit
        assert_eq!(repo.snapshot_stats(), (1, 1));
    }

    #[test]
    fn commit_changes_with_content() {
        let a = BenchmarkRepo::new("r").with_file("f", "1");
        let b = BenchmarkRepo::new("r").with_file("f", "2");
        assert_ne!(a.commit, b.commit);
    }
}
