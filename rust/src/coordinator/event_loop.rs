//! The coordinator event loop: many in-flight pipelines on one shared
//! virtual timeline.
//!
//! The pre-event-loop coordinator ran each pipeline to completion before
//! the next started, so two applications on the same machine never
//! actually contended for nodes — the work queue only interleaved
//! *dispatch order*, never *timelines*. Here each pipeline is a
//! [`PipelineTask`]: a resumable state machine that advances its CI
//! invocations (execution stages drive a
//! [`crate::harness::RunCursor`] through [`super::execution::ExecutionTask`])
//! and *yields* whenever a remote step is submitted. [`drive`]
//! interleaves all tasks by repeatedly completing the **globally
//! earliest** batch-system event across all machines and waking the
//! pipeline that was waiting on the finished job. Queue waits, backfill,
//! and account-budget contention therefore emerge from the shared
//! timeline instead of being serialized away.
//!
//! Fleet-scale dispatch (DESIGN.md §5): [`drive`] is indexed, not
//! scanned. Waiting tasks live in a `(machine, jobid) → slot` map, the
//! next completion per machine sits in one lazily-validated min-heap,
//! and completions are observed through each batch system's event log —
//! so one event costs O(log n) bookkeeping instead of a rescan of every
//! task and every machine. The pre-index implementation is retained
//! verbatim as [`drive_reference`]: it is the executable specification
//! that the differential property tests replay campaigns against.
//!
//! Determinism: tasks are polled in creation order, machines are visited
//! in `BTreeMap` (name) order with event time as the primary key, and
//! each task carries its own PRNG stream (seeded per campaign item by
//! the caller), so a campaign's results are bit-reproducible and
//! independent of how the interleaving happens to schedule.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

use crate::ci::{CiJob, CiJobState, ComponentInvocation, Pipeline, Trigger};
use crate::util::prng::Prng;
use crate::util::timeutil::SimTime;

use super::execution::{ExecPoll, ExecutionParams, ExecutionTask};
use super::postproc;
use super::repo::BenchmarkRepo;
use super::world::World;

/// What a pipeline task is doing after a poll.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskPoll {
    /// Waiting for batch job `jobid` on `machine` to complete.
    Waiting { machine: String, jobid: u64 },
    /// All invocations ran; finish with [`PipelineTask::finish_into`].
    Done,
}

enum Started {
    /// The invocation is an execution orchestrator: runs resumably.
    Execution(Box<ExecutionTask>),
    /// The invocation completed synchronously (post-processing,
    /// validation failures, unknown components).
    Jobs(Vec<CiJob>),
}

/// One in-flight pipeline: owns its repository (checked out of
/// `world.repos` for the duration of the run) and the pipeline record
/// under construction.
pub struct PipelineTask {
    repo: BenchmarkRepo,
    pipeline: Pipeline,
    invocations: Vec<ComponentInvocation>,
    inv_idx: usize,
    exec: Option<Box<ExecutionTask>>,
    /// Per-pipeline noise stream. `None` uses the world PRNG, which
    /// reproduces the sequential dispatch behaviour exactly; concurrent
    /// campaigns install a per-item stream so results are independent of
    /// the interleaving.
    pub rng: Option<Prng>,
    waiting: Option<(String, u64)>,
    done: bool,
}

impl PipelineTask {
    /// Parse the repository's CI configuration and allocate the pipeline
    /// id. On a config error the repository is handed back so the caller
    /// can restore it into the world.
    pub(super) fn new(
        world: &mut World,
        repo: BenchmarkRepo,
        trigger: Trigger,
    ) -> Result<PipelineTask, (BenchmarkRepo, String)> {
        let config = match repo.ci_config() {
            Ok(c) => c,
            Err(e) => return Err((repo, e)),
        };
        let pipeline = Pipeline {
            id: world.ids.pipeline_id(),
            repo: repo.name.clone(),
            trigger,
            created: world.now(),
            jobs: Vec::new(),
        };
        Ok(PipelineTask {
            repo,
            pipeline,
            invocations: config.invocations,
            inv_idx: 0,
            exec: None,
            rng: None,
            waiting: None,
            done: false,
        })
    }

    pub fn pipeline_id(&self) -> u64 {
        self.pipeline.id
    }

    pub fn repo_name(&self) -> &str {
        &self.pipeline.repo
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// The (machine, jobid) this task is blocked on, if any.
    pub fn waiting_on(&self) -> Option<(&str, u64)> {
        self.waiting.as_ref().map(|(m, j)| (m.as_str(), *j))
    }

    /// Advance through invocations until the task blocks on a batch job
    /// or finishes. Pass the completed awaited jobid when resuming.
    ///
    /// The wake is the observability seam shared by [`drive`] and
    /// [`drive_reference`]: both drivers deliver each completed awaited
    /// job exactly once, so emitting here (stamped with the completed
    /// job's recorded end time — content, not dispatch order) keeps the
    /// trace identical across them.
    pub fn poll(&mut self, world: &mut World, mut completed: Option<u64>) -> TaskPoll {
        if let Some(jobid) = completed {
            crate::obs::count(crate::obs::Ctr::TaskWakes, 1);
            if crate::obs::tracing() {
                if let Some((machine, _)) = self.waiting.clone() {
                    let end = world
                        .batch
                        .get(&machine)
                        .and_then(|b| b.record(jobid))
                        .and_then(|r| r.end_time);
                    if let Some(ts) = end {
                        crate::obs::trace::instant(
                            &machine,
                            "wake",
                            ts,
                            crate::obs::trace::args(&[
                                ("pipeline", self.pipeline.id.to_string()),
                                ("jobid", jobid.to_string()),
                            ]),
                        );
                    }
                }
            }
        }
        loop {
            if let Some(exec) = self.exec.as_mut() {
                match exec.poll(world, &mut self.repo, self.rng.as_mut(), completed.take()) {
                    ExecPoll::Waiting { machine, jobid } => {
                        self.waiting = Some((machine.clone(), jobid));
                        return TaskPoll::Waiting { machine, jobid };
                    }
                    ExecPoll::Done => {
                        self.waiting = None;
                        let finished = self.exec.take().expect("just polled");
                        let (jobs, _report) = finished.into_result();
                        self.pipeline.jobs.extend(jobs);
                        self.inv_idx += 1;
                    }
                }
                continue;
            }
            if self.inv_idx >= self.invocations.len() {
                self.done = true;
                return TaskPoll::Done;
            }
            let invocation = self.invocations[self.inv_idx].clone();
            match self.start_invocation(world, &invocation) {
                Started::Execution(task) => self.exec = Some(task),
                Started::Jobs(jobs) => {
                    self.pipeline.jobs.extend(jobs);
                    self.inv_idx += 1;
                }
            }
        }
    }

    /// Validate one component invocation against the catalog and route
    /// it: execution components become resumable tasks, post-processing
    /// components run synchronously (they read recorded reports and
    /// never touch the batch system).
    fn start_invocation(
        &mut self,
        world: &mut World,
        invocation: &ComponentInvocation,
    ) -> Started {
        let component = invocation.component.as_str();
        fn validate_failure(world: &mut World, component: &str, err: &str) -> Started {
            let mut job = CiJob::new(world.ids.job_id(), &format!("{component}.validate"));
            job.log_line(format!("input validation failed: {err}"));
            job.state = CiJobState::Failed;
            Started::Jobs(vec![job])
        }
        // input validation against the component schema
        let resolved = match world
            .registry
            .get(component)
            .and_then(|spec| spec.resolve(&invocation.inputs))
        {
            Ok(r) => r,
            Err(e) => return validate_failure(world, component, &e.to_string()),
        };
        match component {
            "execution@v3" | "example/jube@v3.2" | "feature-injection@v3" => {
                match ExecutionParams::from_inputs(&resolved) {
                    Ok(params) => Started::Execution(Box::new(ExecutionTask::new(
                        params,
                        self.pipeline.id,
                    ))),
                    Err(e) => validate_failure(world, component, &e),
                }
            }
            "jureap/energy@v3" => Started::Jobs(postproc::run_energy_study(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
            )),
            // the concurrent energy sweep (DESIGN.md §11): every
            // frequency point is a fresh execution task interleaved on
            // the shared batch timeline, cache stashed — measurement
            // runs need fresh noise, like the regression gate
            "energy-sweep@v1" => Started::Jobs(crate::energy::study::run_energy_sweep(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
            )),
            "machine-comparison@v3" => Started::Jobs(vec![
                postproc::run_machine_comparison(world, &self.repo, &resolved),
            ]),
            "scalability@v3" => {
                Started::Jobs(vec![postproc::run_scalability(world, &self.repo, &resolved)])
            }
            "time-series@v3" => {
                Started::Jobs(vec![postproc::run_time_series(world, &self.repo, &resolved)])
            }
            // the tracking gate (DESIGN.md §9): reads recorded history,
            // adaptively schedules extra repetition runs through the
            // batch system's discrete-event API, passes/fails the
            // pipeline with a regressions.json sidecar
            "regression-check@v1" => Started::Jobs(crate::tracking::run_regression_gate(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
                self.rng.as_mut(),
            )),
            // the maturity gate (DESIGN.md §10): reads recorded
            // evidence, blocks or grants ladder promotion, re-levels
            // the repository, emits the maturity.json sidecar
            "maturity-check@v1" => Started::Jobs(crate::maturity::run_maturity_gate(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
            )),
            other => {
                let mut job =
                    CiJob::new(world.ids.job_id(), &format!("{other}.dispatch"));
                job.log_line(format!(
                    "component '{other}' validated but has no orchestrator"
                ));
                job.state = CiJobState::Failed;
                Started::Jobs(vec![job])
            }
        }
    }

    /// Fail the in-flight execution (if any) and mark the task done.
    fn give_up(&mut self, reason: &str) {
        if let Some(mut exec) = self.exec.take() {
            exec.abort(reason);
            let (jobs, _) = exec.into_result();
            self.pipeline.jobs.extend(jobs);
        }
        self.waiting = None;
        self.done = true;
    }

    /// Return the finished pipeline to the world: the pipeline record is
    /// appended and the repository restored.
    pub fn finish_into(self, world: &mut World) {
        world.record_pipeline(self.pipeline);
        world.repos.insert(self.repo.name.clone(), self.repo);
    }
}

/// Indexed dispatch state for [`drive`]. Tasks sit in fixed slots (their
/// creation order — the polling priority); everything else is an index
/// over those slots.
struct Dispatcher {
    /// Machine names in `world.batch` (BTreeMap) order; the index into
    /// this Vec is the machine id used by every other structure. Name
    /// order makes index order reproduce the old `(time, name)` min —
    /// no per-candidate `String` clones on the hot path.
    machine_names: Vec<String>,
    machine_index: HashMap<String, usize>,
    /// `None` = retired. Retirement keeps slots stable (no shifting
    /// `Vec::remove`), and retiring a task the moment it finishes keeps
    /// `world.pipelines` in the same order the reference scan produces.
    slots: Vec<Option<PipelineTask>>,
    live: usize,
    /// `(machine id, jobid) → slot` for every blocked task: completion →
    /// waiter in O(1) instead of a scan over all tasks.
    waiters: HashMap<(usize, u64), usize>,
    /// Slots ready to resume, keyed by slot so one pass polls them in
    /// task order (the reference's sweep order).
    wakes: BTreeMap<usize, u64>,
    /// Next-completion candidates per machine, min-first. Entries are
    /// validated lazily against `peek_next_event` when popped, so stale
    /// times (the machine advanced, or a shorter job arrived) cost one
    /// re-push instead of an eager rebuild.
    events: BinaryHeap<Reverse<(SimTime, usize)>>,
}

impl Dispatcher {
    /// Poll one slot (optionally delivering a completed jobid), index
    /// the resulting wait or retire the finished task, then absorb any
    /// completions the poll itself triggered (gates run jobs on other
    /// machines inside a poll).
    fn poll_slot(&mut self, world: &mut World, slot: usize, completed: Option<u64>) {
        let Some(mut task) = self.slots[slot].take() else {
            return;
        };
        match task.poll(world, completed) {
            TaskPoll::Done => {
                task.finish_into(world);
                self.live -= 1;
            }
            TaskPoll::Waiting { machine, jobid } => {
                let terminal = world
                    .batch
                    .get(&machine)
                    .and_then(|b| b.job_state(jobid))
                    .map(|s| s.is_terminal())
                    // an unknown job can never complete; waking the task
                    // collects a failed outcome instead of hanging
                    .unwrap_or(true);
                if terminal {
                    self.wakes.insert(slot, jobid);
                } else {
                    let mi = self.machine_index[&machine];
                    self.waiters.insert((mi, jobid), slot);
                    // the submission may have become this machine's next
                    // event — record the current candidate
                    if let Some(t) = world.batch[&machine].peek_next_event() {
                        self.events.push(Reverse((t, mi)));
                    }
                }
                self.slots[slot] = Some(task);
            }
        }
        self.drain_logs(world);
    }

    /// Drain every machine's completion log into wakes. Completions with
    /// no registered waiter are jobs a gate drove to completion inside
    /// its own poll — no pipeline blocks on those, so they are dropped.
    fn drain_logs(&mut self, world: &mut World) {
        for (mi, name) in self.machine_names.iter().enumerate() {
            let Some(bs) = world.batch.get_mut(name) else {
                continue;
            };
            let done = bs.drain_event_log();
            if done.is_empty() {
                continue;
            }
            // the machine's timeline moved; record its new candidate
            if let Some(t) = bs.peek_next_event() {
                self.events.push(Reverse((t, mi)));
            }
            for jobid in done {
                if let Some(slot) = self.waiters.remove(&(mi, jobid)) {
                    self.wakes.insert(slot, jobid);
                }
            }
        }
    }
}

/// Drive a set of pipeline tasks to completion on the shared timeline.
///
/// All tasks are first polled to their initial yield (so every pipeline
/// submits its head-of-line batch job before any simulated time passes —
/// this is what makes same-trigger pipelines contend). The loop then
/// repeatedly completes the globally earliest scheduler event across all
/// machines and resumes whichever task was waiting on the finished job.
/// Returns the pipeline ids in task order; the finished pipelines land
/// in `world.pipelines` and every repository is restored to
/// `world.repos`.
///
/// Dispatch is indexed end to end — waiter map, per-machine event heap,
/// per-machine completion logs — so cost per event is O(log n), and a
/// no-gate campaign replays byte-identical to [`drive_reference`] (the
/// `integration_dispatch_diff` differential property test holds this
/// contract).
pub fn drive(world: &mut World, tasks: Vec<PipelineTask>) -> Vec<u64> {
    let pids: Vec<u64> = tasks.iter().map(|t| t.pipeline_id()).collect();
    if tasks.is_empty() {
        return pids;
    }
    let machine_names: Vec<String> = world.batch.keys().cloned().collect();
    let machine_index: HashMap<String, usize> = machine_names
        .iter()
        .enumerate()
        .map(|(i, n)| (n.clone(), i))
        .collect();
    // observe completions wherever they happen (including inside gate
    // polls); remember each machine's prior log state to restore at exit
    let prior_log: Vec<bool> = machine_names
        .iter()
        .map(|n| world.batch.get_mut(n).expect("listed machine").set_event_log(true))
        .collect();
    let live = tasks.len();
    let mut d = Dispatcher {
        machine_names,
        machine_index,
        slots: tasks.into_iter().map(Some).collect(),
        live,
        waiters: HashMap::new(),
        wakes: BTreeMap::new(),
        events: BinaryHeap::new(),
    };
    for slot in 0..d.slots.len() {
        d.poll_slot(world, slot, None);
    }
    'outer: while d.live > 0 {
        // Wake pass: resume ready tasks in slot order. A wake landing at
        // or below the cursor (triggered by a poll later in the pass)
        // defers to the next pass — exactly the reference scan's
        // restart-the-sweep semantics.
        while !d.wakes.is_empty() {
            let mut cursor = 0;
            loop {
                let Some((&slot, &jobid)) = d.wakes.range(cursor..).next() else {
                    break;
                };
                d.wakes.remove(&slot);
                cursor = slot + 1;
                d.poll_slot(world, slot, Some(jobid));
            }
        }
        if d.live == 0 {
            break;
        }
        // Advance the globally earliest completion event. Heap entries
        // are validated against the machine's actual next event: stale
        // entries re-queue the truth, idle machines drop out, and an
        // empty heap earns one rebuild from scratch before giving up.
        let mut advanced = false;
        let mut rebuilt = false;
        loop {
            let Some(&Reverse((t, mi))) = d.events.peek() else {
                if rebuilt {
                    break;
                }
                rebuilt = true;
                for (mi, name) in d.machine_names.iter().enumerate() {
                    if let Some(t) = world.batch[name].peek_next_event() {
                        d.events.push(Reverse((t, mi)));
                    }
                }
                continue;
            };
            d.events.pop();
            let name = &d.machine_names[mi];
            match world.batch[name].peek_next_event() {
                Some(actual) if actual == t => {
                    world
                        .batch
                        .get_mut(name)
                        .and_then(|b| b.advance_next_event());
                    if let Some(nt) = world.batch[name].peek_next_event() {
                        d.events.push(Reverse((nt, mi)));
                    }
                    d.drain_logs(world);
                    advanced = true;
                    break;
                }
                Some(actual) => {
                    // the machine's timeline moved under this entry (it
                    // advanced, or a shorter job arrived) — requeue the
                    // current candidate and try again
                    d.events.push(Reverse((actual, mi)));
                }
                None => {} // machine went idle; drop the entry
            }
        }
        if advanced {
            continue 'outer;
        }
        // No validatable event anywhere. Resume any task whose awaited
        // job is already terminal (e.g. completed incidentally by a
        // clock advance outside our logs) before declaring a stall.
        let mut woke = false;
        for slot in 0..d.slots.len() {
            let Some(task) = d.slots[slot].as_ref() else {
                continue;
            };
            let Some((machine, jobid)) = task.waiting_on() else {
                continue;
            };
            let terminal = world
                .batch
                .get(machine)
                .and_then(|b| b.job_state(jobid))
                .map(|s| s.is_terminal())
                .unwrap_or(true);
            if terminal {
                if let Some(&mi) = d.machine_index.get(machine) {
                    d.waiters.remove(&(mi, jobid));
                }
                d.wakes.insert(slot, jobid);
                woke = true;
            }
        }
        if woke {
            continue 'outer;
        }
        // no running job anywhere, yet tasks are still waiting: the
        // awaited jobs can never complete — fail loudly, don't spin
        for slot in 0..d.slots.len() {
            if let Some(mut task) = d.slots[slot].take() {
                task.give_up("event loop stalled: awaited job never completes");
                task.finish_into(world);
                d.live -= 1;
            }
        }
        break;
    }
    for (name, was) in d.machine_names.iter().zip(prior_log) {
        if let Some(bs) = world.batch.get_mut(name) {
            bs.set_event_log(was);
        }
    }
    pids
}

/// Retire every finished task into the world.
fn finalize_done(world: &mut World, tasks: &mut Vec<PipelineTask>) {
    let mut i = 0;
    while i < tasks.len() {
        if tasks[i].is_done() {
            tasks.remove(i).finish_into(world);
        } else {
            i += 1;
        }
    }
}

/// The pre-index event loop, retained verbatim as the executable
/// specification of dispatch semantics: full task rescans, min-over-
/// machines with name clones, linear waiter search. O(tasks × machines)
/// per event — fine at 24 apps, quadratic at fleet scale — but obviously
/// correct, which is its job: the `integration_dispatch_diff` property
/// test replays campaigns through both loops and requires byte-identical
/// worlds. Do not "optimize" this function; that is what [`drive`] is for.
pub fn drive_reference(world: &mut World, mut tasks: Vec<PipelineTask>) -> Vec<u64> {
    let pids: Vec<u64> = tasks.iter().map(|t| t.pipeline_id()).collect();
    for task in tasks.iter_mut() {
        if !task.is_done() && task.waiting.is_none() {
            task.poll(world, None);
        }
    }
    finalize_done(world, &mut tasks);
    while !tasks.is_empty() {
        // resume any task whose awaited job is already terminal (e.g.
        // completed incidentally by a clock advance elsewhere)
        let mut resumed = false;
        for task in tasks.iter_mut() {
            let Some((machine, jobid)) = task.waiting.clone() else {
                continue;
            };
            let terminal = world
                .batch
                .get(&machine)
                .and_then(|b| b.job_state(jobid))
                .map(|s| s.is_terminal())
                // an unknown job can never complete; resuming collects a
                // failed outcome instead of hanging the loop
                .unwrap_or(true);
            if terminal {
                task.poll(world, Some(jobid));
                resumed = true;
            }
        }
        finalize_done(world, &mut tasks);
        if tasks.is_empty() {
            break;
        }
        if resumed {
            continue;
        }
        // the global virtual clock: the earliest next completion event
        // over all machines (ties broken by machine name — BTreeMap
        // iteration keeps this deterministic)
        let next = world
            .batch
            .iter()
            .filter_map(|(name, bs)| bs.peek_next_event().map(|t| (t, name.clone())))
            .min();
        let Some((_, machine)) = next else {
            // no running job anywhere, yet tasks are still waiting: the
            // awaited jobs can never complete — fail loudly, don't spin
            for task in tasks.iter_mut() {
                task.give_up("event loop stalled: awaited job never completes");
            }
            finalize_done(world, &mut tasks);
            break;
        };
        let completed = world
            .batch
            .get_mut(&machine)
            .and_then(|b| b.advance_next_event());
        if let Some(jobid) = completed {
            for task in tasks.iter_mut() {
                let waits_here = task
                    .waiting
                    .as_ref()
                    .map(|(m, j)| m == &machine && *j == jobid)
                    .unwrap_or(false);
                if waits_here {
                    task.poll(world, Some(jobid));
                }
            }
            finalize_done(world, &mut tasks);
        }
    }
    pids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timeutil::SimTime;

    fn app_repo(name: &str, machine: &str, nodes: u64) -> BenchmarkRepo {
        let jube = format!(
            "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: {nodes}\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name {name} --flops 200000 --steps 50\n",
        );
        let ci = format!(
            "include:\n  - component: execution@v3\n    inputs:\n      prefix: \"{machine}.{name}\"\n      machine: \"{machine}\"\n      queue: \"all\"\n      project: \"cjsc\"\n      budget: \"zam\"\n      jube_file: \"b.yml\"\n"
        );
        BenchmarkRepo::new(name)
            .with_file("b.yml", &jube)
            .with_file(".gitlab-ci.yml", &ci)
    }

    #[test]
    fn concurrent_pipelines_share_the_timeline() {
        // jedi's "all" partition has 48 nodes; four 16-node pipelines
        // submitted at the same trigger cannot all start at once.
        let mut world = World::new(7);
        world.advance_to(SimTime::from_days(1));
        let names = ["app-a", "app-b", "app-c", "app-d"];
        for n in &names {
            world.add_repo(app_repo(n, "jedi", 16));
        }
        let mut tasks = Vec::new();
        for n in &names {
            tasks.push(world.begin_pipeline(n, Trigger::Scheduled).unwrap());
        }
        let pids = drive(&mut world, tasks);
        assert_eq!(pids.len(), 4);
        for pid in &pids {
            assert!(world.pipeline(*pid).unwrap().succeeded());
        }
        // every repo was restored
        for n in &names {
            assert!(world.repo(n).is_some(), "{n}");
        }
        // contention is real: 4x16 nodes on a 48-node partition means at
        // least one job waited for another to finish, beyond the fixed
        // scheduler latency
        let bs = world.batch.get("jedi").unwrap();
        let latency = bs.sched_latency_s;
        let max_wait = bs
            .records()
            .iter()
            .filter_map(|r| r.queue_wait_s())
            .max()
            .unwrap();
        assert!(
            max_wait > latency,
            "expected a real queue wait, max was {max_wait}s"
        );
        // all submissions happened at the shared trigger instant
        let submits: Vec<i64> = bs.records().iter().map(|r| r.submit_time.0).collect();
        assert!(submits.windows(2).all(|w| w[0] == w[1]), "{submits:?}");
    }

    #[test]
    fn drive_on_empty_task_list_is_a_noop() {
        let mut world = World::new(1);
        assert!(drive(&mut world, Vec::new()).is_empty());
        assert!(drive_reference(&mut world, Vec::new()).is_empty());
    }

    #[test]
    fn config_error_restores_repo() {
        let mut world = World::new(3);
        world.add_repo(BenchmarkRepo::new("broken").with_file(".gitlab-ci.yml", "stages: [x]\n"));
        assert!(world.begin_pipeline("broken", Trigger::Manual).is_err());
        assert!(world.repo("broken").is_some());
    }

    /// The indexed loop and the reference scan must build identical
    /// worlds from an identical contended campaign (the full differential
    /// property lives in `tests/integration_dispatch_diff.rs`).
    #[test]
    fn indexed_drive_matches_reference_scan() {
        let run = |f: fn(&mut World, Vec<PipelineTask>) -> Vec<u64>| {
            let mut world = World::new(7);
            world.advance_to(SimTime::from_days(1));
            let names = ["app-a", "app-b", "app-c", "app-d"];
            for n in &names {
                world.add_repo(app_repo(n, "jedi", 16));
            }
            let mut tasks = Vec::new();
            for n in &names {
                tasks.push(world.begin_pipeline(n, Trigger::Scheduled).unwrap());
            }
            let pids = f(&mut world, tasks);
            let sacct: Vec<String> = world
                .batch
                .get("jedi")
                .unwrap()
                .records_iter()
                .map(|r| {
                    format!(
                        "{} {} {:?} {:?} {:?}",
                        r.jobid,
                        r.state.name(),
                        r.submit_time,
                        r.start_time,
                        r.end_time
                    )
                })
                .collect();
            let order: Vec<u64> = world.pipelines.iter().map(|p| p.id).collect();
            (pids, sacct, order)
        };
        assert_eq!(run(drive), run(drive_reference));
    }
}
