//! The coordinator event loop: many in-flight pipelines on one shared
//! virtual timeline.
//!
//! The pre-event-loop coordinator ran each pipeline to completion before
//! the next started, so two applications on the same machine never
//! actually contended for nodes — the work queue only interleaved
//! *dispatch order*, never *timelines*. Here each pipeline is a
//! [`PipelineTask`]: a resumable state machine that advances its CI
//! invocations (execution stages drive a
//! [`crate::harness::RunCursor`] through [`super::execution::ExecutionTask`])
//! and *yields* whenever a remote step is submitted. [`drive`]
//! interleaves all tasks by repeatedly completing the **globally
//! earliest** batch-system event across all machines and waking the
//! pipeline that was waiting on the finished job. Queue waits, backfill,
//! and account-budget contention therefore emerge from the shared
//! timeline instead of being serialized away.
//!
//! Determinism: tasks are polled in creation order, machines are visited
//! in `BTreeMap` (name) order with event time as the primary key, and
//! each task carries its own PRNG stream (seeded per campaign item by
//! the caller), so a campaign's results are bit-reproducible and
//! independent of how the interleaving happens to schedule.

use crate::ci::{CiJob, CiJobState, ComponentInvocation, Pipeline, Trigger};
use crate::util::prng::Prng;

use super::execution::{ExecPoll, ExecutionParams, ExecutionTask};
use super::postproc;
use super::repo::BenchmarkRepo;
use super::world::World;

/// What a pipeline task is doing after a poll.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskPoll {
    /// Waiting for batch job `jobid` on `machine` to complete.
    Waiting { machine: String, jobid: u64 },
    /// All invocations ran; finish with [`PipelineTask::finish_into`].
    Done,
}

enum Started {
    /// The invocation is an execution orchestrator: runs resumably.
    Execution(Box<ExecutionTask>),
    /// The invocation completed synchronously (post-processing,
    /// validation failures, unknown components).
    Jobs(Vec<CiJob>),
}

/// One in-flight pipeline: owns its repository (checked out of
/// `world.repos` for the duration of the run) and the pipeline record
/// under construction.
pub struct PipelineTask {
    repo: BenchmarkRepo,
    pipeline: Pipeline,
    invocations: Vec<ComponentInvocation>,
    inv_idx: usize,
    exec: Option<Box<ExecutionTask>>,
    /// Per-pipeline noise stream. `None` uses the world PRNG, which
    /// reproduces the sequential dispatch behaviour exactly; concurrent
    /// campaigns install a per-item stream so results are independent of
    /// the interleaving.
    pub rng: Option<Prng>,
    waiting: Option<(String, u64)>,
    done: bool,
}

impl PipelineTask {
    /// Parse the repository's CI configuration and allocate the pipeline
    /// id. On a config error the repository is handed back so the caller
    /// can restore it into the world.
    pub(super) fn new(
        world: &mut World,
        repo: BenchmarkRepo,
        trigger: Trigger,
    ) -> Result<PipelineTask, (BenchmarkRepo, String)> {
        let config = match repo.ci_config() {
            Ok(c) => c,
            Err(e) => return Err((repo, e)),
        };
        let pipeline = Pipeline {
            id: world.ids.pipeline_id(),
            repo: repo.name.clone(),
            trigger,
            created: world.now(),
            jobs: Vec::new(),
        };
        Ok(PipelineTask {
            repo,
            pipeline,
            invocations: config.invocations,
            inv_idx: 0,
            exec: None,
            rng: None,
            waiting: None,
            done: false,
        })
    }

    pub fn pipeline_id(&self) -> u64 {
        self.pipeline.id
    }

    pub fn repo_name(&self) -> &str {
        &self.pipeline.repo
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// The (machine, jobid) this task is blocked on, if any.
    pub fn waiting_on(&self) -> Option<(&str, u64)> {
        self.waiting.as_ref().map(|(m, j)| (m.as_str(), *j))
    }

    /// Advance through invocations until the task blocks on a batch job
    /// or finishes. Pass the completed awaited jobid when resuming.
    pub fn poll(&mut self, world: &mut World, mut completed: Option<u64>) -> TaskPoll {
        loop {
            if let Some(exec) = self.exec.as_mut() {
                match exec.poll(world, &mut self.repo, self.rng.as_mut(), completed.take()) {
                    ExecPoll::Waiting { machine, jobid } => {
                        self.waiting = Some((machine.clone(), jobid));
                        return TaskPoll::Waiting { machine, jobid };
                    }
                    ExecPoll::Done => {
                        self.waiting = None;
                        let finished = self.exec.take().expect("just polled");
                        let (jobs, _report) = finished.into_result();
                        self.pipeline.jobs.extend(jobs);
                        self.inv_idx += 1;
                    }
                }
                continue;
            }
            if self.inv_idx >= self.invocations.len() {
                self.done = true;
                return TaskPoll::Done;
            }
            let invocation = self.invocations[self.inv_idx].clone();
            match self.start_invocation(world, &invocation) {
                Started::Execution(task) => self.exec = Some(task),
                Started::Jobs(jobs) => {
                    self.pipeline.jobs.extend(jobs);
                    self.inv_idx += 1;
                }
            }
        }
    }

    /// Validate one component invocation against the catalog and route
    /// it: execution components become resumable tasks, post-processing
    /// components run synchronously (they read recorded reports and
    /// never touch the batch system).
    fn start_invocation(
        &mut self,
        world: &mut World,
        invocation: &ComponentInvocation,
    ) -> Started {
        let component = invocation.component.as_str();
        fn validate_failure(world: &mut World, component: &str, err: &str) -> Started {
            let mut job = CiJob::new(world.ids.job_id(), &format!("{component}.validate"));
            job.log_line(format!("input validation failed: {err}"));
            job.state = CiJobState::Failed;
            Started::Jobs(vec![job])
        }
        // input validation against the component schema
        let resolved = match world
            .registry
            .get(component)
            .and_then(|spec| spec.resolve(&invocation.inputs))
        {
            Ok(r) => r,
            Err(e) => return validate_failure(world, component, &e.to_string()),
        };
        match component {
            "execution@v3" | "example/jube@v3.2" | "feature-injection@v3" => {
                match ExecutionParams::from_inputs(&resolved) {
                    Ok(params) => Started::Execution(Box::new(ExecutionTask::new(
                        params,
                        self.pipeline.id,
                    ))),
                    Err(e) => validate_failure(world, component, &e),
                }
            }
            "jureap/energy@v3" => Started::Jobs(postproc::run_energy_study(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
            )),
            // the concurrent energy sweep (DESIGN.md §11): every
            // frequency point is a fresh execution task interleaved on
            // the shared batch timeline, cache stashed — measurement
            // runs need fresh noise, like the regression gate
            "energy-sweep@v1" => Started::Jobs(crate::energy::study::run_energy_sweep(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
            )),
            "machine-comparison@v3" => Started::Jobs(vec![
                postproc::run_machine_comparison(world, &self.repo, &resolved),
            ]),
            "scalability@v3" => {
                Started::Jobs(vec![postproc::run_scalability(world, &self.repo, &resolved)])
            }
            "time-series@v3" => {
                Started::Jobs(vec![postproc::run_time_series(world, &self.repo, &resolved)])
            }
            // the tracking gate (DESIGN.md §9): reads recorded history,
            // adaptively schedules extra repetition runs through the
            // batch system's discrete-event API, passes/fails the
            // pipeline with a regressions.json sidecar
            "regression-check@v1" => Started::Jobs(crate::tracking::run_regression_gate(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
                self.rng.as_mut(),
            )),
            // the maturity gate (DESIGN.md §10): reads recorded
            // evidence, blocks or grants ladder promotion, re-levels
            // the repository, emits the maturity.json sidecar
            "maturity-check@v1" => Started::Jobs(crate::maturity::run_maturity_gate(
                world,
                &mut self.repo,
                &resolved,
                self.pipeline.id,
            )),
            other => {
                let mut job =
                    CiJob::new(world.ids.job_id(), &format!("{other}.dispatch"));
                job.log_line(format!(
                    "component '{other}' validated but has no orchestrator"
                ));
                job.state = CiJobState::Failed;
                Started::Jobs(vec![job])
            }
        }
    }

    /// Fail the in-flight execution (if any) and mark the task done.
    fn give_up(&mut self, reason: &str) {
        if let Some(mut exec) = self.exec.take() {
            exec.abort(reason);
            let (jobs, _) = exec.into_result();
            self.pipeline.jobs.extend(jobs);
        }
        self.waiting = None;
        self.done = true;
    }

    /// Return the finished pipeline to the world: the pipeline record is
    /// appended and the repository restored.
    pub fn finish_into(self, world: &mut World) {
        world.pipelines.push(self.pipeline);
        world.repos.insert(self.repo.name.clone(), self.repo);
    }
}

/// Retire every finished task into the world.
fn finalize_done(world: &mut World, tasks: &mut Vec<PipelineTask>) {
    let mut i = 0;
    while i < tasks.len() {
        if tasks[i].is_done() {
            tasks.remove(i).finish_into(world);
        } else {
            i += 1;
        }
    }
}

/// Drive a set of pipeline tasks to completion on the shared timeline.
///
/// All tasks are first polled to their initial yield (so every pipeline
/// submits its head-of-line batch job before any simulated time passes —
/// this is what makes same-trigger pipelines contend). The loop then
/// repeatedly completes the globally earliest scheduler event across all
/// machines and resumes whichever task was waiting on the finished job.
/// Returns the pipeline ids in task order; the finished pipelines land
/// in `world.pipelines` and every repository is restored to
/// `world.repos`.
pub fn drive(world: &mut World, mut tasks: Vec<PipelineTask>) -> Vec<u64> {
    let pids: Vec<u64> = tasks.iter().map(|t| t.pipeline_id()).collect();
    for task in tasks.iter_mut() {
        if !task.is_done() && task.waiting.is_none() {
            task.poll(world, None);
        }
    }
    finalize_done(world, &mut tasks);
    while !tasks.is_empty() {
        // resume any task whose awaited job is already terminal (e.g.
        // completed incidentally by a clock advance elsewhere)
        let mut resumed = false;
        for task in tasks.iter_mut() {
            let Some((machine, jobid)) = task.waiting.clone() else {
                continue;
            };
            let terminal = world
                .batch
                .get(&machine)
                .and_then(|b| b.job_state(jobid))
                .map(|s| s.is_terminal())
                // an unknown job can never complete; resuming collects a
                // failed outcome instead of hanging the loop
                .unwrap_or(true);
            if terminal {
                task.poll(world, Some(jobid));
                resumed = true;
            }
        }
        finalize_done(world, &mut tasks);
        if tasks.is_empty() {
            break;
        }
        if resumed {
            continue;
        }
        // the global virtual clock: the earliest next completion event
        // over all machines (ties broken by machine name — BTreeMap
        // iteration keeps this deterministic)
        let next = world
            .batch
            .iter()
            .filter_map(|(name, bs)| bs.peek_next_event().map(|t| (t, name.clone())))
            .min();
        let Some((_, machine)) = next else {
            // no running job anywhere, yet tasks are still waiting: the
            // awaited jobs can never complete — fail loudly, don't spin
            for task in tasks.iter_mut() {
                task.give_up("event loop stalled: awaited job never completes");
            }
            finalize_done(world, &mut tasks);
            break;
        };
        let completed = world
            .batch
            .get_mut(&machine)
            .and_then(|b| b.advance_next_event());
        if let Some(jobid) = completed {
            for task in tasks.iter_mut() {
                let waits_here = task
                    .waiting
                    .as_ref()
                    .map(|(m, j)| m == &machine && *j == jobid)
                    .unwrap_or(false);
                if waits_here {
                    task.poll(world, Some(jobid));
                }
            }
            finalize_done(world, &mut tasks);
        }
    }
    pids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timeutil::SimTime;

    fn app_repo(name: &str, machine: &str, nodes: u64) -> BenchmarkRepo {
        let jube = format!(
            "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: {nodes}\nsteps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n      - simapp --name {name} --flops 200000 --steps 50\n",
        );
        let ci = format!(
            "include:\n  - component: execution@v3\n    inputs:\n      prefix: \"{machine}.{name}\"\n      machine: \"{machine}\"\n      queue: \"all\"\n      project: \"cjsc\"\n      budget: \"zam\"\n      jube_file: \"b.yml\"\n"
        );
        BenchmarkRepo::new(name)
            .with_file("b.yml", &jube)
            .with_file(".gitlab-ci.yml", &ci)
    }

    #[test]
    fn concurrent_pipelines_share_the_timeline() {
        // jedi's "all" partition has 48 nodes; four 16-node pipelines
        // submitted at the same trigger cannot all start at once.
        let mut world = World::new(7);
        world.advance_to(SimTime::from_days(1));
        let names = ["app-a", "app-b", "app-c", "app-d"];
        for n in &names {
            world.add_repo(app_repo(n, "jedi", 16));
        }
        let mut tasks = Vec::new();
        for n in &names {
            tasks.push(world.begin_pipeline(n, Trigger::Scheduled).unwrap());
        }
        let pids = drive(&mut world, tasks);
        assert_eq!(pids.len(), 4);
        for pid in &pids {
            assert!(world.pipeline(*pid).unwrap().succeeded());
        }
        // every repo was restored
        for n in &names {
            assert!(world.repo(n).is_some(), "{n}");
        }
        // contention is real: 4x16 nodes on a 48-node partition means at
        // least one job waited for another to finish, beyond the fixed
        // scheduler latency
        let bs = world.batch.get("jedi").unwrap();
        let latency = bs.sched_latency_s;
        let max_wait = bs
            .records()
            .iter()
            .filter_map(|r| r.queue_wait_s())
            .max()
            .unwrap();
        assert!(
            max_wait > latency,
            "expected a real queue wait, max was {max_wait}s"
        );
        // all submissions happened at the shared trigger instant
        let submits: Vec<i64> = bs.records().iter().map(|r| r.submit_time.0).collect();
        assert!(submits.windows(2).all(|w| w[0] == w[1]), "{submits:?}");
    }

    #[test]
    fn drive_on_empty_task_list_is_a_noop() {
        let mut world = World::new(1);
        assert!(drive(&mut world, Vec::new()).is_empty());
    }

    #[test]
    fn config_error_restores_repo() {
        let mut world = World::new(3);
        world.add_repo(BenchmarkRepo::new("broken").with_file(".gitlab-ci.yml", "stages: [x]\n"));
        assert!(world.begin_pipeline("broken", Trigger::Manual).is_err());
        assert!(world.repo("broken").is_some());
    }
}
