//! The simulation world: machines + batch systems + repositories +
//! shared framework state. Pipelines run through the discrete-event
//! core (`coordinator::event_loop`): [`World::run_pipeline`] drives one
//! pipeline to completion, [`World::begin_pipeline`] starts a resumable
//! task so many pipelines can share the timeline.

use std::collections::{BTreeMap, HashMap};

use crate::ci::{ComponentRegistry, IdAllocator, Pipeline, Trigger};
use crate::cluster::Cluster;
use crate::runtime::Engine;
use crate::scheduler::{for_machine, AccountManager, BatchSystem};
use crate::store::{CacheStats, ExecutionCache, ObjectStore};
use crate::util::prng::Prng;
use crate::util::timeutil::SimTime;
use crate::workloads::HostCalibration;

use super::event_loop::{self, PipelineTask};
use super::repo::BenchmarkRepo;

/// Everything a deployment of exaCB talks to.
pub struct World {
    pub cluster: Cluster,
    pub batch: BTreeMap<String, BatchSystem>,
    pub repos: BTreeMap<String, BenchmarkRepo>,
    pub registry: ComponentRegistry,
    pub ids: IdAllocator,
    pub rng: Prng,
    pub seed: u64,
    pub engine: Option<Engine>,
    pub calibration: HostCalibration,
    pub object_store: ObjectStore,
    /// All executed pipelines (the GitLab pipeline list).
    pub pipelines: Vec<Pipeline>,
    /// id → position in `pipelines` for pipelines appended through
    /// [`World::record_pipeline`]. Lookup stays correct for pipelines
    /// pushed directly onto the (public) Vec via the linear fallback.
    pipeline_index: HashMap<u64, usize>,
    /// Incremental-execution cache. `None` (the default) preserves the
    /// always-re-execute behaviour; [`World::enable_cache`] turns repeat
    /// pipelines with unchanged inputs into zero-submission replays.
    pub cache: Option<ExecutionCache>,
}

/// Standard accounts available on every simulated machine.
fn standard_accounts() -> AccountManager {
    let mut m = AccountManager::new();
    m.add_budget("zam", 5.0e8);
    m.add_budget("exalab", 5.0e8);
    m.add_account(crate::scheduler::Account {
        name: "cjsc".into(),
        budget: "zam".into(),
        enabled: true,
        partitions: vec![],
    });
    m.add_account(crate::scheduler::Account {
        name: "cexalab".into(),
        budget: "exalab".into(),
        enabled: true,
        partitions: vec![],
    });
    m
}

impl World {
    /// A world over the standard JSC-like cluster. No PJRT engine.
    pub fn new(seed: u64) -> World {
        Self::with_cluster(Cluster::standard(), seed)
    }

    pub fn with_cluster(cluster: Cluster, seed: u64) -> World {
        let batch = cluster
            .machines
            .iter()
            .map(|m| (m.name.clone(), for_machine(m, standard_accounts())))
            .collect();
        World {
            cluster,
            batch,
            repos: BTreeMap::new(),
            registry: ComponentRegistry::builtin(),
            ids: IdAllocator::new(),
            rng: Prng::new(seed),
            seed,
            engine: None,
            calibration: HostCalibration::default(),
            object_store: ObjectStore::new(),
            pipelines: Vec::new(),
            pipeline_index: HashMap::new(),
            cache: None,
        }
    }

    /// Turn on incremental execution: repeat pipelines whose resolved
    /// inputs are unchanged replay cached results instead of submitting
    /// batch jobs. Off by default so continuous *measurement* campaigns
    /// (which want fresh noise samples every day) keep re-executing.
    pub fn enable_cache(&mut self) -> &mut World {
        if self.cache.is_none() {
            self.cache = Some(ExecutionCache::new());
        }
        self
    }

    /// Cache counters (zeroes when caching is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache
            .as_ref()
            .map(|c| c.stats)
            .unwrap_or_default()
    }

    /// Attach the PJRT engine (real kernel execution + host calibration)
    /// when artifacts are built; silently stays analytic otherwise.
    pub fn try_attach_engine(&mut self) -> bool {
        match Engine::load_default() {
            Ok(mut engine) => {
                if let Ok(c) = HostCalibration::measure(&mut engine) {
                    self.calibration = c;
                }
                self.engine = Some(engine);
                true
            }
            Err(_) => false,
        }
    }

    pub fn add_repo(&mut self, repo: BenchmarkRepo) {
        self.repos.insert(repo.name.clone(), repo);
    }

    pub fn repo(&self, name: &str) -> Option<&BenchmarkRepo> {
        self.repos.get(name)
    }

    /// Advance every machine's clock to `t` (e.g. the next scheduled
    /// pipeline trigger). Machines already past `t` are left untouched,
    /// so re-dispatching a campaign window over a warmed world (a cache
    /// replay sweep) is safe — simulated time never moves backwards.
    pub fn advance_to(&mut self, t: SimTime) {
        for bs in self.batch.values_mut() {
            if bs.now() < t {
                bs.advance_clock_to(t);
            }
        }
    }

    pub fn now(&self) -> SimTime {
        self.batch
            .values()
            .map(|b| b.now())
            .max()
            .unwrap_or_default()
    }

    /// Run one repository's CI pipeline to completion: parse its config,
    /// validate each component invocation, dispatch to the orchestrators.
    /// Returns the pipeline id (the pipeline itself lands in
    /// `self.pipelines`).
    ///
    /// This is a thin drive-to-completion wrapper over the discrete-event
    /// core: the pipeline becomes a [`PipelineTask`] and
    /// [`event_loop::drive`] runs it alone on the shared timeline. To run
    /// many pipelines *concurrently* — contending for nodes, budgets, and
    /// queue positions — begin several tasks with
    /// [`World::begin_pipeline`] and drive them together.
    pub fn run_pipeline(&mut self, repo_name: &str, trigger: Trigger) -> Result<u64, String> {
        let task = self.begin_pipeline(repo_name, trigger)?;
        let pid = task.pipeline_id();
        event_loop::drive(self, vec![task]);
        Ok(pid)
    }

    /// Start a pipeline as a resumable task without running it. The
    /// repository is checked out of `self.repos` for the duration of the
    /// run and restored when the task finishes under
    /// [`event_loop::drive`].
    pub fn begin_pipeline(
        &mut self,
        repo_name: &str,
        trigger: Trigger,
    ) -> Result<PipelineTask, String> {
        let repo = self
            .repos
            .remove(repo_name)
            .ok_or_else(|| format!("unknown repo '{repo_name}'"))?;
        match PipelineTask::new(self, repo, trigger) {
            Ok(task) => Ok(task),
            Err((repo, e)) => {
                self.repos.insert(repo_name.to_string(), repo);
                Err(e)
            }
        }
    }

    /// Append an executed pipeline, keeping the id index current. The
    /// event loop retires every finished pipeline through here; a fleet
    /// campaign then resolves ids in O(1) instead of scanning the list.
    pub fn record_pipeline(&mut self, pipeline: Pipeline) {
        if crate::obs::metrics_on() {
            use crate::obs::Ctr;
            crate::obs::count_app(&pipeline.repo, Ctr::PipelinesRun, 1);
            if pipeline.succeeded() {
                crate::obs::count_app(&pipeline.repo, Ctr::PipelinesSucceeded, 1);
            } else {
                crate::obs::count_app(&pipeline.repo, Ctr::PipelinesFailed, 1);
            }
        }
        if crate::obs::tracing() {
            // stamped with the pipeline's creation time (content carried
            // in the record), not `self.now()` — the max-over-machines
            // clock at retirement is dispatch-order sensitive
            crate::obs::trace::instant(
                "pipeline",
                "retire",
                pipeline.created,
                crate::obs::trace::args(&[
                    ("pipeline", pipeline.id.to_string()),
                    ("repo", pipeline.repo.clone()),
                    ("jobs", pipeline.jobs.len().to_string()),
                    ("ok", pipeline.succeeded().to_string()),
                ]),
            );
        }
        self.pipeline_index.insert(pipeline.id, self.pipelines.len());
        self.pipelines.push(pipeline);
    }

    /// Find an executed pipeline by id. Indexed for pipelines recorded
    /// via [`World::record_pipeline`]; falls back to a scan for anything
    /// pushed directly onto the public Vec.
    pub fn pipeline(&self, id: u64) -> Option<&Pipeline> {
        if let Some(&i) = self.pipeline_index.get(&id) {
            if let Some(p) = self.pipelines.get(i) {
                if p.id == id {
                    return Some(p);
                }
            }
        }
        self.pipelines.iter().find(|p| p.id == id)
    }

    /// Total core-hours consumed across all machines.
    pub fn total_core_hours(&self) -> f64 {
        self.batch.values().map(|b| b.accounts.total_used()).sum()
    }

    /// Longitudinal verdict table over every repository's recorded
    /// history (the `exacb track` view; DESIGN.md §9). Reads only the
    /// `exacb.data` branches — never executor state.
    pub fn track_table(&self, metric: &str) -> crate::util::table::Table {
        crate::tracking::track_table(self, metric, &crate::tracking::Detector::default())
    }

    /// Cross-application maturity readiness table: declared vs earned
    /// level per repository, with the evidence counters behind it (the
    /// `exacb jureap` view; DESIGN.md §10). Reads only the `exacb.data`
    /// branches — never executor state.
    pub fn maturity_table(&self) -> crate::util::table::Table {
        crate::maturity::maturity_table(self, &crate::maturity::CriteriaConfig::default())
    }

    /// Sweet-spot table over every recorded frequency sweep (the
    /// `exacb energy` view; DESIGN.md §11). Reads only the `exacb.data`
    /// branches — never executor state.
    pub fn energy_table(&self) -> crate::util::table::Table {
        crate::energy::study::energy_table(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::CiJobState;

    #[test]
    fn quickstart_pipeline_end_to_end() {
        // The paper's §II example: logmap on a machine through CI.
        let mut world = World::new(42);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        let pid = world.run_pipeline("logmap", Trigger::Manual).unwrap();
        let p = world.pipeline(pid).unwrap().clone();
        assert!(
            p.succeeded(),
            "jobs: {:?}",
            p.jobs.iter().map(|j| (&j.name, j.state)).collect::<Vec<_>>()
        );
        // three stages: setup, execute, record
        assert_eq!(p.jobs.len(), 3);
        let execute = p.job("jedi.logmap.execute").unwrap();
        let csv = execute.artifact("results.csv").unwrap();
        assert!(csv.starts_with("system,version,queue,variant,jobid,nodes"));
        assert!(csv.contains("jedi"));
        // report landed on the data branch
        let repo = world.repo("logmap").unwrap();
        let paths = repo.store.list("exacb.data", "jedi.logmap/");
        assert_eq!(paths.len(), 2, "{paths:?}");
        // report is protocol-parseable
        let (report_path, _) = (
            paths.iter().find(|p| p.ends_with("report.json")).unwrap(),
            (),
        );
        let doc = repo.store.read("exacb.data", report_path).unwrap();
        let report = crate::protocol::Report::parse(doc).unwrap();
        assert_eq!(report.reporter.pipeline_id, pid);
        assert_eq!(report.experiment.variant, "large-intensity");
        assert_eq!(report.data.len(), 1);
        assert!(report.data[0].success);
    }

    #[test]
    fn warm_pipeline_replays_from_cache() {
        let mut world = World::new(42);
        world.enable_cache();
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        let p1 = world.run_pipeline("logmap", Trigger::Manual).unwrap();
        let jobs_cold = world.batch.get("jedi").unwrap().records().len();
        assert!(jobs_cold > 0);
        let p2 = world.run_pipeline("logmap", Trigger::Manual).unwrap();
        assert!(world.pipeline(p2).unwrap().succeeded());
        // zero new batch submissions on the warm run
        assert_eq!(world.batch.get("jedi").unwrap().records().len(), jobs_cold);
        assert!(world.cache_stats().hits >= 1);
        // byte-identical recorded reports
        let repo = world.repo("logmap").unwrap();
        let d1 = repo
            .store
            .read("exacb.data", &format!("jedi.logmap/{p1}/report.json"))
            .unwrap();
        let d2 = repo
            .store
            .read("exacb.data", &format!("jedi.logmap/{p2}/report.json"))
            .unwrap();
        assert_eq!(d1, d2);
        // provenance marks the warm execute job as all-hit
        let warm = world.pipeline(p2).unwrap();
        let (h, m, i) = warm.cache_summary();
        assert!(h >= 1, "hits={h}");
        assert_eq!((m, i), (0, 0));
    }

    #[test]
    fn cache_disabled_by_default_keeps_reexecuting() {
        let mut world = World::new(42);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        world.run_pipeline("logmap", Trigger::Manual).unwrap();
        let jobs_cold = world.batch.get("jedi").unwrap().records().len();
        world.run_pipeline("logmap", Trigger::Manual).unwrap();
        assert_eq!(
            world.batch.get("jedi").unwrap().records().len(),
            2 * jobs_cold
        );
        assert_eq!(world.cache_stats(), crate::store::CacheStats::default());
    }

    #[test]
    fn pipeline_fails_on_bad_inputs() {
        let mut world = World::new(1);
        let repo = BenchmarkRepo::new("broken").with_file(
            ".gitlab-ci.yml",
            "component: execution@v3\ninputs:\n  prefix: p\n", // missing machine etc.
        );
        world.add_repo(repo);
        let pid = world.run_pipeline("broken", Trigger::Manual).unwrap();
        let p = world.pipeline(pid).unwrap();
        assert!(!p.succeeded());
        assert!(p.jobs[0].log[0].contains("input validation failed"));
    }

    #[test]
    fn unknown_repo_errors() {
        let mut world = World::new(1);
        assert!(world.run_pipeline("ghost", Trigger::Manual).is_err());
    }

    #[test]
    fn clock_advances_between_pipelines() {
        let mut world = World::new(2);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        world.advance_to(SimTime::from_days(3));
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
        assert!(world.now() >= SimTime::from_days(3));
        let repo = world.repo("logmap").unwrap();
        let head = repo.store.head("exacb.data").unwrap();
        assert!(head.time >= SimTime::from_days(3));
        assert!(world.total_core_hours() > 0.0);
    }

    #[test]
    fn disabled_account_fails_setup_stage() {
        let mut world = World::new(3);
        world
            .batch
            .get_mut("jedi")
            .unwrap()
            .accounts
            .set_enabled("cjsc", false);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        let pid = world.run_pipeline("logmap", Trigger::Manual).unwrap();
        let p = world.pipeline(pid).unwrap();
        assert_eq!(p.jobs.len(), 1); // only setup ran
        assert_eq!(p.jobs[0].state, CiJobState::Failed);
    }
}
