//! Collection-scale continuous benchmarking (paper §VI-A): the JUREAP
//! campaign manager.
//!
//! Onboards a portfolio of benchmark repositories at heterogeneous
//! maturity levels, runs their scheduled pipelines across simulated
//! days, and produces the cross-application analyses the paper
//! highlights: uniform protocol capture regardless of maturity, success
//! tracking, maturity histograms, per-domain aggregation.

use std::collections::{HashMap, VecDeque};

use crate::analysis::ReportSet;
use crate::ci::Trigger;
use crate::store::CacheStats;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::table::Table;
use crate::util::timeutil::SimTime;
use crate::workloads::portfolio::{Maturity, PortfolioApp};

use super::repo::BenchmarkRepo;
use super::world::World;

/// Build a benchmark repository for one portfolio application.
///
/// Maturity shapes the definition, mirroring the incremental-adoption
/// ladder: runnability-level repos have the minimal runnable benchmark;
/// instrumentability adds analysis patterns; reproducibility additionally
/// pins the environment and records by default.
pub fn repo_for_app(app: &PortfolioApp, machine: &str, queue: &str) -> BenchmarkRepo {
    let mut jube = format!(
        "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: {nodes}\n",
        name = app.name,
        nodes = app.nodes
    );
    jube.push_str("steps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n");
    jube.push_str(&format!("      - {}\n", app.command()));
    if app.maturity >= Maturity::Instrumentability {
        jube.push_str(
            "analysis:\n  - name: tts_file\n    file: app.out\n    regex: \"time: ([0-9.eE+-]+)\"\n    type: float\n",
        );
    }
    let ci = format!(
        r#"
include:
  - component: execution@v3
    inputs:
      prefix: "{machine}.{name}"
      machine: "{machine}"
      queue: "{queue}"
      project: "cexalab"
      budget: "exalab"
      jube_file: "benchmark/jube/app.yml"
      record: "true"
schedule:
  every: day
  hour: 3
"#,
        name = app.name
    );
    BenchmarkRepo::new(&app.name)
        .with_file("benchmark/jube/app.yml", &jube)
        .with_file(".gitlab-ci.yml", &ci)
        .with_maturity(app.maturity)
}

/// Summary of a collection campaign.
#[derive(Debug, Clone)]
pub struct CollectionSummary {
    pub apps: usize,
    pub days: i64,
    pub pipelines_run: usize,
    pub pipelines_succeeded: usize,
    pub reports_recorded: usize,
    pub entries_ok: usize,
    pub entries_total: usize,
    pub core_hours: f64,
    /// (maturity, app count, success rate)
    pub by_maturity: Vec<(Maturity, usize, f64)>,
    /// (domain, app count, median tts)
    pub by_domain: Vec<(String, usize, f64)>,
    /// Execution-cache counters (zeroes when caching is off).
    pub cache: CacheStats,
}

impl CollectionSummary {
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["maturity", "apps", "success_rate"]);
        for (m, n, rate) in &self.by_maturity {
            t.push_row(vec![
                m.name().to_string(),
                n.to_string(),
                format!("{:.3}", rate),
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Json {
        let mut by_m = Json::arr();
        for (m, n, rate) in &self.by_maturity {
            by_m.push(
                Json::obj()
                    .set("maturity", m.name())
                    .set("apps", *n)
                    .set("success_rate", *rate),
            );
        }
        Json::obj()
            .set("apps", self.apps)
            .set("days", self.days as i64)
            .set("pipelines_run", self.pipelines_run)
            .set("pipelines_succeeded", self.pipelines_succeeded)
            .set("reports_recorded", self.reports_recorded)
            .set("entries_ok", self.entries_ok)
            .set("entries_total", self.entries_total)
            .set("core_hours", self.core_hours)
            .set("cache_hits", self.cache.hits)
            .set("cache_misses", self.cache.misses)
            .set("cache_invalidated", self.cache.invalidated)
            .set("by_maturity", by_m)
    }
}

/// Onboard the portfolio into the world (one repo per app) and return
/// the app list for bookkeeping.
pub fn onboard(world: &mut World, apps: &[PortfolioApp], machine: &str, queue: &str) {
    for app in apps {
        world.add_repo(repo_for_app(app, machine, queue));
    }
}

/// The single source of truth for app→machine placement: round-robin by
/// app index, deterministic. Both onboarding and queued dispatch derive
/// their assignments from here.
pub fn assign(apps: &[PortfolioApp], machines: &[&str]) -> Vec<(String, String)> {
    assert!(!machines.is_empty(), "need at least one machine");
    apps.iter()
        .enumerate()
        .map(|(i, a)| (a.name.clone(), machines[i % machines.len()].to_string()))
        .collect()
}

/// Onboard a portfolio across several machines and return the
/// (app, machine) assignments the work queue dispatches against.
pub fn onboard_multi(
    world: &mut World,
    apps: &[PortfolioApp],
    machines: &[&str],
    queue: &str,
) -> Vec<(String, String)> {
    let assignments = assign(apps, machines);
    for (app, (_, machine)) in apps.iter().zip(&assignments) {
        world.add_repo(repo_for_app(app, machine, queue));
    }
    assignments
}

/// One dispatch unit of a collection campaign.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkItem {
    pub day: i64,
    pub app: String,
    pub machine: String,
}

/// Deterministic concurrent dispatch order for a campaign.
///
/// Per simulated day, the (app, machine) assignments are shuffled by a
/// PRNG forked from the campaign seed, then dealt round-robin across
/// per-machine lanes: consecutive items hit *different* machines'
/// batch systems, so no single repository monopolises the campaign and
/// every machine makes progress concurrently. Because the shuffle is
/// seeded, the interleaving — and therefore the whole campaign — is
/// bit-reproducible: same seed, same queue, same results.
#[derive(Debug, Clone, Default)]
pub struct WorkQueue {
    pub items: Vec<WorkItem>,
}

impl WorkQueue {
    pub fn build(assignments: &[(String, String)], days: i64, seed: u64) -> WorkQueue {
        let mut items = Vec::new();
        for day in 0..days {
            let mut day_rng =
                Prng::new(seed ^ (day as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let mut todo: Vec<&(String, String)> = assignments.iter().collect();
            day_rng.shuffle(&mut todo);
            // group into machine lanes (lane order = first appearance in
            // the shuffled list), then deal round-robin across lanes
            let mut lanes: Vec<(String, VecDeque<&(String, String)>)> = Vec::new();
            for a in todo {
                match lanes.iter_mut().find(|(m, _)| m == &a.1) {
                    Some((_, q)) => q.push_back(a),
                    None => lanes.push((a.1.clone(), VecDeque::from([a]))),
                }
            }
            loop {
                let mut any = false;
                for (_, q) in lanes.iter_mut() {
                    if let Some(a) = q.pop_front() {
                        items.push(WorkItem {
                            day,
                            app: a.0.clone(),
                            machine: a.1.clone(),
                        });
                        any = true;
                    }
                }
                if !any {
                    break;
                }
            }
        }
        WorkQueue { items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Run `days` of daily scheduled pipelines for every onboarded app.
///
/// Each app's repo declares `schedule: daily@3`; the campaign advances
/// the simulated clock day by day and fires every repo's pipeline.
/// Per-run failures are injected per the app's failure rate by flipping
/// the app command to an invalid one for that day (flaky software).
pub fn run_campaign(
    world: &mut World,
    apps: &[PortfolioApp],
    days: i64,
) -> CollectionSummary {
    let mut pipelines_run = 0;
    let mut pipelines_succeeded = 0;
    for day in 0..days {
        world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
        for app in apps {
            // flaky-software injection: with failure_rate probability the
            // app binary crashes this run (exercises the Failed path)
            let fail_today = world.rng.bool_with(app.failure_rate);
            if fail_today {
                if let Some(repo) = world.repos.get_mut(&app.name) {
                    patch_command(repo, &app.command(), "crashing-binary --boom");
                }
            }
            let pid = world.run_pipeline(&app.name, Trigger::Scheduled);
            pipelines_run += 1;
            if let Ok(pid) = pid {
                if world.pipeline(pid).map(|p| p.succeeded()).unwrap_or(false) {
                    pipelines_succeeded += 1;
                }
            }
            if fail_today {
                if let Some(repo) = world.repos.get_mut(&app.name) {
                    patch_command(repo, "crashing-binary --boom", &app.command());
                }
            }
        }
    }
    summarize(world, apps, days, pipelines_run, pipelines_succeeded)
}

/// Dispatch one campaign work item: advance the clock to the item's
/// trigger, run the app's scheduled pipeline, return success.
///
/// The world PRNG is re-seeded from (campaign seed, day, app) before
/// the run, so a pipeline's simulated noise — and therefore its
/// recorded results — depend only on the item identity, never on where
/// the item lands in the dispatch interleaving. This is what makes the
/// concurrent work queue's aggregation genuinely order-independent.
pub fn dispatch_item(world: &mut World, app: &PortfolioApp, day: i64) -> bool {
    world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
    world.rng = Prng::new(
        world.seed ^ crate::util::fnv1a(format!("{day}|{}", app.name).as_bytes()),
    );
    let fail_today = world.rng.bool_with(app.failure_rate);
    if fail_today {
        if let Some(repo) = world.repos.get_mut(&app.name) {
            patch_command(repo, &app.command(), "crashing-binary --boom");
        }
    }
    let pid = world.run_pipeline(&app.name, Trigger::Scheduled);
    let ok = pid
        .ok()
        .and_then(|pid| world.pipeline(pid).map(|p| p.succeeded()))
        .unwrap_or(false);
    if fail_today {
        if let Some(repo) = world.repos.get_mut(&app.name) {
            patch_command(repo, "crashing-binary --boom", &app.command());
        }
    }
    ok
}

/// Run a campaign through the deterministic concurrent work queue,
/// interleaving per-repo pipelines across machines (paper §VI-A at
/// scale). `machines` must be the slice that was passed to
/// [`onboard_multi`] — both derive placement from [`assign`].
///
/// With [`World::enable_cache`] on, a repeat sweep over unchanged
/// inputs replays every pipeline from the execution cache: zero batch
/// submissions, byte-identical recorded reports.
pub fn run_campaign_queued(
    world: &mut World,
    apps: &[PortfolioApp],
    machines: &[&str],
    days: i64,
) -> CollectionSummary {
    let assignments = assign(apps, machines);
    let queue = WorkQueue::build(&assignments, days, world.seed);
    let by_name: HashMap<&str, &PortfolioApp> =
        apps.iter().map(|a| (a.name.as_str(), a)).collect();
    let mut pipelines_run = 0;
    let mut pipelines_succeeded = 0;
    for item in &queue.items {
        let app = by_name
            .get(item.app.as_str())
            .expect("queue items come from the app list");
        pipelines_run += 1;
        if dispatch_item(world, app, item.day) {
            pipelines_succeeded += 1;
        }
    }
    summarize(world, apps, days, pipelines_run, pipelines_succeeded)
}

/// Run a campaign with **true concurrency**: per simulated day, every
/// (app, machine) item of the work queue is started as a resumable
/// pipeline task and all of them are driven together by the coordinator
/// event loop — N apps × M machines in flight simultaneously on one
/// shared virtual timeline. Same-trigger pipelines submit before any
/// simulated time passes, so real queue waits, backfill, and
/// account-budget contention emerge on shared partitions (the JUREAP
/// scenario the sequential dispatcher cannot express).
///
/// Determinism and equivalence: each task gets the same per-item PRNG
/// stream `seed ^ fnv1a("day|app")` that [`dispatch_item`] installs, so
/// on a single machine with no contention-induced day drift this
/// produces byte-identical [`super::postproc::collection_results_table`]
/// output to [`run_campaign_queued`] (property-tested).
pub fn run_campaign_concurrent(
    world: &mut World,
    apps: &[PortfolioApp],
    machines: &[&str],
    days: i64,
) -> CollectionSummary {
    run_campaign_concurrent_with(world, apps, machines, days, super::event_loop::drive)
}

/// [`run_campaign_concurrent`] with a pluggable event loop, so the
/// differential dispatch tests can replay the *same* campaign through
/// [`super::event_loop::drive`] and [`super::event_loop::drive_reference`]
/// and require byte-identical worlds.
pub fn run_campaign_concurrent_with(
    world: &mut World,
    apps: &[PortfolioApp],
    machines: &[&str],
    days: i64,
    drive: fn(&mut World, Vec<super::event_loop::PipelineTask>) -> Vec<u64>,
) -> CollectionSummary {
    let assignments = assign(apps, machines);
    let queue = WorkQueue::build(&assignments, days, world.seed);
    let by_name: HashMap<&str, &PortfolioApp> =
        apps.iter().map(|a| (a.name.as_str(), a)).collect();
    let mut pipelines_run = 0;
    let mut pipelines_succeeded = 0;
    let mut item_cursor = 0;
    for day in 0..days {
        let trigger = SimTime::from_days(day).add_secs(3 * 3600);
        world.advance_to(trigger);
        if crate::obs::tracing() {
            // the trigger instant is campaign input (day schedule), not
            // dispatch state — safe to stamp directly
            crate::obs::trace::instant(
                "campaign",
                "day-trigger",
                trigger,
                crate::obs::trace::args(&[("day", day.to_string())]),
            );
        }
        let mut tasks = Vec::new();
        let mut patched: Vec<&PortfolioApp> = Vec::new();
        // queue items are built day by day, so each day's slice is
        // contiguous — walk a cursor instead of re-filtering all items
        let day_start = item_cursor;
        while item_cursor < queue.items.len() && queue.items[item_cursor].day == day {
            item_cursor += 1;
        }
        for item in &queue.items[day_start..item_cursor] {
            let app = *by_name
                .get(item.app.as_str())
                .expect("queue items come from the app list");
            // the same per-item stream dispatch_item uses: the flaky-
            // software draw comes first, the pipeline's noise follows
            let mut rng = Prng::new(
                world.seed ^ crate::util::fnv1a(format!("{day}|{}", app.name).as_bytes()),
            );
            let fail_today = rng.bool_with(app.failure_rate);
            if fail_today {
                if let Some(repo) = world.repos.get_mut(&app.name) {
                    patch_command(repo, &app.command(), "crashing-binary --boom");
                }
                patched.push(app);
            }
            pipelines_run += 1;
            match world.begin_pipeline(&app.name, crate::ci::Trigger::Scheduled) {
                Ok(mut task) => {
                    task.rng = Some(rng);
                    tasks.push(task);
                }
                Err(_) => {} // counted as run, never as succeeded
            }
        }
        let pids = drive(world, tasks);
        for pid in pids {
            if world.pipeline(pid).map(|p| p.succeeded()).unwrap_or(false) {
                pipelines_succeeded += 1;
            }
        }
        // un-patch after the day's tasks returned their repos
        for app in patched {
            if let Some(repo) = world.repos.get_mut(&app.name) {
                patch_command(repo, "crashing-binary --boom", &app.command());
            }
        }
    }
    summarize(world, apps, days, pipelines_run, pipelines_succeeded)
}

fn patch_command(repo: &mut BenchmarkRepo, from: &str, to: &str) {
    for (path, content) in repo.files.iter_mut() {
        if path.ends_with("app.yml") {
            *content = content.replace(from, to);
        }
    }
}

fn summarize(
    world: &World,
    apps: &[PortfolioApp],
    days: i64,
    pipelines_run: usize,
    pipelines_succeeded: usize,
) -> CollectionSummary {
    let mut reports_recorded = 0;
    let mut entries_ok = 0;
    let mut entries_total = 0;
    let mut by_maturity: Vec<(Maturity, usize, f64)> = Vec::new();
    let mut by_domain: Vec<(String, usize, f64)> = Vec::new();

    for maturity in [
        Maturity::Runnability,
        Maturity::Instrumentability,
        Maturity::Reproducibility,
    ] {
        let mut ok = 0usize;
        let mut total = 0usize;
        let mut n_apps = 0usize;
        for app in apps.iter().filter(|a| a.maturity == maturity) {
            n_apps += 1;
            if let Some(repo) = world.repo(&app.name) {
                let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
                reports_recorded += set.len();
                let (o, t) = set.success_counts();
                ok += o;
                total += t;
            }
        }
        entries_ok += ok;
        entries_total += total;
        by_maturity.push((
            maturity,
            n_apps,
            if total > 0 { ok as f64 / total as f64 } else { 0.0 },
        ));
    }

    let mut domains: Vec<String> = apps.iter().map(|a| a.domain.clone()).collect();
    domains.sort();
    domains.dedup();
    for domain in domains {
        let mut tts: Vec<f64> = Vec::new();
        let mut n_apps = 0;
        for app in apps.iter().filter(|a| a.domain == domain) {
            n_apps += 1;
            if let Some(repo) = world.repo(&app.name) {
                let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
                tts.extend(set.time_series("tts").iter().map(|(_, v)| *v));
            }
        }
        by_domain.push((domain, n_apps, crate::util::stats::median(&tts)));
    }

    CollectionSummary {
        apps: apps.len(),
        days,
        pipelines_run,
        pipelines_succeeded,
        reports_recorded,
        entries_ok,
        entries_total,
        core_hours: world.total_core_hours(),
        by_maturity,
        by_domain,
        cache: world.cache_stats(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::portfolio;

    #[test]
    fn small_campaign_end_to_end() {
        let apps = portfolio::generate(6, 11);
        let mut world = World::new(11);
        onboard(&mut world, &apps, "jupiter", "all");
        let summary = run_campaign(&mut world, &apps, 3);
        assert_eq!(summary.apps, 6);
        assert_eq!(summary.pipelines_run, 18);
        assert!(summary.pipelines_succeeded > 0);
        // every app recorded protocol reports in a uniform format
        assert!(summary.reports_recorded >= summary.pipelines_succeeded);
        assert!(summary.entries_total >= summary.reports_recorded);
        assert!(summary.core_hours > 0.0);
        // maturity table renders
        let t = summary.table();
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn repos_reflect_maturity() {
        let apps = portfolio::generate(20, 3);
        let runnable = apps
            .iter()
            .find(|a| a.maturity == Maturity::Runnability)
            .unwrap();
        let instrumented = apps
            .iter()
            .find(|a| a.maturity >= Maturity::Instrumentability)
            .unwrap();
        let r1 = repo_for_app(runnable, "jupiter", "all");
        let r2 = repo_for_app(instrumented, "jupiter", "all");
        assert!(!r1.file("benchmark/jube/app.yml").unwrap().contains("analysis:"));
        assert!(r2.file("benchmark/jube/app.yml").unwrap().contains("analysis:"));
        // both parse and produce valid CI configs
        r1.ci_config().unwrap();
        r2.ci_config().unwrap();
        r1.benchmark_spec("benchmark/jube/app.yml").unwrap();
    }

    #[test]
    fn work_queue_is_deterministic_and_interleaves_machines() {
        let assignments: Vec<(String, String)> = (0..8)
            .map(|i| {
                (
                    format!("app{i}"),
                    if i % 2 == 0 { "jupiter" } else { "jedi" }.to_string(),
                )
            })
            .collect();
        let a = WorkQueue::build(&assignments, 3, 99);
        let b = WorkQueue::build(&assignments, 3, 99);
        assert_eq!(a.items, b.items);
        assert_eq!(a.len(), 24);
        // a different seed reorders within days but covers the same work
        let c = WorkQueue::build(&assignments, 3, 100);
        assert_ne!(a.items, c.items);
        let key = |q: &WorkQueue| {
            let mut v: Vec<String> = q
                .items
                .iter()
                .map(|i| format!("{}:{}:{}", i.day, i.app, i.machine))
                .collect();
            v.sort();
            v
        };
        assert_eq!(key(&a), key(&c));
        // round-robin: consecutive day-0 items alternate machines
        let day0: Vec<&str> = a
            .items
            .iter()
            .filter(|i| i.day == 0)
            .map(|i| i.machine.as_str())
            .collect();
        for w in day0.windows(2).take(6) {
            assert_ne!(w[0], w[1], "{day0:?}");
        }
    }

    #[test]
    fn queued_campaign_across_machines() {
        let apps = portfolio::generate(6, 17);
        let mut world = World::new(17);
        let machines = ["jupiter", "jedi"];
        onboard_multi(&mut world, &apps, &machines, "all");
        let summary = run_campaign_queued(&mut world, &apps, &machines, 2);
        assert_eq!(summary.pipelines_run, 12);
        assert!(summary.pipelines_succeeded > 0);
        // both machines actually ran jobs
        assert!(world.batch.get("jupiter").unwrap().records().len() > 0);
        assert!(world.batch.get("jedi").unwrap().records().len() > 0);
    }

    #[test]
    fn warm_sweep_submits_zero_jobs() {
        let mut apps = portfolio::generate(4, 23);
        for a in &mut apps {
            a.failure_rate = 0.0; // flaky injection would change inputs
        }
        let mut world = World::new(23);
        world.enable_cache();
        let machines = ["jupiter"];
        onboard_multi(&mut world, &apps, &machines, "all");
        let cold = run_campaign_queued(&mut world, &apps, &machines, 2);
        let jobs_cold = world.batch.get("jupiter").unwrap().records().len();
        assert!(jobs_cold > 0);
        assert!(cold.cache.misses > 0);
        // second sweep over the same inputs: pure replay (stats are
        // cumulative per world, so compare against the cold counters)
        let warm = run_campaign_queued(&mut world, &apps, &machines, 2);
        assert_eq!(
            world.batch.get("jupiter").unwrap().records().len(),
            jobs_cold,
            "warm sweep must submit zero batch jobs"
        );
        assert_eq!(warm.pipelines_run, 8);
        assert_eq!(warm.pipelines_succeeded, warm.pipelines_run);
        assert!(
            warm.cache.hits >= cold.cache.hits + 8,
            "cold {:?} warm {:?}",
            cold.cache,
            warm.cache
        );
        assert_eq!(
            warm.cache.misses, cold.cache.misses,
            "no new misses on a warm sweep"
        );
    }

    #[test]
    fn concurrent_campaign_matches_sequential_on_one_machine() {
        let apps = portfolio::generate(6, 29);
        let machines = ["jedi"];
        let mut seq = World::new(29);
        onboard_multi(&mut seq, &apps, &machines, "all");
        let s1 = run_campaign_queued(&mut seq, &apps, &machines, 2);
        let mut con = World::new(29);
        onboard_multi(&mut con, &apps, &machines, "all");
        let s2 = run_campaign_concurrent(&mut con, &apps, &machines, 2);
        // same per-item PRNG streams: identical outcomes either way
        assert_eq!(s1.pipelines_run, s2.pipelines_run);
        assert_eq!(s1.pipelines_succeeded, s2.pipelines_succeeded);
        assert_eq!(s1.reports_recorded, s2.reports_recorded);
        let t1 = crate::coordinator::postproc::collection_results_table(&seq, "runtime");
        let t2 = crate::coordinator::postproc::collection_results_table(&con, "runtime");
        assert_eq!(t1.to_csv(), t2.to_csv());
    }

    #[test]
    fn concurrent_campaign_interleaves_submissions() {
        // all of a day's pipelines must be in the queue before any
        // simulated time passes — that is what the sequential path
        // cannot express
        let mut apps = portfolio::generate(4, 37);
        for a in &mut apps {
            a.failure_rate = 0.0;
        }
        let machines = ["jedi"];
        let mut world = World::new(37);
        onboard_multi(&mut world, &apps, &machines, "all");
        run_campaign_concurrent(&mut world, &apps, &machines, 1);
        let bs = world.batch.get("jedi").unwrap();
        let submits: Vec<i64> = bs.records().iter().map(|r| r.submit_time.0).collect();
        assert_eq!(submits.len(), 4);
        assert!(
            submits.windows(2).all(|w| w[0] == w[1]),
            "same-trigger submissions must share the submit instant: {submits:?}"
        );
    }

    #[test]
    fn failures_show_up_in_success_rates() {
        // an always-failing app drags its maturity class down
        let mut apps = portfolio::generate(2, 5);
        apps[0].failure_rate = 1.0;
        apps[1].failure_rate = 0.0;
        apps[0].maturity = Maturity::Runnability;
        apps[1].maturity = Maturity::Reproducibility;
        let mut world = World::new(5);
        onboard(&mut world, &apps, "jupiter", "all");
        let summary = run_campaign(&mut world, &apps, 2);
        let runnable = summary
            .by_maturity
            .iter()
            .find(|(m, _, _)| *m == Maturity::Runnability)
            .unwrap();
        let repro = summary
            .by_maturity
            .iter()
            .find(|(m, _, _)| *m == Maturity::Reproducibility)
            .unwrap();
        assert!(runnable.2 < 0.5, "{:?}", summary.by_maturity);
        assert!(repro.2 > 0.9, "{:?}", summary.by_maturity);
    }
}
