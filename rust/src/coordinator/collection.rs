//! Collection-scale continuous benchmarking (paper §VI-A): the JUREAP
//! campaign manager.
//!
//! Onboards a portfolio of benchmark repositories at heterogeneous
//! maturity levels, runs their scheduled pipelines across simulated
//! days, and produces the cross-application analyses the paper
//! highlights: uniform protocol capture regardless of maturity, success
//! tracking, maturity histograms, per-domain aggregation.

use crate::analysis::ReportSet;
use crate::ci::Trigger;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::timeutil::SimTime;
use crate::workloads::portfolio::{Maturity, PortfolioApp};

use super::repo::BenchmarkRepo;
use super::world::World;

/// Build a benchmark repository for one portfolio application.
///
/// Maturity shapes the definition, mirroring the incremental-adoption
/// ladder: runnability-level repos have the minimal runnable benchmark;
/// instrumentability adds analysis patterns; reproducibility additionally
/// pins the environment and records by default.
pub fn repo_for_app(app: &PortfolioApp, machine: &str, queue: &str) -> BenchmarkRepo {
    let mut jube = format!(
        "name: {name}\nparametersets:\n  - name: run\n    parameters:\n      - name: nodes\n        value: {nodes}\n",
        name = app.name,
        nodes = app.nodes
    );
    jube.push_str("steps:\n  - name: execute\n    use: [run]\n    remote: true\n    do:\n");
    jube.push_str(&format!("      - {}\n", app.command()));
    if app.maturity >= Maturity::Instrumentability {
        jube.push_str(
            "analysis:\n  - name: tts_file\n    file: app.out\n    regex: \"time: ([0-9.eE+-]+)\"\n    type: float\n",
        );
    }
    let ci = format!(
        r#"
include:
  - component: execution@v3
    inputs:
      prefix: "{machine}.{name}"
      machine: "{machine}"
      queue: "{queue}"
      project: "cexalab"
      budget: "exalab"
      jube_file: "benchmark/jube/app.yml"
      record: "true"
schedule:
  every: day
  hour: 3
"#,
        name = app.name
    );
    BenchmarkRepo::new(&app.name)
        .with_file("benchmark/jube/app.yml", &jube)
        .with_file(".gitlab-ci.yml", &ci)
        .with_maturity(app.maturity)
}

/// Summary of a collection campaign.
#[derive(Debug, Clone)]
pub struct CollectionSummary {
    pub apps: usize,
    pub days: i64,
    pub pipelines_run: usize,
    pub pipelines_succeeded: usize,
    pub reports_recorded: usize,
    pub entries_ok: usize,
    pub entries_total: usize,
    pub core_hours: f64,
    /// (maturity, app count, success rate)
    pub by_maturity: Vec<(Maturity, usize, f64)>,
    /// (domain, app count, median tts)
    pub by_domain: Vec<(String, usize, f64)>,
}

impl CollectionSummary {
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["maturity", "apps", "success_rate"]);
        for (m, n, rate) in &self.by_maturity {
            t.push_row(vec![
                m.name().to_string(),
                n.to_string(),
                format!("{:.3}", rate),
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Json {
        let mut by_m = Json::arr();
        for (m, n, rate) in &self.by_maturity {
            by_m.push(
                Json::obj()
                    .set("maturity", m.name())
                    .set("apps", *n)
                    .set("success_rate", *rate),
            );
        }
        Json::obj()
            .set("apps", self.apps)
            .set("days", self.days as i64)
            .set("pipelines_run", self.pipelines_run)
            .set("pipelines_succeeded", self.pipelines_succeeded)
            .set("reports_recorded", self.reports_recorded)
            .set("entries_ok", self.entries_ok)
            .set("entries_total", self.entries_total)
            .set("core_hours", self.core_hours)
            .set("by_maturity", by_m)
    }
}

/// Onboard the portfolio into the world (one repo per app) and return
/// the app list for bookkeeping.
pub fn onboard(world: &mut World, apps: &[PortfolioApp], machine: &str, queue: &str) {
    for app in apps {
        world.add_repo(repo_for_app(app, machine, queue));
    }
}

/// Run `days` of daily scheduled pipelines for every onboarded app.
///
/// Each app's repo declares `schedule: daily@3`; the campaign advances
/// the simulated clock day by day and fires every repo's pipeline.
/// Per-run failures are injected per the app's failure rate by flipping
/// the app command to an invalid one for that day (flaky software).
pub fn run_campaign(
    world: &mut World,
    apps: &[PortfolioApp],
    days: i64,
) -> CollectionSummary {
    let mut pipelines_run = 0;
    let mut pipelines_succeeded = 0;
    for day in 0..days {
        world.advance_to(SimTime::from_days(day).add_secs(3 * 3600));
        for app in apps {
            // flaky-software injection: with failure_rate probability the
            // app binary crashes this run (exercises the Failed path)
            let fail_today = world.rng.bool_with(app.failure_rate);
            if fail_today {
                if let Some(repo) = world.repos.get_mut(&app.name) {
                    patch_command(repo, &app.command(), "crashing-binary --boom");
                }
            }
            let pid = world.run_pipeline(&app.name, Trigger::Scheduled);
            pipelines_run += 1;
            if let Ok(pid) = pid {
                if world.pipeline(pid).map(|p| p.succeeded()).unwrap_or(false) {
                    pipelines_succeeded += 1;
                }
            }
            if fail_today {
                if let Some(repo) = world.repos.get_mut(&app.name) {
                    patch_command(repo, "crashing-binary --boom", &app.command());
                }
            }
        }
    }
    summarize(world, apps, days, pipelines_run, pipelines_succeeded)
}

fn patch_command(repo: &mut BenchmarkRepo, from: &str, to: &str) {
    for (path, content) in repo.files.iter_mut() {
        if path.ends_with("app.yml") {
            *content = content.replace(from, to);
        }
    }
}

fn summarize(
    world: &World,
    apps: &[PortfolioApp],
    days: i64,
    pipelines_run: usize,
    pipelines_succeeded: usize,
) -> CollectionSummary {
    let mut reports_recorded = 0;
    let mut entries_ok = 0;
    let mut entries_total = 0;
    let mut by_maturity: Vec<(Maturity, usize, f64)> = Vec::new();
    let mut by_domain: Vec<(String, usize, f64)> = Vec::new();

    for maturity in [
        Maturity::Runnability,
        Maturity::Instrumentability,
        Maturity::Reproducibility,
    ] {
        let mut ok = 0usize;
        let mut total = 0usize;
        let mut n_apps = 0usize;
        for app in apps.iter().filter(|a| a.maturity == maturity) {
            n_apps += 1;
            if let Some(repo) = world.repo(&app.name) {
                let (set, _) = ReportSet::load(&repo.store, "exacb.data", "");
                reports_recorded += set.len();
                let (o, t) = set.success_counts();
                ok += o;
                total += t;
            }
        }
        entries_ok += ok;
        entries_total += total;
        by_maturity.push((
            maturity,
            n_apps,
            if total > 0 { ok as f64 / total as f64 } else { 0.0 },
        ));
    }

    let mut domains: Vec<String> = apps.iter().map(|a| a.domain.clone()).collect();
    domains.sort();
    domains.dedup();
    for domain in domains {
        let mut tts: Vec<f64> = Vec::new();
        let mut n_apps = 0;
        for app in apps.iter().filter(|a| a.domain == domain) {
            n_apps += 1;
            if let Some(repo) = world.repo(&app.name) {
                let (set, _) = ReportSet::load(&repo.store, "exacb.data", "");
                tts.extend(set.time_series("tts").iter().map(|(_, v)| *v));
            }
        }
        by_domain.push((domain, n_apps, crate::util::stats::median(&tts)));
    }

    CollectionSummary {
        apps: apps.len(),
        days,
        pipelines_run,
        pipelines_succeeded,
        reports_recorded,
        entries_ok,
        entries_total,
        core_hours: world.total_core_hours(),
        by_maturity,
        by_domain,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::portfolio;

    #[test]
    fn small_campaign_end_to_end() {
        let apps = portfolio::generate(6, 11);
        let mut world = World::new(11);
        onboard(&mut world, &apps, "jupiter", "all");
        let summary = run_campaign(&mut world, &apps, 3);
        assert_eq!(summary.apps, 6);
        assert_eq!(summary.pipelines_run, 18);
        assert!(summary.pipelines_succeeded > 0);
        // every app recorded protocol reports in a uniform format
        assert!(summary.reports_recorded >= summary.pipelines_succeeded);
        assert!(summary.entries_total >= summary.reports_recorded);
        assert!(summary.core_hours > 0.0);
        // maturity table renders
        let t = summary.table();
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn repos_reflect_maturity() {
        let apps = portfolio::generate(20, 3);
        let runnable = apps
            .iter()
            .find(|a| a.maturity == Maturity::Runnability)
            .unwrap();
        let instrumented = apps
            .iter()
            .find(|a| a.maturity >= Maturity::Instrumentability)
            .unwrap();
        let r1 = repo_for_app(runnable, "jupiter", "all");
        let r2 = repo_for_app(instrumented, "jupiter", "all");
        assert!(!r1.file("benchmark/jube/app.yml").unwrap().contains("analysis:"));
        assert!(r2.file("benchmark/jube/app.yml").unwrap().contains("analysis:"));
        // both parse and produce valid CI configs
        r1.ci_config().unwrap();
        r2.ci_config().unwrap();
        r1.benchmark_spec("benchmark/jube/app.yml").unwrap();
    }

    #[test]
    fn failures_show_up_in_success_rates() {
        // an always-failing app drags its maturity class down
        let mut apps = portfolio::generate(2, 5);
        apps[0].failure_rate = 1.0;
        apps[1].failure_rate = 0.0;
        apps[0].maturity = Maturity::Runnability;
        apps[1].maturity = Maturity::Reproducibility;
        let mut world = World::new(5);
        onboard(&mut world, &apps, "jupiter", "all");
        let summary = run_campaign(&mut world, &apps, 2);
        let runnable = summary
            .by_maturity
            .iter()
            .find(|(m, _, _)| *m == Maturity::Runnability)
            .unwrap();
        let repro = summary
            .by_maturity
            .iter()
            .find(|(m, _, _)| *m == Maturity::Reproducibility)
            .unwrap();
        assert!(runnable.2 < 0.5, "{:?}", summary.by_maturity);
        assert!(repro.2 > 0.9, "{:?}", summary.by_maturity);
    }
}
