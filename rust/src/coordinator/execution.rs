//! The Execution Orchestrator (paper §V-A.1) and its feature-injection
//! variant (§V-A.3), as a **resumable state machine**.
//!
//! Stages, each an individual CI job communicating through artifacts:
//!
//! 1. **setup** — Jacamar-runner preflight: environment, queue, account.
//! 2. **execute** — instantiate the JUBE-style benchmark, run the
//!    parameter study through the batch system, first-line analysis.
//! 3. **record** — assemble the protocol report + Table-I `results.csv`
//!    and (when `record: true`) commit them to the repo's `exacb.data`
//!    branch.
//!
//! [`ExecutionTask`] drives these stages through a
//! [`crate::harness::RunCursor`]: every remote step submission *yields*
//! (`ExecPoll::Waiting`) instead of draining the batch system, so the
//! coordinator event loop can interleave many in-flight executions on
//! one shared virtual timeline. [`run_execution`] remains the blocking
//! drive-to-completion wrapper every pre-event-loop caller used.

use crate::ci::{CiJob, CiJobState, Runner};
use crate::cluster::SoftwareStage;
use crate::harness::{CursorPoll, RunCursor};
use crate::protocol::{
    provenance_document, results_csv, CacheOutcome, Experiment, Report, Reporter,
    StepProvenance,
};
use crate::store::{CacheKey, CacheKeyBuilder};
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::timeutil::SimTime;

use super::executor::{
    env_fingerprint, BatchStepExecutor, CollectTriage, Launcher, PendingStep,
};
use super::repo::BenchmarkRepo;
use super::world::World;

/// Resolved execution inputs (post component-schema validation).
#[derive(Debug, Clone)]
pub struct ExecutionParams {
    pub prefix: String,
    pub machine: String,
    pub queue: String,
    pub project: String,
    pub budget: String,
    pub jube_file: String,
    pub variant: String,
    pub usecase: String,
    pub extra_tags: Vec<String>,
    pub stage: String,
    pub launcher: Launcher,
    pub record: bool,
    pub freq_mhz: Option<f64>,
    pub nodes_override: u64,
    /// Feature injection: command prepended to every remote step.
    pub in_command: Option<String>,
}

impl ExecutionParams {
    /// Build from resolved component inputs. An unknown launcher string
    /// is a loud error surfaced through the CI validation job.
    pub fn from_inputs(inputs: &Json) -> Result<ExecutionParams, String> {
        let s = |k: &str| inputs.str_of(k).unwrap_or("").to_string();
        let freq = inputs.f64_of("freq_mhz").unwrap_or(0.0);
        let launcher = Launcher::parse(inputs.str_of("launcher").unwrap_or("srun"))
            .map_err(|e| e.to_string())?;
        Ok(ExecutionParams {
            prefix: s("prefix"),
            machine: s("machine"),
            queue: s("queue"),
            project: s("project"),
            budget: s("budget"),
            jube_file: s("jube_file"),
            variant: s("variant"),
            usecase: s("usecase"),
            extra_tags: inputs
                .get("tags")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            stage: inputs.str_of("stage").unwrap_or("2026").to_string(),
            launcher,
            record: inputs.bool_of("record").unwrap_or(true)
                && inputs.str_of("record") != Some("false"),
            freq_mhz: if freq > 0.0 { Some(freq) } else { None },
            nodes_override: inputs.u64_of("nodes").unwrap_or(0),
            in_command: inputs.str_of("in_command").map(str::to_string),
        })
    }

    /// The harness tags of this run: machine + variant + usecase + extras
    /// (paper §II-B: "System Name" and "Variant Tag").
    pub fn tags(&self) -> Vec<String> {
        let mut t = vec![self.machine.clone()];
        if !self.variant.is_empty() {
            t.push(self.variant.clone());
        }
        if !self.usecase.is_empty() {
            t.push(self.usecase.clone());
        }
        t.extend(self.extra_tags.iter().cloned());
        t
    }
}

/// Compose the run-level cache key: everything that determines the whole
/// assembled report. A hit replays the stored report + CSV byte-for-byte
/// with **zero** batch submissions; a miss falls through to step-level
/// caching inside the executor.
fn run_cache_key(
    repo: &BenchmarkRepo,
    spec_text: &str,
    tags: &[String],
    params: &ExecutionParams,
    stage: &SoftwareStage,
    account_identity: &str,
    env_fp: &str,
    engine_fp: &str,
) -> CacheKey {
    CacheKeyBuilder::new("run", &params.prefix)
        .ident("machine", &params.machine)
        .ident("jube_file", &params.jube_file)
        .field("commit", &repo.commit)
        .field("definition", spec_text)
        .field("tags", tags.join("\n"))
        .field("stage", &stage.name)
        .field("environment", env_fp)
        .field("account", account_identity)
        .field("launcher", params.launcher.name())
        .field(
            "freq_mhz",
            params
                .freq_mhz
                .map(|f| format!("{f:.3}"))
                .unwrap_or_default(),
        )
        .field(
            "in_command",
            params.in_command.clone().unwrap_or_default(),
        )
        .field("nodes_override", params.nodes_override.to_string())
        .field("engine", engine_fp)
        .build()
}

/// What an [`ExecutionTask`] is doing after a poll.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecPoll {
    /// A remote step is in flight as batch job `jobid` on `machine`;
    /// poll again with `completed = Some(jobid)` once that job reaches a
    /// terminal state.
    Waiting { machine: String, jobid: u64 },
    /// The orchestrator finished (successfully or not); take the CI jobs
    /// and report with [`ExecutionTask::into_result`].
    Done,
}

enum Phase {
    Start,
    Executing,
    Done,
}

/// Persistent executor state threaded across polls: the borrowing
/// [`BatchStepExecutor`] is rebuilt per poll, but exported environment,
/// provenance, and the in-flight step survive between yields.
#[derive(Default)]
struct StepExecState {
    injected_commands: Vec<String>,
    provenance: Vec<StepProvenance>,
    pending: Option<PendingStep>,
}

/// One resumable run of the execution orchestrator.
///
/// Create with [`ExecutionTask::new`], then [`ExecutionTask::poll`]
/// until `ExecPoll::Done`. Between a `Waiting` result and the matching
/// completion the task holds no borrows of the world, so any number of
/// tasks can be in flight concurrently — that is the whole point.
pub struct ExecutionTask {
    params: ExecutionParams,
    pipeline_id: u64,
    phase: Phase,
    jobs: Vec<CiJob>,
    report: Option<Report>,
    execute_job: Option<CiJob>,
    cursor: Option<RunCursor>,
    exec_state: StepExecState,
    stage: SoftwareStage,
    tags: Vec<String>,
    benchmark_name: String,
    engine_fp: String,
    run_key: Option<CacheKey>,
    start_time: SimTime,
}

impl ExecutionTask {
    pub fn new(params: ExecutionParams, pipeline_id: u64) -> ExecutionTask {
        let stage =
            SoftwareStage::by_name(&params.stage).unwrap_or_else(SoftwareStage::stage_2026);
        let tags = params.tags();
        ExecutionTask {
            params,
            pipeline_id,
            phase: Phase::Start,
            jobs: Vec::new(),
            report: None,
            execute_job: None,
            cursor: None,
            exec_state: StepExecState::default(),
            stage,
            tags,
            benchmark_name: String::new(),
            engine_fp: String::new(),
            run_key: None,
            start_time: SimTime::default(),
        }
    }

    pub fn machine(&self) -> &str {
        &self.params.machine
    }

    /// Advance as far as possible. `rng` selects the noise stream: a
    /// per-pipeline stream for concurrent campaigns, or `None` to use
    /// the world PRNG (the legacy sequential behaviour). Pass the jobid
    /// of the completed awaited job in `completed` when resuming.
    pub fn poll(
        &mut self,
        world: &mut World,
        repo: &mut BenchmarkRepo,
        rng: Option<&mut Prng>,
        completed: Option<u64>,
    ) -> ExecPoll {
        if matches!(self.phase, Phase::Start) {
            if let Some(done) = self.start(world, repo) {
                return done;
            }
            self.phase = Phase::Executing;
        }
        if matches!(self.phase, Phase::Done) {
            return ExecPoll::Done;
        }
        // Phase::Executing: drive the cursor through an executor that
        // borrows the world for exactly this poll.
        let poll = {
            let World {
                cluster,
                batch,
                engine,
                rng: world_rng,
                calibration,
                cache,
                ..
            } = world;
            let batch = match batch.get_mut(&self.params.machine) {
                Some(b) => b,
                None => {
                    self.abort("machine disappeared mid-run");
                    return ExecPoll::Done;
                }
            };
            let rng = match rng {
                Some(r) => r,
                None => world_rng,
            };
            let mut exec = BatchStepExecutor {
                cluster,
                batch,
                engine: engine.as_mut(),
                rng,
                calibration: *calibration,
                machine: self.params.machine.clone(),
                queue: self.params.queue.clone(),
                project: self.params.project.clone(),
                budget: self.params.budget.clone(),
                stage: self.stage.clone(),
                launcher: self.params.launcher,
                freq_mhz: self.params.freq_mhz,
                injected_commands: std::mem::take(&mut self.exec_state.injected_commands),
                nodes_override: self.params.nodes_override,
                walltime_s: 7200,
                benchmark: self.benchmark_name.clone(),
                cache: cache.as_mut(),
                engine_fingerprint: self.engine_fp.clone(),
                provenance: std::mem::take(&mut self.exec_state.provenance),
                pending: self.exec_state.pending.take(),
            };
            let cursor = self.cursor.as_mut().expect("cursor live while executing");
            let poll = match completed {
                // Before collecting, triage the terminal state: a
                // preempted job is followed to its requeued twin, a
                // node-failed one is resubmitted with bounded backoff —
                // in both cases the cursor retargets and keeps waiting.
                Some(jobid) => match exec.triage(jobid) {
                    CollectTriage::Resubmitted { jobid: next } => {
                        cursor.retarget(jobid, next);
                        CursorPoll::Waiting { jobid: next }
                    }
                    CollectTriage::Proceed => cursor.complete(jobid, &mut exec),
                },
                None => cursor.poll(&mut exec),
            };
            self.exec_state.injected_commands = exec.injected_commands;
            self.exec_state.provenance = exec.provenance;
            self.exec_state.pending = exec.pending;
            poll
        };
        match poll {
            CursorPoll::Waiting { jobid } => ExecPoll::Waiting {
                machine: self.params.machine.clone(),
                jobid,
            },
            CursorPoll::Finished => {
                self.finalize(world, repo);
                ExecPoll::Done
            }
        }
    }

    /// Give up on an in-flight execution (e.g. the awaited job can never
    /// complete); the execute stage is recorded as failed.
    pub fn abort(&mut self, reason: &str) {
        if let Some(mut execute) = self.execute_job.take() {
            execute.log_line(format!("aborted: {reason}"));
            execute.state = CiJobState::Failed;
            self.jobs.push(execute);
        }
        self.cursor = None;
        self.phase = Phase::Done;
    }

    /// The accumulated CI jobs and (on success) the protocol report.
    pub fn into_result(self) -> (Vec<CiJob>, Option<Report>) {
        (self.jobs, self.report)
    }

    /// Setup stage + run-level cache replay + cursor construction.
    /// Returns `Some(ExecPoll::Done)` when the task short-circuits
    /// (setup failure, bad spec, or a full cache replay).
    fn start(&mut self, world: &mut World, repo: &mut BenchmarkRepo) -> Option<ExecPoll> {
        let params = self.params.clone();

        // ---- stage 1: setup (runner preflight) ------------------------
        let mut setup = CiJob::new(world.ids.job_id(), &format!("{}.setup", params.prefix));
        setup.state = CiJobState::Running;
        let runner = Runner::new(&params.machine);
        let preflight = match world.batch.get(&params.machine) {
            Some(bs) => runner
                .setup(bs, &params.project, &params.budget, &params.queue)
                .map_err(|e| e.to_string()),
            None => Err(format!("no batch system for machine '{}'", params.machine)),
        };
        match &preflight {
            Ok(()) => {
                setup.log_line(format!(
                    "environment ready on {} (queue {}, project {}, budget {})",
                    params.machine, params.queue, params.project, params.budget
                ));
                setup.state = CiJobState::Success;
            }
            Err(e) => {
                setup.log_line(format!("setup failed: {e}"));
                setup.state = CiJobState::Failed;
            }
        }
        let setup_ok = setup.state == CiJobState::Success;
        self.jobs.push(setup);
        if !setup_ok {
            self.phase = Phase::Done;
            return Some(ExecPoll::Done);
        }

        // ---- stage 2: execute -----------------------------------------
        let mut execute = CiJob::new(world.ids.job_id(), &format!("{}.execute", params.prefix));
        execute.state = CiJobState::Running;
        let spec = match repo.benchmark_spec(&params.jube_file) {
            Ok(s) => s,
            Err(e) => {
                execute.log_line(e);
                execute.state = CiJobState::Failed;
                self.jobs.push(execute);
                self.phase = Phase::Done;
                return Some(ExecPoll::Done);
            }
        };
        let stage = self.stage.clone();
        self.start_time = world
            .batch
            .get(&params.machine)
            .map(|b| b.now())
            .unwrap_or_default();

        // ---- incremental execution: run-level replay ------------------
        let spec_text = repo.file(&params.jube_file).unwrap_or_default().to_string();
        self.engine_fp = world
            .engine
            .as_ref()
            .map(|e| e.manifest.fingerprint())
            .unwrap_or_else(|| "analytic".to_string());
        let account_identity =
            runner.environment_fingerprint(&params.project, &params.budget, &params.queue);
        let run_env_fp = world
            .cluster
            .env_at(&params.machine, &stage, self.start_time)
            .map(|e| env_fingerprint(&e))
            .unwrap_or_else(|| "unresolved-env".into());
        let run_key = run_cache_key(
            repo,
            &spec_text,
            &self.tags,
            &params,
            &stage,
            &account_identity,
            &run_env_fp,
            &self.engine_fp,
        );
        if let Some(cache) = world.cache.as_mut() {
            let (status, doc) = cache.lookup(&run_key, "report");
            if status == CacheOutcome::Hit {
                if let Some(doc) = doc {
                    if let Ok(report) = Report::parse(&doc) {
                        let csv = cache
                            .get("csv", &run_key.digest)
                            .unwrap_or_default()
                            .to_string();
                        // replay the cold run's per-step provenance (real
                        // step digests), re-labelled as hits; fall back to
                        // step names from the spec if the sidecar is absent
                        let mut prov: Vec<StepProvenance> = cache
                            .get("prov", &run_key.digest)
                            .map(crate::protocol::parse_provenance)
                            .unwrap_or_default();
                        for s in &mut prov {
                            s.status = CacheOutcome::Hit;
                        }
                        if prov.is_empty() {
                            prov = spec
                                .steps
                                .iter()
                                .filter(|s| s.remote)
                                .map(|s| {
                                    StepProvenance::new(
                                        &s.name,
                                        &run_key.digest,
                                        CacheOutcome::Hit,
                                    )
                                })
                                .collect();
                        }
                        execute.log_line(format!(
                            "cache hit: replayed {} data entries, 0 batch jobs submitted",
                            report.data.len()
                        ));
                        execute.add_artifact("results.csv", &csv);
                        execute.add_artifact("report.json", &doc);
                        execute.add_artifact("cache.json", &provenance_document(&prov));
                        execute.output = Json::obj()
                            .set("points", report.data.len())
                            .set(
                                "succeeded",
                                report.data.iter().filter(|e| e.success).count(),
                            )
                            .set("cache", "hit");
                        execute.provenance = prov;
                        execute.state = CiJobState::Success;
                        if crate::obs::tracing() {
                            crate::obs::trace::instant(
                                &params.machine,
                                "cache-replay",
                                self.start_time,
                                crate::obs::trace::args(&[
                                    ("pipeline", self.pipeline_id.to_string()),
                                    ("prefix", params.prefix.clone()),
                                    ("points", report.data.len().to_string()),
                                ]),
                            );
                        }
                        self.jobs.push(execute);
                        if params.record {
                            let end_time = world
                                .batch
                                .get(&params.machine)
                                .map(|b| b.now())
                                .unwrap_or_default();
                            let mut record = CiJob::new(
                                world.ids.job_id(),
                                &format!("{}.record", params.prefix),
                            );
                            record.state = CiJobState::Running;
                            let base = format!("{}/{}", params.prefix, self.pipeline_id);
                            let commit_id = repo.store.commit(
                                "exacb.data",
                                &[
                                    (format!("{base}/report.json"), doc),
                                    (format!("{base}/results.csv"), csv),
                                ],
                                &format!(
                                    "record pipeline {} (cache replay)",
                                    self.pipeline_id
                                ),
                                end_time,
                            );
                            record.log_line(format!(
                                "committed {commit_id} to exacb.data at {base}/"
                            ));
                            record.state = CiJobState::Success;
                            self.jobs.push(record);
                        }
                        self.report = Some(report);
                        self.phase = Phase::Done;
                        return Some(ExecPoll::Done);
                    }
                }
            }
        }
        self.run_key = Some(run_key);

        // ---- cold (or partially warm) execution: build the cursor -----
        let cursor = match RunCursor::new(&spec, &self.tags) {
            Ok(c) => c,
            Err(e) => {
                execute.log_line(format!("harness: {e}"));
                execute.state = CiJobState::Failed;
                self.jobs.push(execute);
                self.phase = Phase::Done;
                return Some(ExecPoll::Done);
            }
        };
        self.benchmark_name = spec.name.clone();
        self.exec_state = StepExecState {
            injected_commands: params.in_command.iter().cloned().collect(),
            provenance: Vec::new(),
            pending: None,
        };
        self.cursor = Some(cursor);
        self.execute_job = Some(execute);
        None
    }

    /// The cursor finished every point: assemble the protocol report,
    /// cache it, and run the record stage.
    fn finalize(&mut self, world: &mut World, repo: &mut BenchmarkRepo) {
        let params = self.params.clone();
        let mut execute = self.execute_job.take().expect("execute job live");
        let outcomes = self
            .cursor
            .take()
            .expect("cursor live while executing")
            .into_outcomes();
        let step_provenance = std::mem::take(&mut self.exec_state.provenance);

        let n_ok = outcomes.iter().filter(|o| o.success).count();
        execute.log_line(format!(
            "{}/{} parameter points succeeded",
            n_ok,
            outcomes.len()
        ));
        let prov_doc = provenance_document(&step_provenance);
        if world.cache.is_some() {
            let (h, m, i) = crate::protocol::provenance::tally(&step_provenance);
            execute.log_line(format!("cache: {h} hit / {m} miss / {i} invalidated"));
            execute.add_artifact("cache.json", &prov_doc);
        }

        // ---- assemble the protocol report -----------------------------
        let end_time = world
            .batch
            .get(&params.machine)
            .map(|b| b.now())
            .unwrap_or_default();
        let machine_version = world
            .cluster
            .machine(&params.machine)
            .map(|m| m.version.clone())
            .unwrap_or_default();
        let mut parameter = Json::obj()
            .set("variant", params.variant.as_str())
            .set("usecase", params.usecase.as_str())
            .set("tags", self.tags.clone())
            .set("launcher", params.launcher.name());
        if let Some(f) = params.freq_mhz {
            parameter.insert("freq_mhz", f);
        }
        if let Some(cmd) = &params.in_command {
            parameter.insert("in_command", cmd.as_str());
        }
        let report = Report {
            reporter: Reporter {
                tool: "exacb".into(),
                tool_version: env!("CARGO_PKG_VERSION").into(),
                pipeline_id: self.pipeline_id,
                ci_job_id: execute.id,
                commit: repo.commit.clone(),
                user: "exacb-bot".into(),
                system: params.machine.clone(),
                system_version: machine_version,
                timestamp: end_time.iso8601(),
                seed: world.seed,
            },
            parameter,
            experiment: Experiment {
                system: params.machine.clone(),
                software_version: format!("stage-{}", self.stage.name),
                variant: params.variant.clone(),
                usecase: params.usecase.clone(),
                timestamp: self.start_time.iso8601(),
            },
            data: outcomes.iter().map(|o| o.to_data_entry()).collect(),
        };
        let csv = results_csv(&[&report]);
        let report_doc = report.to_document();
        execute.add_artifact("results.csv", &csv);
        execute.add_artifact("report.json", &report_doc);
        execute.output = Json::obj()
            .set("points", outcomes.len())
            .set("succeeded", n_ok);
        execute.state = if n_ok == outcomes.len() && !outcomes.is_empty() {
            CiJobState::Success
        } else {
            CiJobState::Failed
        };
        execute.provenance = step_provenance;
        let execute_ok = execute.state == CiJobState::Success;
        // machine-local clocks at the execute stage's start and finish:
        // both are functions of that machine's own job sequence (pinned
        // byte-identical across drivers by the sacct contract), unlike
        // the max-over-machines `world.now()`
        if crate::obs::tracing() {
            crate::obs::trace::span(
                &params.machine,
                "execute",
                self.start_time,
                end_time,
                crate::obs::trace::args(&[
                    ("pipeline", self.pipeline_id.to_string()),
                    ("prefix", params.prefix.clone()),
                    ("points", outcomes.len().to_string()),
                    ("ok", execute_ok.to_string()),
                ]),
            );
        }
        self.jobs.push(execute);

        // Only fully-successful runs enter the run-level cache: a failure
        // must re-execute on the next attempt, never replay.
        if execute_ok {
            if let (Some(cache), Some(run_key)) =
                (world.cache.as_mut(), self.run_key.as_ref())
            {
                cache.insert(run_key, "report", &report_doc);
                cache.insert_aux("csv", &run_key.digest, &csv);
                cache.insert_aux("prov", &run_key.digest, &prov_doc);
            }
        }

        // ---- stage 3: record ------------------------------------------
        if params.record {
            let mut record =
                CiJob::new(world.ids.job_id(), &format!("{}.record", params.prefix));
            record.state = CiJobState::Running;
            let base = format!("{}/{}", params.prefix, self.pipeline_id);
            let commit_id = repo.store.commit(
                "exacb.data",
                &[
                    (format!("{base}/report.json"), report_doc),
                    (format!("{base}/results.csv"), csv),
                ],
                &format!("record pipeline {}", self.pipeline_id),
                end_time,
            );
            record.log_line(format!("committed {commit_id} to exacb.data at {base}/"));
            record.state = CiJobState::Success;
            self.jobs.push(record);
        }

        self.report = Some(report);
        self.phase = Phase::Done;
    }
}

/// Run the execution orchestrator for one repository, blocking until it
/// completes: a thin drive-to-completion wrapper over [`ExecutionTask`]
/// that drains the machine's batch system at every yield — exactly the
/// pre-event-loop behaviour, preserved for every existing caller.
pub fn run_execution(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    params: &ExecutionParams,
    pipeline_id: u64,
) -> (Vec<CiJob>, Option<Report>) {
    let mut task = ExecutionTask::new(params.clone(), pipeline_id);
    let mut completed = None;
    loop {
        match task.poll(world, repo, None, completed.take()) {
            ExecPoll::Done => break,
            ExecPoll::Waiting { machine, jobid } => {
                if let Some(bs) = world.batch.get_mut(&machine) {
                    bs.run_until_idle();
                }
                completed = Some(jobid);
            }
        }
    }
    task.into_result()
}
