//! The Execution Orchestrator (paper §V-A.1) and its feature-injection
//! variant (§V-A.3).
//!
//! Stages, each an individual CI job communicating through artifacts:
//!
//! 1. **setup** — Jacamar-runner preflight: environment, queue, account.
//! 2. **execute** — instantiate the JUBE-style benchmark, run the
//!    parameter study through the batch system, first-line analysis.
//! 3. **record** — assemble the protocol report + Table-I `results.csv`
//!    and (when `record: true`) commit them to the repo's `exacb.data`
//!    branch.

use crate::ci::{CiJob, CiJobState, Runner};
use crate::cluster::SoftwareStage;
use crate::harness::run_benchmark;
use crate::protocol::{
    provenance_document, results_csv, CacheOutcome, Experiment, Report, Reporter,
    StepProvenance,
};
use crate::store::{CacheKey, CacheKeyBuilder};
use crate::util::json::Json;

use super::executor::{env_fingerprint, BatchStepExecutor, Launcher};
use super::repo::BenchmarkRepo;
use super::world::World;

/// Resolved execution inputs (post component-schema validation).
#[derive(Debug, Clone)]
pub struct ExecutionParams {
    pub prefix: String,
    pub machine: String,
    pub queue: String,
    pub project: String,
    pub budget: String,
    pub jube_file: String,
    pub variant: String,
    pub usecase: String,
    pub extra_tags: Vec<String>,
    pub stage: String,
    pub launcher: Launcher,
    pub record: bool,
    pub freq_mhz: Option<f64>,
    pub nodes_override: u64,
    /// Feature injection: command prepended to every remote step.
    pub in_command: Option<String>,
}

impl ExecutionParams {
    /// Build from resolved component inputs.
    pub fn from_inputs(inputs: &Json) -> ExecutionParams {
        let s = |k: &str| inputs.str_of(k).unwrap_or("").to_string();
        let freq = inputs.f64_of("freq_mhz").unwrap_or(0.0);
        ExecutionParams {
            prefix: s("prefix"),
            machine: s("machine"),
            queue: s("queue"),
            project: s("project"),
            budget: s("budget"),
            jube_file: s("jube_file"),
            variant: s("variant"),
            usecase: s("usecase"),
            extra_tags: inputs
                .get("tags")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            stage: inputs.str_of("stage").unwrap_or("2026").to_string(),
            launcher: Launcher::parse(inputs.str_of("launcher").unwrap_or("srun")),
            record: inputs.bool_of("record").unwrap_or(true)
                && inputs.str_of("record") != Some("false"),
            freq_mhz: if freq > 0.0 { Some(freq) } else { None },
            nodes_override: inputs.u64_of("nodes").unwrap_or(0),
            in_command: inputs.str_of("in_command").map(str::to_string),
        }
    }

    /// The harness tags of this run: machine + variant + usecase + extras
    /// (paper §II-B: "System Name" and "Variant Tag").
    pub fn tags(&self) -> Vec<String> {
        let mut t = vec![self.machine.clone()];
        if !self.variant.is_empty() {
            t.push(self.variant.clone());
        }
        if !self.usecase.is_empty() {
            t.push(self.usecase.clone());
        }
        t.extend(self.extra_tags.iter().cloned());
        t
    }
}

/// Compose the run-level cache key: everything that determines the whole
/// assembled report. A hit replays the stored report + CSV byte-for-byte
/// with **zero** batch submissions; a miss falls through to step-level
/// caching inside the executor.
fn run_cache_key(
    repo: &BenchmarkRepo,
    spec_text: &str,
    tags: &[String],
    params: &ExecutionParams,
    stage: &SoftwareStage,
    account_identity: &str,
    env_fp: &str,
    engine_fp: &str,
) -> CacheKey {
    CacheKeyBuilder::new("run", &params.prefix)
        .ident("machine", &params.machine)
        .ident("jube_file", &params.jube_file)
        .field("commit", &repo.commit)
        .field("definition", spec_text)
        .field("tags", tags.join("\n"))
        .field("stage", &stage.name)
        .field("environment", env_fp)
        .field("account", account_identity)
        .field(
            "launcher",
            match params.launcher {
                Launcher::Jpwr => "jpwr",
                Launcher::Srun => "srun",
            },
        )
        .field(
            "freq_mhz",
            params
                .freq_mhz
                .map(|f| format!("{f:.3}"))
                .unwrap_or_default(),
        )
        .field(
            "in_command",
            params.in_command.clone().unwrap_or_default(),
        )
        .field("nodes_override", params.nodes_override.to_string())
        .field("engine", engine_fp)
        .build()
}

/// Run the execution orchestrator for one repository. Returns the CI
/// jobs of this stage and the protocol report (when execution happened).
pub fn run_execution(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    params: &ExecutionParams,
    pipeline_id: u64,
) -> (Vec<CiJob>, Option<Report>) {
    let mut jobs = Vec::new();

    // ---- stage 1: setup (runner preflight) ----------------------------
    let mut setup = CiJob::new(world.ids.job_id(), &format!("{}.setup", params.prefix));
    setup.state = CiJobState::Running;
    let runner = Runner::new(&params.machine);
    let preflight = match world.batch.get(&params.machine) {
        Some(bs) => runner
            .setup(bs, &params.project, &params.budget, &params.queue)
            .map_err(|e| e.to_string()),
        None => Err(format!("no batch system for machine '{}'", params.machine)),
    };
    match &preflight {
        Ok(()) => {
            setup.log_line(format!(
                "environment ready on {} (queue {}, project {}, budget {})",
                params.machine, params.queue, params.project, params.budget
            ));
            setup.state = CiJobState::Success;
        }
        Err(e) => {
            setup.log_line(format!("setup failed: {e}"));
            setup.state = CiJobState::Failed;
        }
    }
    let setup_ok = setup.state == CiJobState::Success;
    jobs.push(setup);
    if !setup_ok {
        return (jobs, None);
    }

    // ---- stage 2: execute ---------------------------------------------
    let mut execute = CiJob::new(world.ids.job_id(), &format!("{}.execute", params.prefix));
    execute.state = CiJobState::Running;
    let spec = match repo.benchmark_spec(&params.jube_file) {
        Ok(s) => s,
        Err(e) => {
            execute.log_line(e);
            execute.state = CiJobState::Failed;
            jobs.push(execute);
            return (jobs, None);
        }
    };
    let stage = SoftwareStage::by_name(&params.stage).unwrap_or_else(SoftwareStage::stage_2026);
    let start_time = world
        .batch
        .get(&params.machine)
        .map(|b| b.now())
        .unwrap_or_default();
    let tags = params.tags();

    // ---- incremental execution: run-level replay ----------------------
    let spec_text = repo.file(&params.jube_file).unwrap_or_default().to_string();
    let engine_fp = world
        .engine
        .as_ref()
        .map(|e| e.manifest.fingerprint())
        .unwrap_or_else(|| "analytic".to_string());
    let account_identity =
        runner.environment_fingerprint(&params.project, &params.budget, &params.queue);
    let run_env_fp = world
        .cluster
        .env_at(&params.machine, &stage, start_time)
        .map(|e| env_fingerprint(&e))
        .unwrap_or_else(|| "unresolved-env".into());
    let run_key = run_cache_key(
        repo,
        &spec_text,
        &tags,
        params,
        &stage,
        &account_identity,
        &run_env_fp,
        &engine_fp,
    );
    if let Some(cache) = world.cache.as_mut() {
        let (status, doc) = cache.lookup(&run_key, "report");
        if status == CacheOutcome::Hit {
            if let Some(doc) = doc {
                if let Ok(report) = Report::parse(&doc) {
                    let csv = cache
                        .get("csv", &run_key.digest)
                        .unwrap_or_default()
                        .to_string();
                    // replay the cold run's per-step provenance (real
                    // step digests), re-labelled as hits; fall back to
                    // step names from the spec if the sidecar is absent
                    let mut prov: Vec<StepProvenance> = cache
                        .get("prov", &run_key.digest)
                        .map(crate::protocol::parse_provenance)
                        .unwrap_or_default();
                    for s in &mut prov {
                        s.status = CacheOutcome::Hit;
                    }
                    if prov.is_empty() {
                        prov = spec
                            .steps
                            .iter()
                            .filter(|s| s.remote)
                            .map(|s| {
                                StepProvenance::new(&s.name, &run_key.digest, CacheOutcome::Hit)
                            })
                            .collect();
                    }
                    execute.log_line(format!(
                        "cache hit: replayed {} data entries, 0 batch jobs submitted",
                        report.data.len()
                    ));
                    execute.add_artifact("results.csv", &csv);
                    execute.add_artifact("report.json", &doc);
                    execute.add_artifact("cache.json", &provenance_document(&prov));
                    execute.output = Json::obj()
                        .set("points", report.data.len())
                        .set(
                            "succeeded",
                            report.data.iter().filter(|e| e.success).count(),
                        )
                        .set("cache", "hit");
                    execute.provenance = prov;
                    execute.state = CiJobState::Success;
                    jobs.push(execute);
                    if params.record {
                        let end_time = world
                            .batch
                            .get(&params.machine)
                            .map(|b| b.now())
                            .unwrap_or_default();
                        let mut record = CiJob::new(
                            world.ids.job_id(),
                            &format!("{}.record", params.prefix),
                        );
                        record.state = CiJobState::Running;
                        let base = format!("{}/{}", params.prefix, pipeline_id);
                        let commit_id = repo.store.commit(
                            "exacb.data",
                            &[
                                (format!("{base}/report.json"), doc),
                                (format!("{base}/results.csv"), csv),
                            ],
                            &format!("record pipeline {pipeline_id} (cache replay)"),
                            end_time,
                        );
                        record.log_line(format!(
                            "committed {commit_id} to exacb.data at {base}/"
                        ));
                        record.state = CiJobState::Success;
                        jobs.push(record);
                    }
                    return (jobs, Some(report));
                }
            }
        }
    }

    // ---- cold (or partially warm) execution ---------------------------
    let exec_result = {
        let batch = world.batch.get_mut(&params.machine).expect("checked above");
        let mut exec = BatchStepExecutor {
            cluster: &world.cluster,
            batch,
            engine: world.engine.as_mut(),
            rng: &mut world.rng,
            calibration: world.calibration,
            machine: params.machine.clone(),
            queue: params.queue.clone(),
            project: params.project.clone(),
            budget: params.budget.clone(),
            stage: stage.clone(),
            launcher: params.launcher,
            freq_mhz: params.freq_mhz,
            injected_commands: params.in_command.iter().cloned().collect(),
            nodes_override: params.nodes_override,
            walltime_s: 7200,
            benchmark: spec.name.clone(),
            cache: world.cache.as_mut(),
            engine_fingerprint: engine_fp.clone(),
            provenance: Vec::new(),
        };
        let result = run_benchmark(&spec, &tags, &mut exec);
        match result {
            Ok(o) => Ok((o, exec.provenance)),
            Err(e) => Err(e),
        }
    };
    let (outcomes, step_provenance) = match exec_result {
        Ok(v) => v,
        Err(e) => {
            execute.log_line(format!("harness: {e}"));
            execute.state = CiJobState::Failed;
            jobs.push(execute);
            return (jobs, None);
        }
    };
    let n_ok = outcomes.iter().filter(|o| o.success).count();
    execute.log_line(format!(
        "{}/{} parameter points succeeded",
        n_ok,
        outcomes.len()
    ));
    let prov_doc = provenance_document(&step_provenance);
    if world.cache.is_some() {
        let (h, m, i) = crate::protocol::provenance::tally(&step_provenance);
        execute.log_line(format!("cache: {h} hit / {m} miss / {i} invalidated"));
        execute.add_artifact("cache.json", &prov_doc);
    }

    // ---- assemble the protocol report ---------------------------------
    let end_time = world
        .batch
        .get(&params.machine)
        .map(|b| b.now())
        .unwrap_or_default();
    let machine_version = world
        .cluster
        .machine(&params.machine)
        .map(|m| m.version.clone())
        .unwrap_or_default();
    let mut parameter = Json::obj()
        .set("variant", params.variant.as_str())
        .set("usecase", params.usecase.as_str())
        .set("tags", tags.clone())
        .set("launcher", match params.launcher {
            Launcher::Jpwr => "jpwr",
            Launcher::Srun => "srun",
        });
    if let Some(f) = params.freq_mhz {
        parameter.insert("freq_mhz", f);
    }
    if let Some(cmd) = &params.in_command {
        parameter.insert("in_command", cmd.as_str());
    }
    let report = Report {
        reporter: Reporter {
            tool: "exacb".into(),
            tool_version: env!("CARGO_PKG_VERSION").into(),
            pipeline_id,
            ci_job_id: execute.id,
            commit: repo.commit.clone(),
            user: "exacb-bot".into(),
            system: params.machine.clone(),
            system_version: machine_version,
            timestamp: end_time.iso8601(),
            seed: world.seed,
        },
        parameter,
        experiment: Experiment {
            system: params.machine.clone(),
            software_version: format!("stage-{}", stage.name),
            variant: params.variant.clone(),
            usecase: params.usecase.clone(),
            timestamp: start_time.iso8601(),
        },
        data: outcomes.iter().map(|o| o.to_data_entry()).collect(),
    };
    let csv = results_csv(&[&report]);
    let report_doc = report.to_document();
    execute.add_artifact("results.csv", &csv);
    execute.add_artifact("report.json", &report_doc);
    execute.output = Json::obj()
        .set("points", outcomes.len())
        .set("succeeded", n_ok);
    execute.state = if n_ok == outcomes.len() && !outcomes.is_empty() {
        CiJobState::Success
    } else {
        CiJobState::Failed
    };
    execute.provenance = step_provenance;
    let execute_ok = execute.state == CiJobState::Success;
    jobs.push(execute);

    // Only fully-successful runs enter the run-level cache: a failure
    // must re-execute on the next attempt, never replay.
    if execute_ok {
        if let Some(cache) = world.cache.as_mut() {
            cache.insert(&run_key, "report", &report_doc);
            cache.insert_aux("csv", &run_key.digest, &csv);
            cache.insert_aux("prov", &run_key.digest, &prov_doc);
        }
    }

    // ---- stage 3: record ----------------------------------------------
    if params.record {
        let mut record = CiJob::new(world.ids.job_id(), &format!("{}.record", params.prefix));
        record.state = CiJobState::Running;
        let base = format!("{}/{}", params.prefix, pipeline_id);
        let commit_id = repo.store.commit(
            "exacb.data",
            &[
                (format!("{base}/report.json"), report_doc),
                (format!("{base}/results.csv"), csv),
            ],
            &format!("record pipeline {pipeline_id}"),
            end_time,
        );
        record.log_line(format!("committed {commit_id} to exacb.data at {base}/"));
        record.state = CiJobState::Success;
        jobs.push(record);
    }

    (jobs, Some(report))
}
