//! The Execution Orchestrator (paper §V-A.1) and its feature-injection
//! variant (§V-A.3).
//!
//! Stages, each an individual CI job communicating through artifacts:
//!
//! 1. **setup** — Jacamar-runner preflight: environment, queue, account.
//! 2. **execute** — instantiate the JUBE-style benchmark, run the
//!    parameter study through the batch system, first-line analysis.
//! 3. **record** — assemble the protocol report + Table-I `results.csv`
//!    and (when `record: true`) commit them to the repo's `exacb.data`
//!    branch.

use crate::ci::{CiJob, CiJobState, Runner};
use crate::cluster::SoftwareStage;
use crate::harness::run_benchmark;
use crate::protocol::{results_csv, Experiment, Report, Reporter};
use crate::util::json::Json;

use super::executor::{BatchStepExecutor, Launcher};
use super::repo::BenchmarkRepo;
use super::world::World;

/// Resolved execution inputs (post component-schema validation).
#[derive(Debug, Clone)]
pub struct ExecutionParams {
    pub prefix: String,
    pub machine: String,
    pub queue: String,
    pub project: String,
    pub budget: String,
    pub jube_file: String,
    pub variant: String,
    pub usecase: String,
    pub extra_tags: Vec<String>,
    pub stage: String,
    pub launcher: Launcher,
    pub record: bool,
    pub freq_mhz: Option<f64>,
    pub nodes_override: u64,
    /// Feature injection: command prepended to every remote step.
    pub in_command: Option<String>,
}

impl ExecutionParams {
    /// Build from resolved component inputs.
    pub fn from_inputs(inputs: &Json) -> ExecutionParams {
        let s = |k: &str| inputs.str_of(k).unwrap_or("").to_string();
        let freq = inputs.f64_of("freq_mhz").unwrap_or(0.0);
        ExecutionParams {
            prefix: s("prefix"),
            machine: s("machine"),
            queue: s("queue"),
            project: s("project"),
            budget: s("budget"),
            jube_file: s("jube_file"),
            variant: s("variant"),
            usecase: s("usecase"),
            extra_tags: inputs
                .get("tags")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            stage: inputs.str_of("stage").unwrap_or("2026").to_string(),
            launcher: Launcher::parse(inputs.str_of("launcher").unwrap_or("srun")),
            record: inputs.bool_of("record").unwrap_or(true)
                && inputs.str_of("record") != Some("false"),
            freq_mhz: if freq > 0.0 { Some(freq) } else { None },
            nodes_override: inputs.u64_of("nodes").unwrap_or(0),
            in_command: inputs.str_of("in_command").map(str::to_string),
        }
    }

    /// The harness tags of this run: machine + variant + usecase + extras
    /// (paper §II-B: "System Name" and "Variant Tag").
    pub fn tags(&self) -> Vec<String> {
        let mut t = vec![self.machine.clone()];
        if !self.variant.is_empty() {
            t.push(self.variant.clone());
        }
        if !self.usecase.is_empty() {
            t.push(self.usecase.clone());
        }
        t.extend(self.extra_tags.iter().cloned());
        t
    }
}

/// Run the execution orchestrator for one repository. Returns the CI
/// jobs of this stage and the protocol report (when execution happened).
pub fn run_execution(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    params: &ExecutionParams,
    pipeline_id: u64,
) -> (Vec<CiJob>, Option<Report>) {
    let mut jobs = Vec::new();

    // ---- stage 1: setup (runner preflight) ----------------------------
    let mut setup = CiJob::new(world.ids.job_id(), &format!("{}.setup", params.prefix));
    setup.state = CiJobState::Running;
    let runner = Runner::new(&params.machine);
    let preflight = match world.batch.get(&params.machine) {
        Some(bs) => runner
            .setup(bs, &params.project, &params.budget, &params.queue)
            .map_err(|e| e.to_string()),
        None => Err(format!("no batch system for machine '{}'", params.machine)),
    };
    match &preflight {
        Ok(()) => {
            setup.log_line(format!(
                "environment ready on {} (queue {}, project {}, budget {})",
                params.machine, params.queue, params.project, params.budget
            ));
            setup.state = CiJobState::Success;
        }
        Err(e) => {
            setup.log_line(format!("setup failed: {e}"));
            setup.state = CiJobState::Failed;
        }
    }
    let setup_ok = setup.state == CiJobState::Success;
    jobs.push(setup);
    if !setup_ok {
        return (jobs, None);
    }

    // ---- stage 2: execute ---------------------------------------------
    let mut execute = CiJob::new(world.ids.job_id(), &format!("{}.execute", params.prefix));
    execute.state = CiJobState::Running;
    let spec = match repo.benchmark_spec(&params.jube_file) {
        Ok(s) => s,
        Err(e) => {
            execute.log_line(e);
            execute.state = CiJobState::Failed;
            jobs.push(execute);
            return (jobs, None);
        }
    };
    let stage = SoftwareStage::by_name(&params.stage).unwrap_or_else(SoftwareStage::stage_2026);
    let start_time = world
        .batch
        .get(&params.machine)
        .map(|b| b.now())
        .unwrap_or_default();
    let tags = params.tags();
    let outcomes = {
        let batch = world.batch.get_mut(&params.machine).expect("checked above");
        let mut exec = BatchStepExecutor {
            cluster: &world.cluster,
            batch,
            engine: world.engine.as_mut(),
            rng: &mut world.rng,
            calibration: world.calibration,
            machine: params.machine.clone(),
            queue: params.queue.clone(),
            project: params.project.clone(),
            budget: params.budget.clone(),
            stage: stage.clone(),
            launcher: params.launcher,
            freq_mhz: params.freq_mhz,
            injected_commands: params.in_command.iter().cloned().collect(),
            nodes_override: params.nodes_override,
            walltime_s: 7200,
            benchmark: spec.name.clone(),
        };
        match run_benchmark(&spec, &tags, &mut exec) {
            Ok(o) => o,
            Err(e) => {
                execute.log_line(format!("harness: {e}"));
                execute.state = CiJobState::Failed;
                jobs.push(execute);
                return (jobs, None);
            }
        }
    };
    let n_ok = outcomes.iter().filter(|o| o.success).count();
    execute.log_line(format!(
        "{}/{} parameter points succeeded",
        n_ok,
        outcomes.len()
    ));

    // ---- assemble the protocol report ---------------------------------
    let end_time = world
        .batch
        .get(&params.machine)
        .map(|b| b.now())
        .unwrap_or_default();
    let machine_version = world
        .cluster
        .machine(&params.machine)
        .map(|m| m.version.clone())
        .unwrap_or_default();
    let mut parameter = Json::obj()
        .set("variant", params.variant.as_str())
        .set("usecase", params.usecase.as_str())
        .set("tags", tags.clone())
        .set("launcher", match params.launcher {
            Launcher::Jpwr => "jpwr",
            Launcher::Srun => "srun",
        });
    if let Some(f) = params.freq_mhz {
        parameter.insert("freq_mhz", f);
    }
    if let Some(cmd) = &params.in_command {
        parameter.insert("in_command", cmd.as_str());
    }
    let report = Report {
        reporter: Reporter {
            tool: "exacb".into(),
            tool_version: env!("CARGO_PKG_VERSION").into(),
            pipeline_id,
            ci_job_id: execute.id,
            commit: repo.commit.clone(),
            user: "exacb-bot".into(),
            system: params.machine.clone(),
            system_version: machine_version,
            timestamp: end_time.iso8601(),
            seed: world.seed,
        },
        parameter,
        experiment: Experiment {
            system: params.machine.clone(),
            software_version: format!("stage-{}", stage.name),
            variant: params.variant.clone(),
            usecase: params.usecase.clone(),
            timestamp: start_time.iso8601(),
        },
        data: outcomes.iter().map(|o| o.to_data_entry()).collect(),
    };
    let csv = results_csv(&[&report]);
    execute.add_artifact("results.csv", &csv);
    execute.add_artifact("report.json", &report.to_document());
    execute.output = Json::obj()
        .set("points", outcomes.len())
        .set("succeeded", n_ok);
    execute.state = if n_ok == outcomes.len() && !outcomes.is_empty() {
        CiJobState::Success
    } else {
        CiJobState::Failed
    };
    let execute_ok = execute.state == CiJobState::Success;
    jobs.push(execute);

    // ---- stage 3: record ----------------------------------------------
    if params.record {
        let mut record = CiJob::new(world.ids.job_id(), &format!("{}.record", params.prefix));
        record.state = CiJobState::Running;
        let base = format!("{}/{}", params.prefix, pipeline_id);
        let commit_id = repo.store.commit(
            "exacb.data",
            &[
                (format!("{base}/report.json"), report.to_document()),
                (format!("{base}/results.csv"), csv),
            ],
            &format!("record pipeline {pipeline_id}"),
            end_time,
        );
        record.log_line(format!("committed {commit_id} to exacb.data at {base}/"));
        record.state = CiJobState::Success;
        jobs.push(record);
    }

    let _ = execute_ok;
    (jobs, Some(report))
}
