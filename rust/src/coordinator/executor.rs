//! The harness→batch-system bridge: exaCB's step executor.
//!
//! Implements [`crate::harness::StepExecutor`]: local steps run on the
//! login node (setup commands, `export` env mutations), remote steps are
//! submitted as batch jobs whose payload runs the application zoo
//! ([`crate::workloads`]) under the resolved machine environment. The
//! jpwr launcher (§VI-B) and feature injection (§V-A.3) both plug in
//! here — *without touching the benchmark definition*, exactly as the
//! paper requires.

use std::collections::BTreeMap;

use crate::cluster::{Cluster, RunEnv, SoftwareStage};
use crate::energy::wrap_with_jpwr;
use crate::harness::{ResolvedStep, StepDispatch, StepDriver, StepExecutor, StepOutcome};
use crate::protocol::{CacheOutcome, StepProvenance};
use crate::runtime::Engine;
use crate::scheduler::{backoff_s, BatchSystem, JobResult, JobSpec, JobState, SubmitError};
use crate::store::{CacheKey, CacheKeyBuilder, ExecutionCache};
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::workloads::{run_command, AppProfile, ExecCtx, HostCalibration};

/// A launcher string the platform configuration does not know.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LauncherError(pub String);

impl std::fmt::Display for LauncherError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown launcher '{}' (expected 'srun' or 'jpwr')",
            self.0
        )
    }
}

impl std::error::Error for LauncherError {}

/// Which launcher wraps the application (JUBE platform configuration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Launcher {
    Srun,
    /// Energy-aware launcher: samples power, adds energy metrics.
    Jpwr,
}

impl Launcher {
    /// Parse a launcher name; anything that is not `srun`/`jpwr` is a
    /// loud error (it used to silently fall back to `Srun`, hiding
    /// typos like `jwpr` until an energy study produced no energy).
    pub fn parse(s: &str) -> Result<Launcher, LauncherError> {
        if s.eq_ignore_ascii_case("jpwr") {
            Ok(Launcher::Jpwr)
        } else if s.eq_ignore_ascii_case("srun") {
            Ok(Launcher::Srun)
        } else {
            Err(LauncherError(s.to_string()))
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Launcher::Jpwr => "jpwr",
            Launcher::Srun => "srun",
        }
    }
}

/// Executor bound to one machine's batch system for one benchmark run.
pub struct BatchStepExecutor<'w> {
    pub cluster: &'w Cluster,
    pub batch: &'w mut BatchSystem,
    pub engine: Option<&'w mut Engine>,
    pub rng: &'w mut Prng,
    pub calibration: HostCalibration,
    pub machine: String,
    pub queue: String,
    pub project: String,
    pub budget: String,
    pub stage: SoftwareStage,
    pub launcher: Launcher,
    pub freq_mhz: Option<f64>,
    /// Feature-injected commands, run before every remote step's own
    /// commands (`in_command` of feature-injection@v3).
    pub injected_commands: Vec<String>,
    /// Node-count override from CI inputs (0 = use the step's parameter).
    pub nodes_override: u64,
    pub walltime_s: u64,
    /// Benchmark name for job naming.
    pub benchmark: String,
    /// Execution cache (None = incremental execution disabled).
    pub cache: Option<&'w mut ExecutionCache>,
    /// Fingerprint of the attached engine artifacts ("analytic" without
    /// PJRT) — part of every step's cache key.
    pub engine_fingerprint: String,
    /// Per-remote-step cache provenance accumulated over this run.
    pub provenance: Vec<StepProvenance>,
    /// The in-flight remote step when driving in two-phase mode: set by
    /// [`StepDriver::dispatch`], consumed by [`StepDriver::collect`].
    pub pending: Option<PendingStep>,
}

/// Bookkeeping for a remote step submitted but not yet collected.
#[derive(Debug, Clone)]
pub struct PendingStep {
    pub step_name: String,
    pub jobid: u64,
    /// Cache key + pre-classified outcome (miss/invalidated) to record
    /// once the job completes; `None` when caching is disabled.
    pub cache_ctx: Option<(CacheKey, CacheOutcome)>,
    /// Retained submission (spec + precomputed payload result) so a
    /// node-failed job can be resubmitted verbatim — no application
    /// re-run, no PRNG re-consumption — plus the retry attempt count.
    pub retry: Option<RetrySpec>,
}

/// The retained submission behind a pending step (DESIGN.md §14).
#[derive(Debug, Clone)]
pub struct RetrySpec {
    pub spec: JobSpec,
    pub result: JobResult,
    pub attempts: u32,
}

/// Bounded retries per step after node failures; past this the step is
/// collected honestly as failed.
pub const FAULT_RETRY_LIMIT: u32 = 2;

/// What an awaited job's completion means for the in-flight step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectTriage {
    /// Terminal in a state the step accepts: collect now.
    Proceed,
    /// The job was preempted-and-requeued by the scheduler, or
    /// node-failed and resubmitted with backoff; the caller must keep
    /// waiting on the new jobid (and retarget its cursor).
    Resubmitted { jobid: u64 },
}

/// Digest of the resolved machine environment at a point in simulated
/// time: machine identity/version + software stage + the effective
/// stage×event factors per metric class. Day-granular system events
/// therefore invalidate cached step results exactly when they change
/// the modelled performance.
pub fn env_fingerprint(env: &RunEnv) -> String {
    use crate::cluster::MetricClass;
    // CacheKeyBuilder's canonical encoding keeps the no-aliasing rule
    // (free-form names vs separators) in one tested place
    CacheKeyBuilder::new("machine-env", &env.machine.name)
        .field("version", &env.machine.version)
        .field("stage", &env.stage.name)
        .field("compute", format!("{:.9}", env.factor(MetricClass::Compute)))
        .field("membw", format!("{:.9}", env.factor(MetricClass::MemBw)))
        .field("network", format!("{:.9}", env.factor(MetricClass::Network)))
        .field("io", format!("{:.9}", env.factor(MetricClass::Io)))
        .build()
        .digest
}

impl<'w> BatchStepExecutor<'w> {
    fn parse_export(cmd: &str) -> Option<(String, String)> {
        let rest = cmd.trim().strip_prefix("export ")?;
        let (k, v) = rest.split_once('=')?;
        Some((k.trim().to_string(), v.trim().to_string()))
    }

    /// Compose the content-addressed cache key of one resolved remote
    /// step — over what the executor actually *consumes*, not the raw
    /// definition. Identity (slot): benchmark + step + machine + the
    /// resolved geometry (nodes / tasks / threads — distinct parameter-
    /// study points stay distinct entries). Inputs (digest): the
    /// substituted command lines (these embed every parameter the step
    /// references) and every execution-context knob that can change the
    /// outcome — environment fingerprint (machine version, software
    /// stage, event factors at submit time), account context, launcher,
    /// frequency, injected features, walltime, engine artifacts.
    /// Consequence: mutating one parameter value re-executes exactly the
    /// steps whose resolved commands change; steps that resolve
    /// identically keep hitting.
    fn step_key(&self, step: &ResolvedStep) -> CacheKey {
        let env_fp = self
            .cluster
            .env_at(&self.machine, &self.stage, self.batch.now())
            .map(|e| env_fingerprint(&e))
            .unwrap_or_else(|| "unresolved-env".into());
        let p = |k: &str| step.point.get(k).cloned().unwrap_or_default();
        CacheKeyBuilder::new(&self.benchmark, &step.name)
            .ident("machine", &self.machine)
            .ident("nodes", self.remote_nodes(step).to_string())
            .ident("taskspernode", p("taskspernode"))
            .ident("threadspertask", p("threadspertask"))
            .field("commands", step.commands.join("\n"))
            .field("environment", env_fp)
            .field(
                "account",
                format!("{}/{}/{}", self.project, self.budget, self.queue),
            )
            .field("launcher", self.launcher.name())
            .field(
                "freq_mhz",
                self.freq_mhz.map(|f| format!("{f:.3}")).unwrap_or_default(),
            )
            .field("injected", self.injected_commands.join("\n"))
            .field("nodes_override", self.nodes_override.to_string())
            .field("walltime_s", self.walltime_s.to_string())
            .field("engine", &self.engine_fingerprint)
            .build()
    }

    fn remote_nodes(&self, step: &ResolvedStep) -> u64 {
        if self.nodes_override > 0 {
            return self.nodes_override;
        }
        step.point
            .get("nodes")
            .and_then(|v| v.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or(1)
    }

    /// Submit one remote step as a batch job. The application model runs
    /// at submit time under the environment of the current simulated
    /// clock (events change on day granularity; queue waits are seconds,
    /// so this is a faithful approximation); the precomputed result
    /// becomes the job payload. Does **not** drain the batch system —
    /// collection happens in [`StepDriver::collect`] after the job's
    /// completion event. A scheduler-outage bounce retries as a deferred
    /// submission with deterministic backoff past the window. Returns
    /// the jobid plus the retained submission for fault retries, or a
    /// ready failed outcome when nothing was submitted.
    fn submit_remote(&mut self, step: &ResolvedStep) -> Result<(u64, RetrySpec), StepOutcome> {
        let nodes = self.remote_nodes(step);
        let m = match self.cluster.machine(&self.machine) {
            Some(m) => m,
            None => {
                return Err(StepOutcome::failed(&format!(
                    "unknown machine '{}'",
                    self.machine
                )))
            }
        };
        let tasks_per_node = step
            .point
            .get("taskspernode")
            .and_then(|v| v.parse().ok())
            .unwrap_or(m.gpus_per_node);
        let threads_per_task = step
            .point
            .get("threadspertask")
            .and_then(|v| v.parse().ok())
            .unwrap_or((m.cores_per_node / m.gpus_per_node.max(1)).max(1));

        // ---- pre-compute the application run under the env at submit
        // time (events change on day granularity; queue waits are
        // seconds, so this is a faithful approximation) --------------
        let now = self.batch.now();
        let env = match self.cluster.env_at(&self.machine, &self.stage, now) {
            Some(e) => e,
            None => return Err(StepOutcome::failed("environment resolution failed")),
        };
        let mut env_vars: BTreeMap<String, String> = BTreeMap::new();
        let mut runtime_s = 0.0;
        let mut success = true;
        let mut files = Vec::new();
        let mut metrics = Json::obj();
        let mut profile = AppProfile::default();
        let injected = self.injected_commands.clone();
        {
            let mut ctx = ExecCtx {
                env: &env,
                nodes,
                tasks_per_node,
                threads_per_task,
                env_vars: BTreeMap::new(),
                freq_mhz: self.freq_mhz,
                calibration: self.calibration,
                rng: self.rng,
                engine: self.engine.as_deref_mut(),
            };
            for cmd in injected.iter().chain(step.commands.iter()) {
                if let Some((k, v)) = Self::parse_export(cmd) {
                    env_vars.insert(k, v);
                    ctx.env_vars = env_vars.clone();
                    continue;
                }
                ctx.env_vars = env_vars.clone();
                let out = run_command(cmd, &mut ctx);
                runtime_s += out.runtime_s;
                success &= out.success;
                files.extend(out.files);
                for (k, v) in out.metrics.as_obj().unwrap_or(&[]) {
                    metrics.insert(k, v.clone());
                }
                if out.runtime_s > 0.0 {
                    profile = out.profile;
                }
            }
        }

        // jpwr launcher wrap (adds energy metrics; §VI-B)
        if self.launcher == Launcher::Jpwr && runtime_s > 0.0 {
            let app_out = crate::workloads::AppOutput {
                runtime_s,
                success,
                metrics: metrics.clone(),
                files: files.clone(),
                profile,
            };
            let freq = self.freq_mhz.unwrap_or(m.power.nominal_mhz);
            let (wrapped, _report) = wrap_with_jpwr(app_out, m, nodes, freq, self.rng);
            metrics = wrapped.metrics;
        }

        let spec = JobSpec {
            name: format!("{}.{}", self.benchmark, step.name),
            account: self.project.clone(),
            budget: self.budget.clone(),
            partition: self.queue.clone(),
            nodes,
            tasks_per_node,
            threads_per_task,
            walltime_limit_s: self.walltime_s,
        };
        let payload_result = JobResult {
            duration_s: runtime_s,
            success,
            metrics: metrics.clone(),
            files: files.clone(),
        };
        let retained = RetrySpec {
            spec: spec.clone(),
            result: payload_result.clone(),
            attempts: 0,
        };
        match self.batch.submit(spec, Box::new(move |_| payload_result)) {
            Ok(id) => Ok((id, retained)),
            Err(SubmitError::Outage { until }) => {
                // The scheduler bounces submissions during the outage
                // window: retry as a deferred submission released a
                // deterministic (content-hashed, bounded) backoff past
                // the window's end instead of failing the step.
                let release =
                    until.add_secs(backoff_s(&self.machine, &retained.spec.name, 0));
                let result = retained.result.clone();
                match self.batch.submit_deferred(
                    release,
                    retained.spec.clone(),
                    Box::new(move |_| result),
                ) {
                    Ok(id) => Ok((id, retained)),
                    Err(e) => Err(StepOutcome::failed(&format!("submit: {e}"))),
                }
            }
            Err(e) => Err(StepOutcome::failed(&format!("submit: {e}"))),
        }
    }

    /// Inspect the awaited job's terminal state before collecting.
    /// Preempted jobs are followed to their requeued twin; node-failed
    /// jobs are resubmitted verbatim (bounded attempts, deterministic
    /// backoff). Anything else — including a node failure past the retry
    /// limit — proceeds to an honest [`StepDriver::collect`].
    pub fn triage(&mut self, jobid: u64) -> CollectTriage {
        let Some(p) = self.pending.as_mut() else {
            return CollectTriage::Proceed;
        };
        if p.jobid != jobid {
            return CollectTriage::Proceed;
        }
        let Some(record) = self.batch.record(jobid) else {
            return CollectTriage::Proceed;
        };
        match record.state {
            JobState::Preempted => {
                // the scheduler already requeued the job; follow its twin
                let twin = record
                    .result
                    .as_ref()
                    .and_then(|r| r.metrics.u64_of("requeued_as"));
                match twin {
                    Some(twin) => {
                        p.jobid = twin;
                        CollectTriage::Resubmitted { jobid: twin }
                    }
                    None => CollectTriage::Proceed,
                }
            }
            JobState::NodeFail => {
                let Some(retry) = p.retry.as_mut() else {
                    return CollectTriage::Proceed;
                };
                if retry.attempts >= FAULT_RETRY_LIMIT {
                    return CollectTriage::Proceed;
                }
                retry.attempts += 1;
                let attempt = retry.attempts;
                let spec = retry.spec.clone();
                let result = retry.result.clone();
                let release = self
                    .batch
                    .now()
                    .add_secs(backoff_s(&self.machine, &spec.name, attempt));
                match self
                    .batch
                    .submit_deferred(release, spec, Box::new(move |_| result))
                {
                    Ok(new_id) => {
                        self.pending.as_mut().expect("pending checked above").jobid = new_id;
                        CollectTriage::Resubmitted { jobid: new_id }
                    }
                    Err(_) => CollectTriage::Proceed,
                }
            }
            _ => CollectTriage::Proceed,
        }
    }
}

impl<'w> StepDriver for BatchStepExecutor<'w> {
    /// Two-phase step execution, phase one: local steps and cache hits
    /// complete synchronously; a remote step is submitted to the batch
    /// system and left in flight (`pending`) for [`Self::collect`] once
    /// the coordinator observes its completion event.
    fn dispatch(&mut self, step: &ResolvedStep) -> StepDispatch {
        if !step.remote {
            // login-node step: setup commands succeed; exports recorded
            // into the injected set so they reach later remote steps.
            // Local steps are cheap and mutate executor state, so they
            // always run (their effect is part of remote-step keys).
            for cmd in &step.commands {
                if Self::parse_export(cmd).is_some() {
                    self.injected_commands.push(cmd.clone());
                }
            }
            return StepDispatch::Done(StepOutcome::local_ok());
        }
        // remote step: consult the execution cache before submitting
        let cache_ctx = if self.cache.is_some() {
            let key = self.step_key(step);
            let (status, doc) = self
                .cache
                .as_deref_mut()
                .expect("checked above")
                .lookup(&key, "step");
            if status == CacheOutcome::Hit {
                if let Some(out) = doc.as_deref().and_then(StepOutcome::from_document) {
                    self.provenance.push(StepProvenance::new(
                        &step.name,
                        &key.digest,
                        CacheOutcome::Hit,
                    ));
                    return StepDispatch::Done(out);
                }
            }
            // a hit whose document fails to parse re-executes as a miss
            let status = if status == CacheOutcome::Hit {
                CacheOutcome::Miss
            } else {
                status
            };
            Some((key, status))
        } else {
            None
        };
        match self.submit_remote(step) {
            Ok((jobid, retained)) => {
                self.pending = Some(PendingStep {
                    step_name: step.name.clone(),
                    jobid,
                    cache_ctx,
                    retry: Some(retained),
                });
                StepDispatch::Submitted(jobid)
            }
            Err(out) => {
                // nothing was submitted; classify for provenance (never
                // inserted into the cache: the outcome is a failure)
                if let Some((key, status)) = cache_ctx {
                    self.provenance
                        .push(StepProvenance::new(&step.name, &key.digest, status));
                }
                StepDispatch::Done(out)
            }
        }
    }

    /// Phase two: rebuild the step outcome from the completed job's
    /// accounting record, record provenance, and cache successes.
    fn collect(&mut self, jobid: u64) -> StepOutcome {
        let (step_name, cache_ctx) = match self.pending.take() {
            Some(p) if p.jobid == jobid => (p.step_name, p.cache_ctx),
            other => {
                self.pending = other;
                return StepOutcome::failed(&format!("no step pending on job {jobid}"));
            }
        };
        let record = match self.batch.record(jobid) {
            Some(r) => r,
            None => return StepOutcome::failed(&format!("no record for job {jobid}")),
        };
        debug_assert!(record.state.is_terminal(), "collect before completion");
        let result = record
            .result
            .clone()
            .unwrap_or_else(|| JobResult::failure("job produced no result"));
        let out = StepOutcome {
            success: record.state == JobState::Completed,
            runtime_s: result.duration_s,
            files: result.files,
            metrics: result.metrics,
            jobid,
            queue: self.queue.clone(),
            nodes: record.spec.nodes,
            tasks_per_node: record.spec.tasks_per_node,
            threads_per_task: record.spec.threads_per_task,
        };
        if let Some((key, status)) = cache_ctx {
            self.provenance
                .push(StepProvenance::new(&step_name, &key.digest, status));
            if out.success {
                if let Some(cache) = self.cache.as_deref_mut() {
                    cache.insert(&key, "step", &out.to_document());
                }
            }
        }
        out
    }
}

impl<'w> StepExecutor for BatchStepExecutor<'w> {
    /// Blocking mode: dispatch, drain this machine's batch system, and
    /// collect in one call — the pre-event-loop behaviour, still used by
    /// direct `run_benchmark` callers and the drive-to-completion
    /// `run_execution` wrapper.
    fn execute(&mut self, step: &ResolvedStep) -> StepOutcome {
        match self.dispatch(step) {
            StepDispatch::Done(out) => out,
            StepDispatch::Submitted(jobid) => {
                // Under an armed fault plan the awaited job may resolve
                // into a requeued twin or a retried resubmission; follow
                // the chain until a state collect() accepts. Bounded:
                // requeued twins are immune and retries are capped.
                let mut jobid = jobid;
                loop {
                    self.batch.run_until_idle();
                    match self.triage(jobid) {
                        CollectTriage::Resubmitted { jobid: next } => jobid = next,
                        CollectTriage::Proceed => break,
                    }
                }
                self.collect(jobid)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{run_benchmark, BenchmarkSpec};
    use crate::scheduler::AccountManager;

    fn setup() -> (Cluster, BatchSystem, Prng) {
        let cluster = Cluster::standard();
        let m = cluster.machine("jedi").unwrap();
        let batch = crate::scheduler::for_machine(m, AccountManager::open("cjsc", "zam", 1e9));
        (cluster, batch, Prng::new(11))
    }

    fn executor<'w>(
        cluster: &'w Cluster,
        batch: &'w mut BatchSystem,
        rng: &'w mut Prng,
    ) -> BatchStepExecutor<'w> {
        BatchStepExecutor {
            cluster,
            batch,
            engine: None,
            rng,
            calibration: HostCalibration::default(),
            machine: "jedi".into(),
            queue: "all".into(),
            project: "cjsc".into(),
            budget: "zam".into(),
            stage: SoftwareStage::stage_2026(),
            launcher: Launcher::Srun,
            freq_mhz: None,
            injected_commands: vec![],
            nodes_override: 0,
            walltime_s: 7200,
            benchmark: "logmap".into(),
            cache: None,
            engine_fingerprint: "analytic".into(),
            provenance: Vec::new(),
            pending: None,
        }
    }

    fn logmap_spec() -> BenchmarkSpec {
        crate::coordinator::repo::BenchmarkRepo::logmap_example("jedi", "all")
            .benchmark_spec("benchmark/jube/logmap.yml")
            .unwrap()
    }

    #[test]
    fn full_benchmark_runs_through_batch_system() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        assert_eq!(outcomes.len(), 1);
        let o = &outcomes[0];
        assert!(o.success);
        assert!(o.runtime_s > 0.0);
        assert!(o.jobid >= 7_700_000);
        assert_eq!(o.queue, "all");
        // harness analysis extracted app_time from logmap.out
        let app_time = o.metrics.f64_of("app_time").unwrap();
        assert!((app_time - o.runtime_s).abs() < 1e-3 * o.runtime_s);
        // batch accounting charged something
        assert!(batch.accounts.total_used() > 0.0);
    }

    #[test]
    fn scaling_tag_produces_six_jobs() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            run_benchmark(&spec, &["scaling".to_string()], &mut exec).unwrap()
        };
        assert_eq!(outcomes.len(), 6);
        let nodes: Vec<u64> = outcomes.iter().map(|o| o.nodes).collect();
        assert_eq!(nodes, vec![1, 2, 4, 8, 16, 32]);
        // larger runs are faster (strong scaling)
        assert!(outcomes[5].runtime_s < outcomes[0].runtime_s);
        assert_eq!(batch.records().len(), 6);
    }

    #[test]
    fn injected_env_reaches_the_application() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = BenchmarkSpec::parse(
            "name: osu\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - osu_bw\n",
        )
        .unwrap();
        let run_with = |inject: Vec<String>,
                        batch: &mut BatchSystem,
                        rng: &mut Prng|
         -> f64 {
            let mut exec = executor(&cluster, batch, rng);
            exec.injected_commands = inject;
            let outcomes = run_benchmark(&spec, &[], &mut exec).unwrap();
            outcomes[0].metrics.f64_of("rndv_thresh").unwrap()
        };
        let default = run_with(vec![], &mut batch, &mut rng);
        let injected = run_with(
            vec!["export UCX_RNDV_THRESH=intra:65536,inter:65536".into()],
            &mut batch,
            &mut rng,
        );
        assert_eq!(default, 8192.0);
        assert_eq!(injected, 65536.0);
    }

    #[test]
    fn jpwr_launcher_adds_energy_metrics() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.launcher = Launcher::Jpwr;
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        let m = &outcomes[0].metrics;
        assert!(m.f64_of("energy_j").unwrap() > 0.0);
        assert!(m.f64_of("avg_power_w").unwrap() > 50.0);
        assert_eq!(m.str_of("launcher"), Some("jpwr"));
    }

    #[test]
    fn step_cache_replays_without_resubmitting() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let mut cache = ExecutionCache::new();

        let cold = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.cache = Some(&mut cache);
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        let jobs_after_cold = batch.records().len();
        assert_eq!(jobs_after_cold, 1);
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.stats.inserts, 1);

        let warm = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.cache = Some(&mut cache);
            let outs = run_benchmark(&spec, &[], &mut exec).unwrap();
            // provenance classifies the remote step as a hit
            assert_eq!(exec.provenance.len(), 1);
            assert_eq!(exec.provenance[0].status, CacheOutcome::Hit);
            outs
        };
        // no new scheduler jobs, identical replayed outcome
        assert_eq!(batch.records().len(), jobs_after_cold);
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(warm[0].runtime_s, cold[0].runtime_s);
        assert_eq!(warm[0].jobid, cold[0].jobid);
        assert_eq!(warm[0].metrics, cold[0].metrics);
    }

    #[test]
    fn changed_injection_invalidates_step() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let mut cache = ExecutionCache::new();
        {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.cache = Some(&mut cache);
            run_benchmark(&spec, &[], &mut exec).unwrap();
        }
        {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.cache = Some(&mut cache);
            exec.injected_commands = vec!["export UCX_RNDV_THRESH=inter:1".into()];
            run_benchmark(&spec, &[], &mut exec).unwrap();
            assert_eq!(exec.provenance[0].status, CacheOutcome::Invalidated);
        }
        assert_eq!(batch.records().len(), 2);
        assert_eq!(cache.stats.invalidated, 1);
    }

    #[test]
    fn launcher_parse_is_strict() {
        assert_eq!(Launcher::parse("srun").unwrap(), Launcher::Srun);
        assert_eq!(Launcher::parse("SRUN").unwrap(), Launcher::Srun);
        assert_eq!(Launcher::parse("JPWR").unwrap(), Launcher::Jpwr);
        let err = Launcher::parse("mpirun").unwrap_err();
        assert!(err.to_string().contains("mpirun"), "{err}");
        assert!(Launcher::parse("").is_err());
    }

    #[test]
    fn two_phase_dispatch_waits_for_completion_event() {
        use crate::harness::{CursorPoll, RunCursor};
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let mut exec = executor(&cluster, &mut batch, &mut rng);
        let mut cursor = RunCursor::new(&spec, &[]).unwrap();
        let CursorPoll::Waiting { jobid } = cursor.poll(&mut exec) else {
            panic!("remote step must submit, not drain");
        };
        // submitted but not yet completed: the cursor yielded instead of
        // draining the batch system
        assert!(!exec.batch.job_state(jobid).unwrap().is_terminal());
        // advance exactly one scheduler event, then resume the cursor
        assert_eq!(exec.batch.advance_next_event(), Some(jobid));
        assert_eq!(cursor.complete(jobid, &mut exec), CursorPoll::Finished);
        let outs = cursor.into_outcomes();
        assert_eq!(outs.len(), 1);
        assert!(outs[0].success);
        assert_eq!(outs[0].jobid, jobid);
        // analysis ran over the replayed job files
        assert!(outs[0].metrics.f64_of("app_time").is_some());
    }

    #[test]
    fn two_phase_collect_matches_blocking_outcome() {
        use crate::harness::{CursorPoll, RunCursor};
        let spec = logmap_spec();
        let blocking = {
            let (cluster, mut batch, mut rng) = setup();
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        let resumed = {
            let (cluster, mut batch, mut rng) = setup();
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            let mut cursor = RunCursor::new(&spec, &[]).unwrap();
            let mut poll = cursor.poll(&mut exec);
            while let CursorPoll::Waiting { jobid } = poll {
                exec.batch.run_until_idle();
                poll = cursor.complete(jobid, &mut exec);
            }
            cursor.into_outcomes()
        };
        assert_eq!(blocking.len(), resumed.len());
        for (a, b) in blocking.iter().zip(&resumed) {
            assert_eq!(a.success, b.success);
            assert_eq!(a.runtime_s, b.runtime_s);
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.jobid, b.jobid);
            assert_eq!((a.nodes, a.tasks_per_node, a.threads_per_task),
                       (b.nodes, b.tasks_per_node, b.threads_per_task));
        }
    }

    #[test]
    fn preempted_step_follows_requeued_twin() {
        let (cluster, mut batch, mut rng) = setup();
        batch.set_fault_plan(Some(crate::scheduler::FaultPlan {
            preempt_rate: 1.0,
            ..crate::scheduler::FaultPlan::seeded("jedi", 5)
        }));
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        // the requeued twin carried the original measurement through
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].success);
        assert!(outcomes[0].metrics.f64_of("app_time").is_some());
        let preempted = batch
            .records()
            .iter()
            .filter(|r| r.state == JobState::Preempted)
            .count();
        assert_eq!(preempted, 1);
    }

    #[test]
    fn node_failed_step_exhausts_retries_honestly() {
        let (cluster, mut batch, mut rng) = setup();
        batch.set_fault_plan(Some(crate::scheduler::FaultPlan {
            node_fail_rate: 1.0,
            ..crate::scheduler::FaultPlan::seeded("jedi", 5)
        }));
        let spec = logmap_spec();
        let mut cache = ExecutionCache::new();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.cache = Some(&mut cache);
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        // recorded as failed — never dropped, never fabricated
        assert!(!outcomes[0].success);
        assert_eq!(outcomes[0].metrics.bool_of("node_fail"), Some(true));
        // the original plus every bounded retry node-failed
        let node_failed = batch
            .records()
            .iter()
            .filter(|r| r.state == JobState::NodeFail)
            .count();
        assert_eq!(node_failed as u32, 1 + FAULT_RETRY_LIMIT);
        // a failed repetition never warms the cache
        assert_eq!(cache.stats.inserts, 0);
    }

    #[test]
    fn bad_queue_fails_cleanly() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.queue = "ghost".into();
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        assert!(!outcomes[0].success);
    }
}
