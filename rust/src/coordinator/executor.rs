//! The harness→batch-system bridge: exaCB's step executor.
//!
//! Implements [`crate::harness::StepExecutor`]: local steps run on the
//! login node (setup commands, `export` env mutations), remote steps are
//! submitted as batch jobs whose payload runs the application zoo
//! ([`crate::workloads`]) under the resolved machine environment. The
//! jpwr launcher (§VI-B) and feature injection (§V-A.3) both plug in
//! here — *without touching the benchmark definition*, exactly as the
//! paper requires.

use std::collections::BTreeMap;

use crate::cluster::{Cluster, SoftwareStage};
use crate::energy::wrap_with_jpwr;
use crate::harness::{ResolvedStep, StepExecutor, StepOutcome};
use crate::runtime::Engine;
use crate::scheduler::{BatchSystem, JobResult, JobSpec};
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::workloads::{run_command, AppProfile, ExecCtx, HostCalibration};

/// Which launcher wraps the application (JUBE platform configuration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Launcher {
    Srun,
    /// Energy-aware launcher: samples power, adds energy metrics.
    Jpwr,
}

impl Launcher {
    pub fn parse(s: &str) -> Launcher {
        if s.eq_ignore_ascii_case("jpwr") {
            Launcher::Jpwr
        } else {
            Launcher::Srun
        }
    }
}

/// Executor bound to one machine's batch system for one benchmark run.
pub struct BatchStepExecutor<'w> {
    pub cluster: &'w Cluster,
    pub batch: &'w mut BatchSystem,
    pub engine: Option<&'w mut Engine>,
    pub rng: &'w mut Prng,
    pub calibration: HostCalibration,
    pub machine: String,
    pub queue: String,
    pub project: String,
    pub budget: String,
    pub stage: SoftwareStage,
    pub launcher: Launcher,
    pub freq_mhz: Option<f64>,
    /// Feature-injected commands, run before every remote step's own
    /// commands (`in_command` of feature-injection@v3).
    pub injected_commands: Vec<String>,
    /// Node-count override from CI inputs (0 = use the step's parameter).
    pub nodes_override: u64,
    pub walltime_s: u64,
    /// Benchmark name for job naming.
    pub benchmark: String,
}

impl<'w> BatchStepExecutor<'w> {
    fn parse_export(cmd: &str) -> Option<(String, String)> {
        let rest = cmd.trim().strip_prefix("export ")?;
        let (k, v) = rest.split_once('=')?;
        Some((k.trim().to_string(), v.trim().to_string()))
    }

    fn remote_nodes(&self, step: &ResolvedStep) -> u64 {
        if self.nodes_override > 0 {
            return self.nodes_override;
        }
        step.point
            .get("nodes")
            .and_then(|v| v.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or(1)
    }

    fn run_remote(&mut self, step: &ResolvedStep) -> StepOutcome {
        let nodes = self.remote_nodes(step);
        let m = match self.cluster.machine(&self.machine) {
            Some(m) => m,
            None => return StepOutcome::failed(&format!("unknown machine '{}'", self.machine)),
        };
        let tasks_per_node = step
            .point
            .get("taskspernode")
            .and_then(|v| v.parse().ok())
            .unwrap_or(m.gpus_per_node);
        let threads_per_task = step
            .point
            .get("threadspertask")
            .and_then(|v| v.parse().ok())
            .unwrap_or((m.cores_per_node / m.gpus_per_node.max(1)).max(1));

        // ---- pre-compute the application run under the env at submit
        // time (events change on day granularity; queue waits are
        // seconds, so this is a faithful approximation) --------------
        let now = self.batch.now();
        let env = match self.cluster.env_at(&self.machine, &self.stage, now) {
            Some(e) => e,
            None => return StepOutcome::failed("environment resolution failed"),
        };
        let mut env_vars: BTreeMap<String, String> = BTreeMap::new();
        let mut runtime_s = 0.0;
        let mut success = true;
        let mut files = Vec::new();
        let mut metrics = Json::obj();
        let mut profile = AppProfile::default();
        let injected = self.injected_commands.clone();
        {
            let mut ctx = ExecCtx {
                env: &env,
                nodes,
                tasks_per_node,
                threads_per_task,
                env_vars: BTreeMap::new(),
                freq_mhz: self.freq_mhz,
                calibration: self.calibration,
                rng: self.rng,
                engine: self.engine.as_deref_mut(),
            };
            for cmd in injected.iter().chain(step.commands.iter()) {
                if let Some((k, v)) = Self::parse_export(cmd) {
                    env_vars.insert(k, v);
                    ctx.env_vars = env_vars.clone();
                    continue;
                }
                ctx.env_vars = env_vars.clone();
                let out = run_command(cmd, &mut ctx);
                runtime_s += out.runtime_s;
                success &= out.success;
                files.extend(out.files);
                for (k, v) in out.metrics.as_obj().unwrap_or(&[]) {
                    metrics.insert(k, v.clone());
                }
                if out.runtime_s > 0.0 {
                    profile = out.profile;
                }
            }
        }

        // jpwr launcher wrap (adds energy metrics; §VI-B)
        if self.launcher == Launcher::Jpwr && runtime_s > 0.0 {
            let app_out = crate::workloads::AppOutput {
                runtime_s,
                success,
                metrics: metrics.clone(),
                files: files.clone(),
                profile,
            };
            let freq = self.freq_mhz.unwrap_or(m.power.nominal_mhz);
            let (wrapped, _report) = wrap_with_jpwr(app_out, m, nodes, freq, self.rng);
            metrics = wrapped.metrics;
        }

        let spec = JobSpec {
            name: format!("{}.{}", self.benchmark, step.name),
            account: self.project.clone(),
            budget: self.budget.clone(),
            partition: self.queue.clone(),
            nodes,
            tasks_per_node,
            threads_per_task,
            walltime_limit_s: self.walltime_s,
        };
        let payload_result = JobResult {
            duration_s: runtime_s,
            success,
            metrics: metrics.clone(),
            files: files.clone(),
        };
        let jobid = match self
            .batch
            .submit(spec, Box::new(move |_| payload_result))
        {
            Ok(id) => id,
            Err(e) => return StepOutcome::failed(&format!("submit: {e}")),
        };
        self.batch.run_until_idle();
        let record = self.batch.record(jobid).expect("record exists");
        let job_success = record.state == crate::scheduler::JobState::Completed;

        StepOutcome {
            success: job_success,
            runtime_s,
            files,
            metrics,
            jobid,
            queue: self.queue.clone(),
            nodes,
            tasks_per_node,
            threads_per_task,
        }
    }
}

impl<'w> StepExecutor for BatchStepExecutor<'w> {
    fn execute(&mut self, step: &ResolvedStep) -> StepOutcome {
        if step.remote {
            self.run_remote(step)
        } else {
            // login-node step: setup commands succeed; exports recorded
            // into the injected set so they reach later remote steps.
            for cmd in &step.commands {
                if Self::parse_export(cmd).is_some() {
                    self.injected_commands.push(cmd.clone());
                }
            }
            StepOutcome::local_ok()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{run_benchmark, BenchmarkSpec};
    use crate::scheduler::AccountManager;

    fn setup() -> (Cluster, BatchSystem, Prng) {
        let cluster = Cluster::standard();
        let m = cluster.machine("jedi").unwrap();
        let batch = crate::scheduler::for_machine(m, AccountManager::open("cjsc", "zam", 1e9));
        (cluster, batch, Prng::new(11))
    }

    fn executor<'w>(
        cluster: &'w Cluster,
        batch: &'w mut BatchSystem,
        rng: &'w mut Prng,
    ) -> BatchStepExecutor<'w> {
        BatchStepExecutor {
            cluster,
            batch,
            engine: None,
            rng,
            calibration: HostCalibration::default(),
            machine: "jedi".into(),
            queue: "all".into(),
            project: "cjsc".into(),
            budget: "zam".into(),
            stage: SoftwareStage::stage_2026(),
            launcher: Launcher::Srun,
            freq_mhz: None,
            injected_commands: vec![],
            nodes_override: 0,
            walltime_s: 7200,
            benchmark: "logmap".into(),
        }
    }

    fn logmap_spec() -> BenchmarkSpec {
        crate::coordinator::repo::BenchmarkRepo::logmap_example("jedi", "all")
            .benchmark_spec("benchmark/jube/logmap.yml")
            .unwrap()
    }

    #[test]
    fn full_benchmark_runs_through_batch_system() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        assert_eq!(outcomes.len(), 1);
        let o = &outcomes[0];
        assert!(o.success);
        assert!(o.runtime_s > 0.0);
        assert!(o.jobid >= 7_700_000);
        assert_eq!(o.queue, "all");
        // harness analysis extracted app_time from logmap.out
        let app_time = o.metrics.f64_of("app_time").unwrap();
        assert!((app_time - o.runtime_s).abs() < 1e-3 * o.runtime_s);
        // batch accounting charged something
        assert!(batch.accounts.total_used() > 0.0);
    }

    #[test]
    fn scaling_tag_produces_six_jobs() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            run_benchmark(&spec, &["scaling".to_string()], &mut exec).unwrap()
        };
        assert_eq!(outcomes.len(), 6);
        let nodes: Vec<u64> = outcomes.iter().map(|o| o.nodes).collect();
        assert_eq!(nodes, vec![1, 2, 4, 8, 16, 32]);
        // larger runs are faster (strong scaling)
        assert!(outcomes[5].runtime_s < outcomes[0].runtime_s);
        assert_eq!(batch.records().len(), 6);
    }

    #[test]
    fn injected_env_reaches_the_application() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = BenchmarkSpec::parse(
            "name: osu\nsteps:\n  - name: execute\n    remote: true\n    do:\n      - osu_bw\n",
        )
        .unwrap();
        let run_with = |inject: Vec<String>,
                        batch: &mut BatchSystem,
                        rng: &mut Prng|
         -> f64 {
            let mut exec = executor(&cluster, batch, rng);
            exec.injected_commands = inject;
            let outcomes = run_benchmark(&spec, &[], &mut exec).unwrap();
            outcomes[0].metrics.f64_of("rndv_thresh").unwrap()
        };
        let default = run_with(vec![], &mut batch, &mut rng);
        let injected = run_with(
            vec!["export UCX_RNDV_THRESH=intra:65536,inter:65536".into()],
            &mut batch,
            &mut rng,
        );
        assert_eq!(default, 8192.0);
        assert_eq!(injected, 65536.0);
    }

    #[test]
    fn jpwr_launcher_adds_energy_metrics() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.launcher = Launcher::Jpwr;
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        let m = &outcomes[0].metrics;
        assert!(m.f64_of("energy_j").unwrap() > 0.0);
        assert!(m.f64_of("avg_power_w").unwrap() > 50.0);
        assert_eq!(m.str_of("launcher"), Some("jpwr"));
    }

    #[test]
    fn bad_queue_fails_cleanly() {
        let (cluster, mut batch, mut rng) = setup();
        let spec = logmap_spec();
        let outcomes = {
            let mut exec = executor(&cluster, &mut batch, &mut rng);
            exec.queue = "ghost".into();
            run_benchmark(&spec, &[], &mut exec).unwrap()
        };
        assert!(!outcomes[0].success);
    }
}
