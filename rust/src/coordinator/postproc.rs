//! Post-processing orchestrators (paper §V-A.2) and the energy-study
//! orchestrator (§VI-B).
//!
//! Each runs as a dedicated CI job, reads protocol reports from the
//! repository's `exacb.data` branch, applies the `analysis` module, and
//! attaches plots + CSV as CI artifacts — fully decoupled from execution
//! ("without having to rerun the benchmarks themselves").

use crate::analysis::{
    analyse, machine_comparison_plot, weak_scaling_plot, ReportSet, StrongScaling, WeakScaling,
};
use crate::ci::{CiJob, CiJobState};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::timeutil::SimTime;

use super::repo::BenchmarkRepo;
use super::world::World;

fn str_list(inputs: &Json, key: &str) -> Vec<String> {
    inputs
        .get(key)
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default()
}

fn u64_list(inputs: &Json, key: &str) -> Vec<u64> {
    inputs
        .get(key)
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_u64).collect())
        .unwrap_or_default()
}

fn load_set(repo: &BenchmarkRepo, prefix: &str, inputs: &Json) -> (ReportSet, usize) {
    // read via the repo's shared snapshot (DESIGN.md §12): analysis
    // jobs dispatched per pipeline stop re-walking the whole branch
    let (set, skipped) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, prefix));
    let set = set.filter_pipelines(&u64_list(inputs, "pipeline"));
    let span = str_list(inputs, "time_span");
    let from = span.first().and_then(|s| SimTime::parse(s));
    let to = span.get(1).and_then(|s| SimTime::parse(s));
    (set.filter_time_span(from, to), skipped)
}

/// How one data entry ended, for the results table's `status` column:
/// failed repetitions are labelled by *why* they failed (the honesty
/// flags of DESIGN.md §14) instead of being folded into — or silently
/// dropped from — the completed counts.
fn entry_status(e: &crate::protocol::DataEntry) -> &'static str {
    if e.success {
        "completed"
    } else if e.metrics.bool_of("node_fail") == Some(true) {
        "node_fail"
    } else if e.metrics.bool_of("preempted") == Some(true) {
        "preempted"
    } else if e.metrics.bool_of("timeout") == Some(true) {
        "timeout"
    } else {
        "failed"
    }
}

/// Canonical cross-repo results table: every data entry of every report
/// across the world's repositories, sorted by a total order independent
/// of pipeline dispatch or store iteration order. Successful entries
/// carry their metric value; failed entries are always listed — with a
/// `status` naming the failure mode — never folded into the completed
/// rows and never dropped. Two campaigns over the same inputs yield
/// byte-identical tables whatever the work-queue interleaving — the
/// aggregation counterpart of the deterministic concurrent collection
/// runner.
pub fn collection_results_table(world: &World, metric: &str) -> Table {
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (name, repo) in &world.repos {
        let (set, _) = repo.with_snapshot(|snap| ReportSet::from_snapshot(snap, ""));
        for (_, r) in &set.reports {
            for e in &r.data {
                let v = if metric == "runtime" {
                    Some(e.runtime)
                } else {
                    e.metric(metric)
                };
                // successful entries without the metric are skipped (an
                // uninstrumented app has nothing to report here); failed
                // entries always get a row so faults stay visible
                let value = match (e.success, v) {
                    (true, Some(v)) => format!("{v:.6}"),
                    (true, None) => continue,
                    (false, Some(v)) => format!("{v:.6}"),
                    (false, None) => "-".to_string(),
                };
                // date (not time-of-day): campaigns trigger daily,
                // and exact submit times depend on how the work
                // queue interleaved jobs on a shared batch system —
                // day granularity is the order-independent identity
                let date = r
                    .experiment
                    .time()
                    .map(|t| t.date_string())
                    .unwrap_or_default();
                rows.push(vec![
                    name.clone(),
                    r.experiment.system.clone(),
                    date,
                    e.nodes.to_string(),
                    value,
                    entry_status(e).to_string(),
                ]);
            }
        }
    }
    rows.sort();
    rows.dedup();
    let mut t = Table::new(&["benchmark", "system", "date", "nodes", metric, "status"]);
    if rows.is_empty() {
        // a labelled empty table, not a bare header: a world with no
        // completed pipelines should read as such, not render as if the
        // campaign produced nothing parseable
        t.push_placeholder("(no completed pipelines)");
        return t;
    }
    for r in rows {
        t.push_row(r);
    }
    t
}

/// Scheduler queue-wait statistics per machine: job count, p50/p95 wait
/// [s], and how many jobs were backfilled (started while an
/// earlier-submitted job of the same partition was still waiting).
///
/// Queue waits include the fixed scheduler-cycle latency, so an idle
/// machine reports p50 ≈ `sched_latency_s`; anything beyond that is real
/// contention. This is the observability counterpart of the concurrent
/// event loop — on the sequential dispatch path every pipeline drains
/// before the next starts, so waits never exceed the latency floor.
pub fn queue_stats(world: &World) -> Table {
    let mut t = Table::new(&[
        "machine",
        "jobs",
        "p50_wait_s",
        "p95_wait_s",
        "backfilled",
        "node_fail",
        "preempted",
        "requeued",
    ]);
    for (name, bs) in &world.batch {
        let waits: Vec<f64> = bs
            .records_iter()
            .filter_map(|r| r.queue_wait_s())
            .map(|w| w as f64)
            .collect();
        if waits.is_empty() {
            continue;
        }
        // One pass in jobid (= submission) order: a started job jumped
        // the queue iff some earlier submission of its partition started
        // *later* than it, or is still pending unstarted. Tracking the
        // running max start and a pending flag per partition gives the
        // same count as the old all-pairs scan in O(records).
        let mut per_partition: std::collections::HashMap<&str, (Option<SimTime>, bool)> =
            std::collections::HashMap::new();
        let mut backfilled = 0usize;
        // fault accounting (DESIGN.md §14): node-failed and preempted
        // jobs by terminal state, requeued twins by the scheduler's
        // `requeued_as` breadcrumb — kept separate from the completed
        // counts instead of being folded into them
        let mut node_fail = 0usize;
        let mut preempted = 0usize;
        let mut requeued = 0usize;
        for r in bs.records_iter() {
            match r.state {
                crate::scheduler::JobState::NodeFail => node_fail += 1,
                crate::scheduler::JobState::Preempted => preempted += 1,
                _ => {}
            }
            if r.result
                .as_ref()
                .map(|res| res.metrics.u64_of("requeued_as").is_some())
                .unwrap_or(false)
            {
                requeued += 1;
            }
            let entry = per_partition
                .entry(r.spec.partition.as_str())
                .or_insert((None, false));
            match r.start_time {
                Some(start) => {
                    if entry.1 || entry.0.map(|s| s > start).unwrap_or(false) {
                        backfilled += 1;
                    }
                    entry.0 = Some(entry.0.map_or(start, |s| s.max(start)));
                }
                None => {
                    if r.state == crate::scheduler::JobState::Pending {
                        entry.1 = true;
                    }
                }
            }
        }
        t.push_row(vec![
            name.clone(),
            waits.len().to_string(),
            format!("{:.0}", crate::util::stats::percentile(&waits, 50.0)),
            format!("{:.0}", crate::util::stats::percentile(&waits, 95.0)),
            backfilled.to_string(),
            node_fail.to_string(),
            preempted.to_string(),
            requeued.to_string(),
        ]);
    }
    if t.rows.is_empty() {
        // no machine ran anything: label it instead of rendering a bare
        // header that reads like a formatting bug
        t.push_placeholder("(no jobs submitted)");
    }
    t
}

fn ev_arg<'a>(e: &'a crate::obs::TraceEvent, key: &str) -> &'a str {
    e.args
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
        .unwrap_or("")
}

fn top_by_duration<'a>(
    events: &'a [crate::obs::TraceEvent],
    name: &str,
    top_n: usize,
) -> Vec<&'a crate::obs::TraceEvent> {
    let mut picked: Vec<&crate::obs::TraceEvent> =
        events.iter().filter(|e| e.name == name && e.dur >= 0).collect();
    // longest first; ties broken by the canonical content order so the
    // table is as replay-stable as the trace it summarizes
    picked.sort_by(|a, b| b.dur.cmp(&a.dur).then_with(|| a.cmp(b)));
    picked.truncate(top_n);
    picked
}

/// The `exacb trace` critical-path views over a drained trace + metrics
/// snapshot: (longest queue waits, slowest execute stages, gate-scheduled
/// repetitions per app). Pure functions of the canonical trace content,
/// so the tables are byte-identical across replays and drivers.
pub fn critical_path_tables(
    events: &[crate::obs::TraceEvent],
    metrics: &crate::obs::MetricsSnapshot,
    top_n: usize,
) -> (Table, Table, Table) {
    let mut waits = Table::new(&["machine", "jobid", "job", "wait_s", "backfilled"]);
    for e in top_by_duration(events, "queue-wait", top_n) {
        waits.push_row(vec![
            e.track.clone(),
            ev_arg(e, "jobid").to_string(),
            ev_arg(e, "job").to_string(),
            e.dur.to_string(),
            ev_arg(e, "backfilled").to_string(),
        ]);
    }
    if waits.rows.is_empty() {
        waits.push_placeholder("(no queue waits recorded)");
    }

    let mut steps = Table::new(&["machine", "jobid", "job", "run_s", "state"]);
    for e in top_by_duration(events, "run", top_n) {
        steps.push_row(vec![
            e.track.clone(),
            ev_arg(e, "jobid").to_string(),
            ev_arg(e, "job").to_string(),
            e.dur.to_string(),
            ev_arg(e, "state").to_string(),
        ]);
    }
    if steps.rows.is_empty() {
        steps.push_placeholder("(no job runs recorded)");
    }

    let mut gates = Table::new(&["app", "pipelines", "gate_rounds", "extra_reps"]);
    use crate::obs::Ctr;
    for app in metrics.apps() {
        gates.push_row(vec![
            app.to_string(),
            metrics.app_counter(app, Ctr::PipelinesRun).to_string(),
            metrics.app_counter(app, Ctr::GateRounds).to_string(),
            metrics.app_counter(app, Ctr::GateReps).to_string(),
        ]);
    }
    if gates.rows.is_empty() {
        gates.push_placeholder("(no app activity recorded)");
    }

    (waits, steps, gates)
}

/// `time-series@v3` (paper §V-A.2): continuous visualisation of selected
/// performance metrics with regression detection (Figs. 3–4).
pub fn run_time_series(world: &mut World, repo: &BenchmarkRepo, inputs: &Json) -> CiJob {
    let prefix = inputs.str_of("prefix").unwrap_or("").to_string();
    let mut job = CiJob::new(world.ids.job_id(), &format!("{prefix}.time-series"));
    job.state = CiJobState::Running;

    let (set, skipped) = load_set(repo, &prefix, inputs);
    if skipped > 0 {
        job.log_line(format!("skipped {skipped} unparseable reports"));
    }
    if set.is_empty() {
        job.log_line("no reports selected");
        job.state = CiJobState::Failed;
        return job;
    }
    let data_labels = str_list(inputs, "data_labels");
    let plot_labels = str_list(inputs, "plot_labels");
    let ylabel = str_list(inputs, "ylabel")
        .first()
        .cloned()
        .unwrap_or_else(|| "value".to_string());

    let mut analyses = Vec::new();
    let mut csv = Table::new(&["label", "points", "mean", "cv", "changepoints", "stable"]);
    let mut verdict = Json::obj();
    for label in &data_labels {
        let a = analyse(&set, label, 8.0);
        csv.push_row(vec![
            label.clone(),
            a.points.len().to_string(),
            format!("{:.4}", a.mean),
            format!("{:.5}", a.cv),
            a.changepoints.len().to_string(),
            a.is_stable().to_string(),
        ]);
        let mut cps = Json::arr();
        for cp in &a.changepoints {
            cps.push(
                Json::obj()
                    .set(
                        "date",
                        a.points
                            .get(cp.index)
                            .map(|(t, _)| t.date_string())
                            .unwrap_or_default(),
                    )
                    .set("before", cp.before)
                    .set("after", cp.after)
                    .set(
                        "kind",
                        if cp.after < cp.before {
                            "regression"
                        } else {
                            "recovery"
                        },
                    ),
            );
        }
        verdict.insert(
            label,
            Json::obj()
                .set("stable", a.is_stable())
                .set("changepoints", cps),
        );
        analyses.push(a);
    }
    let plot = crate::analysis::timeseries::plot(
        &format!("{prefix} time series"),
        &ylabel,
        &analyses,
        &plot_labels,
    );
    job.add_artifact("timeseries.svg", &plot.render_svg());
    job.add_artifact("timeseries.csv", &csv.to_csv());
    job.output = verdict;
    job.log_line(format!(
        "analysed {} labels over {} reports",
        data_labels.len(),
        set.len()
    ));
    job.state = CiJobState::Success;
    job
}

/// `machine-comparison@v3`: strong-scaling comparison across systems
/// (Fig. 5). `selector` lists the store prefixes to compare.
pub fn run_machine_comparison(world: &mut World, repo: &BenchmarkRepo, inputs: &Json) -> CiJob {
    let prefix = inputs.str_of("prefix").unwrap_or("").to_string();
    let mut job = CiJob::new(world.ids.job_id(), &format!("{prefix}.machine-comparison"));
    job.state = CiJobState::Running;
    let metric = inputs.str_of("metric").unwrap_or("runtime").to_string();
    let band = inputs.f64_of("scaling_band").unwrap_or(80.0);

    let mut merged = ReportSet::default();
    for sel in str_list(inputs, "selector") {
        let (set, _) = load_set(repo, &sel, inputs);
        merged.reports.extend(set.reports);
    }
    if merged.is_empty() {
        job.log_line("no reports selected");
        job.state = CiJobState::Failed;
        return job;
    }
    let systems = merged.systems();
    // The paper halves the Ampere result "for easier comparability".
    let halve: Vec<String> = systems
        .iter()
        .filter(|s| {
            world
                .cluster
                .machine(s)
                .map(|m| m.gpu_gen == crate::cluster::GpuGen::Ampere)
                .unwrap_or(false)
        })
        .cloned()
        .collect();
    let plot = machine_comparison_plot(&merged, &systems, &metric, band, &halve);
    job.add_artifact("comparison.svg", &plot.render_svg());

    let mut csv = Table::new(&["system", "nodes", "median_runtime", "speedup", "efficiency"]);
    let mut out = Json::obj();
    for system in &systems {
        if let Some(s) = StrongScaling::from_set(&merged, system, &metric) {
            for (i, &(n, t)) in s.runtimes.iter().enumerate() {
                csv.push_row(vec![
                    system.clone(),
                    n.to_string(),
                    format!("{t:.4}"),
                    format!("{:.3}", s.speedups[i].1),
                    format!("{:.3}", s.efficiencies[i].1),
                ]);
            }
            out.insert(
                system,
                Json::obj().set(
                    "scaling_limit_80pct",
                    s.scaling_limit(band / 100.0)
                        .map(|n| Json::Num(n as f64))
                        .unwrap_or(Json::Null),
                ),
            );
        }
    }
    job.add_artifact("comparison.csv", &csv.to_csv());
    job.output = out;
    job.log_line(format!("compared {} systems", systems.len()));
    job.state = CiJobState::Success;
    job
}

/// `scalability@v3`: single-system strong or weak scaling analysis.
pub fn run_scalability(world: &mut World, repo: &BenchmarkRepo, inputs: &Json) -> CiJob {
    let prefix = inputs.str_of("prefix").unwrap_or("").to_string();
    let mut job = CiJob::new(world.ids.job_id(), &format!("{prefix}.scalability"));
    job.state = CiJobState::Running;
    let metric = inputs.str_of("metric").unwrap_or("runtime").to_string();
    let mode = inputs.str_of("mode").unwrap_or("strong").to_string();
    let selector = inputs.str_of("selector").unwrap_or("").to_string();
    let (set, _) = load_set(repo, &selector, inputs);
    if set.is_empty() {
        job.log_line("no reports selected");
        job.state = CiJobState::Failed;
        return job;
    }

    let mut csv = Table::new(&["nodes", "median", "efficiency"]);
    if mode == "weak" {
        let Some(w) = WeakScaling::from_set(&set, &prefix, &metric) else {
            job.log_line("insufficient points for weak scaling");
            job.state = CiJobState::Failed;
            return job;
        };
        for (i, &(n, t)) in w.runtimes.iter().enumerate() {
            csv.push_row(vec![
                n.to_string(),
                format!("{t:.4}"),
                format!("{:.3}", w.efficiencies[i].1),
            ]);
        }
        job.add_artifact("scaling.svg", &weak_scaling_plot(&[w]).render_svg());
    } else {
        let systems = set.systems();
        let Some(sys) = systems.first() else {
            job.state = CiJobState::Failed;
            return job;
        };
        let Some(s) = StrongScaling::from_set(&set, sys, &metric) else {
            job.log_line("insufficient points for strong scaling");
            job.state = CiJobState::Failed;
            return job;
        };
        for (i, &(n, t)) in s.runtimes.iter().enumerate() {
            csv.push_row(vec![
                n.to_string(),
                format!("{t:.4}"),
                format!("{:.3}", s.efficiencies[i].1),
            ]);
        }
        let plot = machine_comparison_plot(&set, &systems, &metric, 80.0, &[]);
        job.add_artifact("scaling.svg", &plot.render_svg());
    }
    job.add_artifact("scaling.csv", &csv.to_csv());
    job.state = CiJobState::Success;
    job
}

/// `jureap/energy@v3` (paper §VI-B, Fig. 9): run the benchmark through
/// the jpwr launcher at each requested frequency, then analyse the
/// energy-vs-frequency sweep for its sweet spot.
///
/// Since the §11 energy subsystem landed this is a thin wrapper over
/// [`crate::energy::study`], pinned to the legacy sequential dispatch
/// (one point drains before the next submits) — the concurrent path is
/// the `energy-sweep@v1` component. Validation is shared, so an unknown
/// machine fails loudly with its name instead of producing an empty
/// default sweep and a misleading "not enough energy points" failure.
pub fn run_energy_study(
    world: &mut World,
    repo: &mut BenchmarkRepo,
    inputs: &Json,
    pipeline_id: u64,
) -> Vec<CiJob> {
    crate::energy::study::run_sweep(
        world,
        repo,
        inputs,
        pipeline_id,
        "jureap/energy@v3",
        Some(false),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::Trigger;

    /// Repo whose CI config runs logmap daily; we seed its store by
    /// running pipelines, then post-process.
    fn world_with_history(days: i64) -> World {
        let mut world = World::new(7);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        for d in 0..days {
            world.advance_to(SimTime::from_days(d).add_secs(3 * 3600));
            world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
        }
        world
    }

    #[test]
    fn time_series_over_recorded_history() {
        let mut world = world_with_history(10);
        let repo = world.repos.remove("logmap").unwrap();
        let inputs = Json::obj()
            .set("prefix", "jedi.logmap")
            .set("pipeline", Json::arr())
            .set("data_labels", vec!["app_time"])
            .set("ylabel", vec!["time / s"])
            .set("plot_labels", Json::arr())
            .set("time_span", Json::arr());
        let job = run_time_series(&mut world, &repo, &inputs);
        assert_eq!(job.state, CiJobState::Success, "{:?}", job.log);
        assert!(job.artifact("timeseries.svg").unwrap().contains("<svg"));
        let csv = Table::from_csv(job.artifact("timeseries.csv").unwrap()).unwrap();
        assert_eq!(csv.rows[0][0], "app_time");
        assert_eq!(csv.rows[0][1], "10");
        // logmap on an event-free machine is stable
        assert_eq!(csv.rows[0][5], "true");
    }

    #[test]
    fn time_series_respects_time_span() {
        let mut world = world_with_history(10);
        let repo = world.repos.remove("logmap").unwrap();
        let inputs = Json::obj()
            .set("prefix", "jedi.logmap")
            .set("data_labels", vec!["app_time"])
            .set(
                "time_span",
                vec!["2026-01-03".to_string(), "2026-01-05".to_string()],
            );
        let job = run_time_series(&mut world, &repo, &inputs);
        let csv = Table::from_csv(job.artifact("timeseries.csv").unwrap()).unwrap();
        // experiments run at 03:00 daily; the span [Jan 3 00:00, Jan 5
        // 00:00] covers the Jan 3 and Jan 4 runs only
        assert_eq!(csv.rows[0][1], "2");
    }

    #[test]
    fn queue_stats_reports_latency_floor_without_contention() {
        let world = world_with_history(3);
        let t = queue_stats(&world);
        // only jedi ran jobs; idle machines are omitted
        assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
        assert_eq!(t.rows[0][0], "jedi");
        assert_eq!(t.rows[0][1], "3");
        // sequential daily pipelines never contend: every wait is the
        // fixed scheduler latency, and nothing backfills
        let latency = world.batch.get("jedi").unwrap().sched_latency_s;
        assert_eq!(t.rows[0][2], format!("{latency}"));
        assert_eq!(t.rows[0][3], format!("{latency}"));
        assert_eq!(t.rows[0][4], "0");
        // a fault-free history reports zero faults, not blank cells
        assert_eq!(&t.rows[0][5..8], ["0", "0", "0"]);
    }

    /// Satellite regression (§14): a planted node-failure day must show
    /// up as distinct `node_fail` accounting in both postproc surfaces —
    /// never folded into the completed counts, never dropped.
    #[test]
    fn planted_fault_day_is_labelled_not_folded() {
        let mut world = World::new(7);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        // day 0 is clean; day 1 runs under a node-fail-everything plan
        world.advance_to(SimTime::from_days(0).add_secs(3 * 3600));
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();
        let plan = crate::scheduler::FaultPlan {
            node_fail_rate: 1.0,
            ..crate::scheduler::FaultPlan::seeded("jedi", 7)
        };
        world
            .batch
            .get_mut("jedi")
            .unwrap()
            .set_fault_plan(Some(plan));
        world.advance_to(SimTime::from_days(1).add_secs(3 * 3600));
        world.run_pipeline("logmap", Trigger::Scheduled).unwrap();

        let t = queue_stats(&world);
        assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
        let node_fail: usize = t.rows[0][5].parse().unwrap();
        // the faulted step node-fails once plus once per bounded retry
        assert_eq!(
            node_fail as u32,
            1 + crate::coordinator::executor::FAULT_RETRY_LIMIT
        );
        assert_eq!(t.rows[0][6], "0");
        assert_eq!(t.rows[0][7], "0");

        let results = collection_results_table(&world, "app_time");
        let statuses: Vec<&str> = results
            .rows
            .iter()
            .map(|r| r.last().unwrap().as_str())
            .collect();
        assert!(
            statuses.contains(&"completed"),
            "clean day still completed: {statuses:?}"
        );
        assert!(
            statuses.contains(&"node_fail"),
            "faulted day labelled node_fail: {statuses:?}"
        );
    }

    #[test]
    fn queue_stats_labels_empty_world() {
        let world = World::new(1);
        let t = queue_stats(&world);
        assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
        assert!(t.rows[0][0].contains("no jobs submitted"));
        assert_eq!(t.rows[0][1], "-");
        assert_eq!(t.rows[0].len(), t.columns.len());
    }

    #[test]
    fn collection_results_table_labels_empty_world() {
        let world = World::new(1);
        let t = collection_results_table(&world, "runtime");
        assert_eq!(t.rows.len(), 1, "{:?}", t.rows);
        assert!(t.rows[0][0].contains("no completed pipelines"));
        // and stays labelled when repos exist but never ran
        let mut world = World::new(2);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        let t = collection_results_table(&world, "runtime");
        assert!(t.rows[0][0].contains("no completed pipelines"));
    }

    #[test]
    fn empty_prefix_fails() {
        let mut world = world_with_history(1);
        let repo = world.repos.remove("logmap").unwrap();
        let inputs = Json::obj()
            .set("prefix", "nothing.here")
            .set("data_labels", vec!["app_time"]);
        let job = run_time_series(&mut world, &repo, &inputs);
        assert_eq!(job.state, CiJobState::Failed);
    }

    #[test]
    fn energy_study_finds_sweet_spot() {
        let mut world = World::new(9);
        world.add_repo(BenchmarkRepo::logmap_example("jedi", "all"));
        let mut repo = world.repos.remove("logmap").unwrap();
        let inputs = Json::obj()
            .set("prefix", "jedi.energy")
            .set("machine", "jedi")
            .set("queue", "all")
            .set("project", "cjsc")
            .set("budget", "zam")
            .set("jube_file", "benchmark/jube/logmap.yml")
            .set("variant", "large-intensity")
            .set("usecase", "large-workload")
            .set("frequencies", Json::arr());
        let jobs = run_energy_study(&mut world, &mut repo, &inputs, 1);
        let analysis = jobs.last().unwrap();
        assert_eq!(analysis.state, CiJobState::Success, "{:?}", analysis.log);
        let spot = analysis.output.f64_of("sweet_spot_mhz").unwrap();
        let m = world.cluster.machine("jedi").unwrap();
        assert!(
            spot > m.power.min_mhz && spot < m.power.nominal_mhz,
            "interior sweet spot, got {spot}"
        );
        assert!(analysis.output.f64_of("saving_vs_nominal").unwrap() > 0.0);
        // the §11 sidecar rides along on the legacy component too
        let doc = Json::parse(analysis.artifact("energy.json").unwrap()).unwrap();
        assert_eq!(doc.str_of("component"), Some("jureap/energy@v3"));
        assert_eq!(doc.str_of("verdict"), Some("saving"));
    }

    /// Satellite regression: an unknown machine used to produce an empty
    /// default sweep, zero execution jobs, and a misleading "not enough
    /// energy points" failure — it must fail validation loudly with the
    /// machine's name, mirroring `Launcher::parse`.
    #[test]
    fn energy_study_unknown_machine_fails_loudly() {
        let mut world = World::new(9);
        let mut repo = BenchmarkRepo::logmap_example("jedi", "all");
        let inputs = Json::obj()
            .set("prefix", "ghost.energy")
            .set("machine", "ghost")
            .set("queue", "all")
            .set("project", "cjsc")
            .set("budget", "zam")
            .set("jube_file", "benchmark/jube/logmap.yml")
            .set("frequencies", Json::arr());
        let jobs = run_energy_study(&mut world, &mut repo, &inputs, 1);
        assert_eq!(jobs.len(), 1, "one loud validation job, no execution jobs");
        assert_eq!(jobs[0].state, CiJobState::Failed);
        assert!(jobs[0].name.ends_with(".validate"), "{}", jobs[0].name);
        assert!(
            jobs[0].log.iter().any(|l| l.contains("unknown machine 'ghost'")),
            "{:?}",
            jobs[0].log
        );
        assert!(
            !jobs[0].log.iter().any(|l| l.contains("not enough energy points")),
            "{:?}",
            jobs[0].log
        );
        // no batch submissions happened anywhere
        assert!(world.batch.values().all(|b| b.records().is_empty()));
    }
}
