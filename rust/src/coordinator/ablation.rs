//! Integration-mode ablation (paper §III, Fig. 2): centralization ×
//! coupling.
//!
//! The paper argues qualitatively that quadrant ② — *decentralized
//! repositories, strongly coupled to a central external harness* — is
//! the most balanced design, and implements exaCB that way. This module
//! turns the §III prose into a quantitative model and simulates the four
//! quadrants over a collection lifecycle, reproducing the trade-offs as
//! numbers (the Fig. 2 ablation bench).
//!
//! Modelled effects, each traceable to a §III claim:
//! * centralized repos put a **curator-review queue** in front of both
//!   onboarding and benchmark updates ("a contribution threshold ... may
//!   create a bottleneck");
//! * tight coupling propagates harness enhancements **immediately**
//!   ("direct embedding ... ensures immediate propagation"), loose
//!   coupling requires per-repo manual incorporation, "inducing delay or
//!   even omission";
//! * strong coupling to a shared protocol enables **collection-wide
//!   experiments** ("can easily participate in collection-wide
//!   large-scale experiments"), loose coupling makes them "cumbersome";
//! * decentralization preserves **contributor autonomy** (loss of
//!   control under central curation).

use crate::util::prng::Prng;
use crate::util::table::Table;

/// The two §III axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Centralization {
    Central,
    Distributed,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Coupling {
    Tight,
    Loose,
}

/// One quadrant of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntegrationMode {
    pub centralization: Centralization,
    pub coupling: Coupling,
}

impl IntegrationMode {
    /// Quadrant number as labelled in the paper's Fig. 2.
    pub fn quadrant(&self) -> u8 {
        match (self.centralization, self.coupling) {
            (Centralization::Central, Coupling::Tight) => 1,
            (Centralization::Distributed, Coupling::Tight) => 2,
            (Centralization::Central, Coupling::Loose) => 3,
            (Centralization::Distributed, Coupling::Loose) => 4,
        }
    }

    pub fn label(&self) -> &'static str {
        match self.quadrant() {
            1 => "central+tight (monorepo)",
            2 => "distributed+tight (exaCB)",
            3 => "central+loose",
            _ => "distributed+loose",
        }
    }

    pub fn all() -> [IntegrationMode; 4] {
        [
            IntegrationMode {
                centralization: Centralization::Central,
                coupling: Coupling::Tight,
            },
            IntegrationMode {
                centralization: Centralization::Distributed,
                coupling: Coupling::Tight,
            },
            IntegrationMode {
                centralization: Centralization::Central,
                coupling: Coupling::Loose,
            },
            IntegrationMode {
                centralization: Centralization::Distributed,
                coupling: Coupling::Loose,
            },
        ]
    }
}

/// Simulated lifecycle outcome for one mode.
#[derive(Debug, Clone)]
pub struct ModeOutcome {
    pub mode: IntegrationMode,
    /// Mean days from "team wants to onboard" to first green run.
    pub onboarding_days: f64,
    /// Mean days for a harness enhancement to reach all benchmarks.
    pub propagation_days: f64,
    /// Fraction of the collection reachable by a cross-experiment.
    pub cross_experiment_coverage: f64,
    /// Contributor autonomy score in [0, 1].
    pub autonomy: f64,
    /// Composite balance score (geometric mix of normalised criteria).
    pub balance: f64,
}

/// Simulate a collection lifecycle: `n_benchmarks` onboard, then
/// `n_enhancements` harness improvements roll out.
pub fn simulate(
    mode: IntegrationMode,
    n_benchmarks: usize,
    n_enhancements: usize,
    seed: u64,
) -> ModeOutcome {
    let mut rng = Prng::new(seed ^ mode.quadrant() as u64);
    // --- onboarding ------------------------------------------------------
    // base effort: adapting the benchmark to the harness conventions
    let adapt_days = match mode.coupling {
        Coupling::Tight => 3.0, // strict protocol conformance
        Coupling::Loose => 1.5, // "fewer adaptions"
    };
    // curator review queue for centralized collections (serial, grows
    // with queue position)
    let mut onboarding = Vec::with_capacity(n_benchmarks);
    for i in 0..n_benchmarks {
        let review = match mode.centralization {
            Centralization::Central => 2.0 + 0.15 * i as f64, // bottleneck grows
            Centralization::Distributed => 0.5,               // self-service
        };
        onboarding.push(adapt_days * rng.jitter(0.3) + review * rng.jitter(0.2));
    }
    let onboarding_days = onboarding.iter().sum::<f64>() / n_benchmarks as f64;

    // --- enhancement propagation ------------------------------------------
    let mut propagation = Vec::with_capacity(n_enhancements);
    for _ in 0..n_enhancements {
        let d = match mode.coupling {
            // shared harness: next scheduled run picks it up
            Coupling::Tight => rng.range_f64(0.5, 1.5),
            // each repo incorporates manually; some omit for a long time
            Coupling::Loose => {
                let mut worst: f64 = 0.0;
                for _ in 0..n_benchmarks {
                    let per_repo = if rng.bool_with(0.15) {
                        rng.range_f64(30.0, 90.0) // omission
                    } else {
                        rng.range_f64(2.0, 14.0)
                    };
                    worst = worst.max(per_repo);
                }
                worst
            }
        };
        propagation.push(d);
    }
    let propagation_days = propagation.iter().sum::<f64>() / n_enhancements as f64;

    // --- cross-experiment coverage ----------------------------------------
    let cross_experiment_coverage = match mode.coupling {
        Coupling::Tight => 0.97, // protocol-aligned artifacts
        Coupling::Loose => {
            // only repos that happen to follow the guidelines closely
            let mut covered = 0;
            for _ in 0..n_benchmarks {
                if rng.bool_with(0.45) {
                    covered += 1;
                }
            }
            covered as f64 / n_benchmarks as f64
        }
    };

    // --- autonomy -----------------------------------------------------------
    let autonomy = match (mode.centralization, mode.coupling) {
        (Centralization::Distributed, Coupling::Loose) => 0.95,
        (Centralization::Distributed, Coupling::Tight) => 0.80, // own repo, shared protocol
        (Centralization::Central, Coupling::Loose) => 0.45,
        (Centralization::Central, Coupling::Tight) => 0.30, // curators gate everything
    };

    // --- composite balance ---------------------------------------------------
    // normalise each criterion to [0,1], higher is better
    let onb = (10.0 - onboarding_days).clamp(0.0, 10.0) / 10.0;
    let prop = (30.0 - propagation_days).clamp(0.0, 30.0) / 30.0;
    let balance =
        (onb * prop * cross_experiment_coverage * autonomy).powf(0.25);

    ModeOutcome {
        mode,
        onboarding_days,
        propagation_days,
        cross_experiment_coverage,
        autonomy,
        balance,
    }
}

/// Run the full Fig. 2 ablation and render the comparison table.
pub fn run_ablation(
    n_benchmarks: usize,
    n_enhancements: usize,
    seed: u64,
) -> (Vec<ModeOutcome>, Table) {
    let outcomes: Vec<ModeOutcome> = IntegrationMode::all()
        .iter()
        .map(|&m| simulate(m, n_benchmarks, n_enhancements, seed))
        .collect();
    let mut t = Table::new(&[
        "quadrant",
        "mode",
        "onboard_days",
        "propagate_days",
        "cross_experiment",
        "autonomy",
        "balance",
    ]);
    for o in &outcomes {
        t.push_row(vec![
            o.mode.quadrant().to_string(),
            o.mode.label().to_string(),
            format!("{:.2}", o.onboarding_days),
            format!("{:.2}", o.propagation_days),
            format!("{:.2}", o.cross_experiment_coverage),
            format!("{:.2}", o.autonomy),
            format!("{:.3}", o.balance),
        ]);
    }
    (outcomes, t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcomes() -> Vec<ModeOutcome> {
        run_ablation(70, 10, 2026).0
    }

    #[test]
    fn exacb_quadrant_has_best_balance() {
        // §III: "we consider the ... strongly-coupled, but uncentralized
        // approach of 2 the most balanced"
        let outs = outcomes();
        let best = outs
            .iter()
            .max_by(|a, b| a.balance.partial_cmp(&b.balance).unwrap())
            .unwrap();
        assert_eq!(best.mode.quadrant(), 2, "{outs:#?}");
    }

    #[test]
    fn tight_coupling_propagates_fast() {
        let outs = outcomes();
        let tight: Vec<&ModeOutcome> = outs
            .iter()
            .filter(|o| o.mode.coupling == Coupling::Tight)
            .collect();
        let loose: Vec<&ModeOutcome> = outs
            .iter()
            .filter(|o| o.mode.coupling == Coupling::Loose)
            .collect();
        for t in &tight {
            for l in &loose {
                assert!(t.propagation_days < l.propagation_days / 5.0);
                assert!(t.cross_experiment_coverage > l.cross_experiment_coverage);
            }
        }
    }

    #[test]
    fn central_curation_slows_onboarding() {
        let outs = outcomes();
        let central_tight = outs.iter().find(|o| o.mode.quadrant() == 1).unwrap();
        let dist_tight = outs.iter().find(|o| o.mode.quadrant() == 2).unwrap();
        assert!(central_tight.onboarding_days > dist_tight.onboarding_days);
        assert!(central_tight.autonomy < dist_tight.autonomy);
    }

    #[test]
    fn table_renders_all_quadrants() {
        let (_, t) = run_ablation(20, 5, 1);
        assert_eq!(t.len(), 4);
        assert!(t.render().contains("exaCB"));
    }
}
