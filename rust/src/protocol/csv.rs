//! Table I: the harness `results.csv` contract.
//!
//! "The result table shown here represents the minimum required output —
//! a baseline that stays consistent as users add more metrics via
//! additional *additional_metrics* columns." (paper §II-B, Table I)
//!
//! Column order is normative: system, version, queue, variant, jobid,
//! nodes, taskspernode, threadspertasks, runtime, success, then one
//! column per additional metric (sorted by name for stability).

use super::report::Report;
use crate::util::table::Table;

/// The fixed Table-I columns, in order. `threadspertasks` keeps the
/// paper's spelling.
pub const BASE_COLUMNS: [&str; 10] = [
    "system",
    "version",
    "queue",
    "variant",
    "jobid",
    "nodes",
    "taskspernode",
    "threadspertasks",
    "runtime",
    "success",
];

/// Does a `results.csv` text honour the Table-I contract (base columns
/// present, in order, before any additional metric columns)? Both the
/// maturity assessor and the store snapshot judge CSV artifacts through
/// this one predicate, so they can never disagree.
pub fn csv_honours_contract(csv: &str) -> bool {
    let Some(header) = csv.lines().next() else {
        return false;
    };
    let cols: Vec<&str> = header.split(',').collect();
    cols.len() >= BASE_COLUMNS.len() && cols[..BASE_COLUMNS.len()] == BASE_COLUMNS[..]
}

/// Render one or more protocol reports as a Table-I `results.csv` table.
pub fn results_table(reports: &[&Report]) -> Table {
    // Collect the union of metric names across all entries.
    let mut metric_names: Vec<String> = Vec::new();
    for r in reports {
        for e in &r.data {
            for (k, v) in e.metrics.as_obj().unwrap_or(&[]) {
                if v.as_f64().is_some() && !metric_names.contains(k) {
                    metric_names.push(k.clone());
                }
            }
        }
    }
    metric_names.sort();

    let mut columns: Vec<&str> = BASE_COLUMNS.to_vec();
    for m in &metric_names {
        columns.push(m.as_str());
    }
    let mut table = Table::new(&columns);
    for r in reports {
        for e in &r.data {
            let mut row = vec![
                r.experiment.system.clone(),
                r.reporter.system_version.clone(),
                e.queue.clone(),
                r.experiment.variant.clone(),
                e.jobid.to_string(),
                e.nodes.to_string(),
                e.taskspernode.to_string(),
                e.threadspertask.to_string(),
                format_num(e.runtime),
                e.success.to_string(),
            ];
            for m in &metric_names {
                row.push(e.metric(m).map(format_num).unwrap_or_default());
            }
            table.push_row(row);
        }
    }
    table
}

/// Emit Table-I CSV text for a set of reports.
pub fn results_csv(reports: &[&Report]) -> String {
    results_table(reports).to_csv()
}

fn format_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::super::report::{DataEntry, Experiment, Report, Reporter};
    use super::*;
    use crate::util::json::Json;

    fn report_with_metrics() -> Report {
        Report {
            reporter: Reporter {
                tool: "exacb".into(),
                tool_version: "0.1".into(),
                system: "jedi".into(),
                system_version: "2026.1".into(),
                timestamp: "2026-02-01T00:00:00Z".into(),
                ..Default::default()
            },
            parameter: Json::obj(),
            experiment: Experiment {
                system: "jedi".into(),
                variant: "large-intensity".into(),
                ..Default::default()
            },
            data: vec![
                DataEntry {
                    success: true,
                    runtime: 12.5,
                    nodes: 4,
                    taskspernode: 4,
                    threadspertask: 8,
                    jobid: 101,
                    queue: "all".into(),
                    metrics: Json::obj().set("gflops", 830.25),
                },
                DataEntry {
                    success: false,
                    runtime: 0.0,
                    nodes: 8,
                    jobid: 102,
                    queue: "all".into(),
                    ..Default::default()
                },
            ],
        }
    }

    #[test]
    fn table1_contract_columns_in_order() {
        let r = report_with_metrics();
        let t = results_table(&[&r]);
        assert_eq!(
            &t.columns[..10],
            &BASE_COLUMNS
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()[..]
        );
        // additional_metrics columns follow the base set
        assert_eq!(t.columns[10], "gflops");
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn rows_carry_values() {
        let r = report_with_metrics();
        let t = results_table(&[&r]);
        assert_eq!(t.rows[0][0], "jedi");
        assert_eq!(t.rows[0][4], "101");
        assert_eq!(t.rows[0][8], "12.500000");
        assert_eq!(t.rows[0][9], "true");
        assert_eq!(t.rows[0][10], "830.250000");
        // missing metric -> empty cell
        assert_eq!(t.rows[1][10], "");
        assert_eq!(t.rows[1][9], "false");
    }

    #[test]
    fn csv_parses_back() {
        let r = report_with_metrics();
        let csv = results_csv(&[&r]);
        let t = crate::util::table::Table::from_csv(&csv).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.column("system").unwrap(), vec!["jedi", "jedi"]);
    }

    #[test]
    fn multiple_reports_union_metrics() {
        let a = report_with_metrics();
        let mut b = report_with_metrics();
        b.data[0].metrics = Json::obj().set("bw_copy", 1.0);
        let t = results_table(&[&a, &b]);
        assert!(t.col_index("gflops").is_some());
        assert!(t.col_index("bw_copy").is_some());
        assert_eq!(t.len(), 4);
    }
}
