//! Protocol schema migrations (§V-B (a): "a protocol version identifier
//! ... enables backward compatibility and schema evolution").
//!
//! Old reports stored in `exacb.data` branches remain readable forever:
//! `Report::parse` migrates any supported version forward to the current
//! schema before validation. Version history in this reproduction:
//!
//! * **v1** — flat `meta` section (tool/system/timestamp mixed together),
//!   no `experiment` section, runs under `runs` with `runtime_s`.
//! * **v2** — split `reporter`, added `experiment`, renamed `runs` →
//!   `data` but kept `runtime_s` and string booleans for `success`.
//! * **v3** — current: numeric `runtime`, real booleans, `metrics` object.

use super::report::ProtocolError;
use crate::util::json::Json;

/// Migrate a parsed document at `version` to the current schema.
pub fn migrate(doc: &Json, version: u64) -> Result<Json, ProtocolError> {
    let mut v = doc.clone();
    let mut at = version;
    while at < super::report::PROTOCOL_VERSION {
        v = match at {
            1 => v1_to_v2(&v)?,
            2 => v2_to_v3(&v)?,
            other => return Err(ProtocolError::Version(other)),
        };
        at += 1;
    }
    Ok(v)
}

fn v1_to_v2(doc: &Json) -> Result<Json, ProtocolError> {
    let meta = doc.get("meta").cloned().unwrap_or_else(Json::obj);
    let reporter = Json::obj()
        .set("tool", meta.str_of("tool").unwrap_or("unknown"))
        .set("tool_version", meta.str_of("tool_version").unwrap_or("0"))
        .set("system", meta.str_of("system").unwrap_or("unknown"))
        .set("timestamp", meta.str_of("timestamp").unwrap_or(""));
    let experiment = Json::obj()
        .set("system", meta.str_of("system").unwrap_or("unknown"))
        .set("variant", meta.str_of("variant").unwrap_or(""))
        .set("timestamp", meta.str_of("timestamp").unwrap_or(""));
    let runs = doc.get("runs").cloned().unwrap_or_else(Json::arr);
    Ok(Json::obj()
        .set("version", 2u64)
        .set("reporter", reporter)
        .set(
            "parameter",
            doc.get("parameter").cloned().unwrap_or_else(Json::obj),
        )
        .set("experiment", experiment)
        .set("data", runs))
}

fn v2_to_v3(doc: &Json) -> Result<Json, ProtocolError> {
    let mut out = doc.clone();
    out.insert("version", 3u64);
    let data = doc
        .get("data")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .to_vec();
    let mut migrated = Json::arr();
    for entry in data {
        let mut e = entry.clone();
        // runtime_s (v2) -> runtime (v3)
        if e.get("runtime").is_none() {
            if let Some(rt) = e.f64_of("runtime_s") {
                e.insert("runtime", rt);
            }
        }
        // string "true"/"false" success (v2) -> bool (v3)
        if let Some(s) = e.str_of("success").map(str::to_string) {
            e.insert("success", s == "true" || s == "1");
        }
        // loose numeric metrics at top level -> metrics object
        if e.get("metrics").is_none() {
            let known = [
                "success",
                "runtime",
                "runtime_s",
                "nodes",
                "taskspernode",
                "threadspertask",
                "jobid",
                "queue",
            ];
            let extras: Vec<(String, Json)> = e
                .as_obj()
                .unwrap_or(&[])
                .iter()
                .filter(|(k, v)| !known.contains(&k.as_str()) && v.as_f64().is_some())
                .cloned()
                .collect();
            if !extras.is_empty() {
                e.insert("metrics", Json::Obj(extras));
            }
        }
        migrated.push(e);
    }
    out.insert("data", migrated);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::report::Report;
    use super::*;

    #[test]
    fn v2_document_parses() {
        let doc = r#"{
            "version": 2,
            "reporter": {"tool": "exacb", "tool_version": "0.0.2",
                         "system": "jureca", "timestamp": "2026-01-05T00:00:00Z"},
            "experiment": {"system": "jureca", "variant": "single",
                           "timestamp": "2026-01-05T00:00:00Z"},
            "data": [
                {"success": "true", "runtime_s": 33.1, "nodes": 2,
                 "jobid": 5, "queue": "dc-gpu", "bw_copy": 1234.5}
            ]
        }"#;
        let r = Report::parse(doc).unwrap();
        assert_eq!(r.data.len(), 1);
        assert!(r.data[0].success);
        assert!((r.data[0].runtime - 33.1).abs() < 1e-9);
        assert_eq!(r.data[0].metric("bw_copy"), Some(1234.5));
    }

    #[test]
    fn v1_document_parses() {
        let doc = r#"{
            "version": 1,
            "meta": {"tool": "jube-glue", "system": "juwels-booster",
                     "variant": "strong", "timestamp": "2026-01-02T00:00:00Z"},
            "runs": [
                {"success": "false", "runtime_s": 0.0, "nodes": 8}
            ]
        }"#;
        let r = Report::parse(doc).unwrap();
        assert_eq!(r.reporter.tool, "jube-glue");
        assert_eq!(r.experiment.system, "juwels-booster");
        assert_eq!(r.experiment.variant, "strong");
        assert!(!r.data[0].success);
        assert_eq!(r.data[0].nodes, 8);
    }

    #[test]
    fn v1_empty_runs_ok() {
        let doc = r#"{"version": 1,
                      "meta": {"tool":"t","system":"s","timestamp":"2026-01-01"}}"#;
        let r = Report::parse(doc).unwrap();
        assert!(r.data.is_empty());
    }

    #[test]
    fn unknown_old_version_fails() {
        let err = migrate(&Json::obj(), 0).unwrap_err();
        assert!(matches!(err, ProtocolError::Version(0)));
    }
}
