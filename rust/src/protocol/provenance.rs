//! Cache-provenance section of the protocol: how each step of a pipeline
//! execution was satisfied — executed fresh, replayed from the execution
//! cache, or re-executed because a prior entry was invalidated.
//!
//! Provenance is deliberately a *sidecar* document (the `cache.json` CI
//! artifact), never part of the recorded protocol report: a warm replay
//! must reproduce the cold run's `report.json` byte-for-byte, and
//! hit/miss status is volatile by construction.

use crate::util::json::Json;

/// How one step of a run was satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Replayed from the execution cache; no work submitted.
    Hit,
    /// No prior entry under this key; executed and recorded.
    Miss,
    /// A prior entry existed for this step slot but its inputs changed;
    /// executed and the slot re-pointed.
    Invalidated,
    /// Caching disabled (or a local step): executed directly.
    Executed,
}

impl CacheOutcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            CacheOutcome::Hit => "hit",
            CacheOutcome::Miss => "miss",
            CacheOutcome::Invalidated => "invalidated",
            CacheOutcome::Executed => "executed",
        }
    }

    pub fn parse(s: &str) -> Option<CacheOutcome> {
        match s {
            "hit" => Some(CacheOutcome::Hit),
            "miss" => Some(CacheOutcome::Miss),
            "invalidated" => Some(CacheOutcome::Invalidated),
            "executed" => Some(CacheOutcome::Executed),
            _ => None,
        }
    }
}

/// Provenance of one step execution.
#[derive(Debug, Clone, PartialEq)]
pub struct StepProvenance {
    pub step: String,
    /// The cache key digest the step resolved to.
    pub digest: String,
    pub status: CacheOutcome,
}

impl StepProvenance {
    pub fn new(step: &str, digest: &str, status: CacheOutcome) -> StepProvenance {
        StepProvenance {
            step: step.to_string(),
            digest: digest.to_string(),
            status,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("step", self.step.as_str())
            .set("digest", self.digest.as_str())
            .set("status", self.status.as_str())
    }

    pub fn from_json(v: &Json) -> Option<StepProvenance> {
        Some(StepProvenance {
            step: v.str_of("step")?.to_string(),
            digest: v.str_of("digest")?.to_string(),
            status: CacheOutcome::parse(v.str_of("status")?)?,
        })
    }
}

/// Serialize a run's step provenance as the `cache.json` artifact.
pub fn provenance_document(steps: &[StepProvenance]) -> String {
    let mut arr = Json::arr();
    for s in steps {
        arr.push(s.to_json());
    }
    Json::obj().set("version", 1u64).set("steps", arr).pretty()
}

/// Parse a `cache.json` artifact back; steps with unknown status are
/// dropped (forward compatibility).
pub fn parse_provenance(doc: &str) -> Vec<StepProvenance> {
    let Ok(v) = Json::parse(doc) else {
        return Vec::new();
    };
    v.get("steps")
        .and_then(Json::as_arr)
        .map(|steps| steps.iter().filter_map(StepProvenance::from_json).collect())
        .unwrap_or_default()
}

/// Count (hits, misses, invalidated) across step provenance entries.
pub fn tally(steps: &[StepProvenance]) -> (usize, usize, usize) {
    let mut t = (0, 0, 0);
    for s in steps {
        match s.status {
            CacheOutcome::Hit => t.0 += 1,
            CacheOutcome::Miss => t.1 += 1,
            CacheOutcome::Invalidated => t.2 += 1,
            CacheOutcome::Executed => {}
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn document_roundtrip() {
        let steps = vec![
            StepProvenance::new("compile", "aaaa", CacheOutcome::Hit),
            StepProvenance::new("execute", "bbbb", CacheOutcome::Miss),
            StepProvenance::new("execute", "cccc", CacheOutcome::Invalidated),
        ];
        let doc = provenance_document(&steps);
        let back = parse_provenance(&doc);
        assert_eq!(back, steps);
        assert_eq!(tally(&back), (1, 1, 1));
    }

    #[test]
    fn garbage_documents_parse_empty() {
        assert!(parse_provenance("{not json").is_empty());
        assert!(parse_provenance("{}").is_empty());
        // unknown status dropped, known kept
        let doc = r#"{"steps":[{"step":"a","digest":"x","status":"warp"},
                      {"step":"b","digest":"y","status":"hit"}]}"#;
        let back = parse_provenance(doc);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].status, CacheOutcome::Hit);
    }

    #[test]
    fn outcome_strings_roundtrip() {
        for o in [
            CacheOutcome::Hit,
            CacheOutcome::Miss,
            CacheOutcome::Invalidated,
            CacheOutcome::Executed,
        ] {
            assert_eq!(CacheOutcome::parse(o.as_str()), Some(o));
        }
        assert_eq!(CacheOutcome::parse("nope"), None);
    }
}
