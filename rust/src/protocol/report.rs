//! The exaCB protocol data model (paper §V-B).
//!
//! A protocol document is a single benchmark *report* with five top-level
//! sections: `version`, `reporter`, `parameter`, `experiment`, `data[]`.
//! The format is hierarchical JSON, self-describing, extensible, and
//! robust against partial/incremental generation: every consumer in the
//! framework (orchestrators, store, analysis) speaks only this model.

use crate::util::json::Json;
use crate::util::timeutil::SimTime;

/// Current protocol schema version.
pub const PROTOCOL_VERSION: u64 = 3;

/// §V-B (b): provenance metadata about the entity that generated the
/// report — tool, pipeline/job ids, commit, user, system, timestamps.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Reporter {
    pub tool: String,
    pub tool_version: String,
    pub pipeline_id: u64,
    pub ci_job_id: u64,
    pub commit: String,
    pub user: String,
    pub system: String,
    pub system_version: String,
    pub timestamp: String,
    /// Seed that reproduces simulated noise (this reproduction's addition
    /// to the provenance section; see DESIGN.md).
    pub seed: u64,
}

/// §V-B (d): semantic context of the experiment.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Experiment {
    pub system: String,
    pub software_version: String,
    pub variant: String,
    pub usecase: String,
    pub timestamp: String,
}

/// §V-B (e): one benchmark execution (a run of one parameter point).
#[derive(Debug, Clone, PartialEq)]
pub struct DataEntry {
    pub success: bool,
    /// Total runtime in seconds (time-to-solution).
    pub runtime: f64,
    pub nodes: u64,
    pub taskspernode: u64,
    pub threadspertask: u64,
    /// Scheduler metadata.
    pub jobid: u64,
    pub queue: String,
    /// Extensible benchmark-specific metrics (bandwidths, energy, ...).
    pub metrics: Json,
}

impl Default for DataEntry {
    fn default() -> Self {
        DataEntry {
            success: false,
            runtime: 0.0,
            nodes: 1,
            taskspernode: 1,
            threadspertask: 1,
            jobid: 0,
            queue: String::new(),
            metrics: Json::obj(),
        }
    }
}

/// A complete protocol document.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Report {
    pub reporter: Reporter,
    /// §V-B (c): global (experiment-wide) parameters.
    pub parameter: Json,
    pub experiment: Experiment,
    pub data: Vec<DataEntry>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum ProtocolError {
    Json(String),
    Schema { path: String, msg: String },
    Version(u64),
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::Json(e) => write!(f, "malformed json: {e}"),
            ProtocolError::Schema { path, msg } => {
                write!(f, "schema violation at {path}: {msg}")
            }
            ProtocolError::Version(v) => write!(
                f,
                "unsupported protocol version {v} (current: {PROTOCOL_VERSION})"
            ),
        }
    }
}

impl std::error::Error for ProtocolError {}

fn schema_err(path: &str, msg: &str) -> ProtocolError {
    ProtocolError::Schema {
        path: path.to_string(),
        msg: msg.to_string(),
    }
}

impl Reporter {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("tool", self.tool.as_str())
            .set("tool_version", self.tool_version.as_str())
            .set("pipeline_id", self.pipeline_id)
            .set("ci_job_id", self.ci_job_id)
            .set("commit", self.commit.as_str())
            .set("user", self.user.as_str())
            .set("system", self.system.as_str())
            .set("system_version", self.system_version.as_str())
            .set("timestamp", self.timestamp.as_str())
            .set("seed", self.seed)
    }

    pub fn from_json(v: &Json) -> Result<Reporter, ProtocolError> {
        let req = |k: &str| -> Result<String, ProtocolError> {
            v.str_of(k)
                .map(str::to_string)
                .ok_or_else(|| schema_err(&format!("/reporter/{k}"), "missing string field"))
        };
        Ok(Reporter {
            tool: req("tool")?,
            tool_version: req("tool_version")?,
            pipeline_id: v.u64_of("pipeline_id").unwrap_or(0),
            ci_job_id: v.u64_of("ci_job_id").unwrap_or(0),
            commit: v.str_of("commit").unwrap_or_default().to_string(),
            user: v.str_of("user").unwrap_or_default().to_string(),
            system: req("system")?,
            system_version: v.str_of("system_version").unwrap_or_default().to_string(),
            timestamp: req("timestamp")?,
            seed: v.u64_of("seed").unwrap_or(0),
        })
    }
}

impl Experiment {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("system", self.system.as_str())
            .set("software_version", self.software_version.as_str())
            .set("variant", self.variant.as_str())
            .set("usecase", self.usecase.as_str())
            .set("timestamp", self.timestamp.as_str())
    }

    pub fn from_json(v: &Json) -> Result<Experiment, ProtocolError> {
        Ok(Experiment {
            system: v
                .str_of("system")
                .ok_or_else(|| schema_err("/experiment/system", "missing string field"))?
                .to_string(),
            software_version: v
                .str_of("software_version")
                .unwrap_or_default()
                .to_string(),
            variant: v.str_of("variant").unwrap_or_default().to_string(),
            usecase: v.str_of("usecase").unwrap_or_default().to_string(),
            timestamp: v.str_of("timestamp").unwrap_or_default().to_string(),
        })
    }

    /// Parse the experiment timestamp for time-series filtering.
    pub fn time(&self) -> Option<SimTime> {
        SimTime::parse(&self.timestamp)
    }
}

impl DataEntry {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("success", self.success)
            .set("runtime", self.runtime)
            .set("nodes", self.nodes)
            .set("taskspernode", self.taskspernode)
            .set("threadspertask", self.threadspertask)
            .set("jobid", self.jobid)
            .set("queue", self.queue.as_str())
            .set("metrics", self.metrics.clone())
    }

    pub fn from_json(v: &Json, idx: usize) -> Result<DataEntry, ProtocolError> {
        let path = format!("/data/{idx}");
        let success = v
            .bool_of("success")
            .ok_or_else(|| schema_err(&path, "missing bool 'success'"))?;
        let runtime = v
            .f64_of("runtime")
            .ok_or_else(|| schema_err(&path, "missing number 'runtime'"))?;
        if !runtime.is_finite() || runtime < 0.0 {
            return Err(schema_err(&path, "'runtime' must be finite and >= 0"));
        }
        let nodes = v
            .u64_of("nodes")
            .ok_or_else(|| schema_err(&path, "missing integer 'nodes'"))?;
        if nodes == 0 {
            return Err(schema_err(&path, "'nodes' must be >= 1"));
        }
        let metrics = match v.get("metrics") {
            None => Json::obj(),
            Some(m @ Json::Obj(_)) => m.clone(),
            Some(_) => return Err(schema_err(&path, "'metrics' must be an object")),
        };
        Ok(DataEntry {
            success,
            runtime,
            nodes,
            taskspernode: v.u64_of("taskspernode").unwrap_or(1),
            threadspertask: v.u64_of("threadspertask").unwrap_or(1),
            jobid: v.u64_of("jobid").unwrap_or(0),
            queue: v.str_of("queue").unwrap_or_default().to_string(),
            metrics,
        })
    }

    /// A named metric value, if present and numeric.
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.f64_of(name)
    }
}

impl Report {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("version", PROTOCOL_VERSION)
            .set("reporter", self.reporter.to_json())
            .set("parameter", self.parameter.clone())
            .set("experiment", self.experiment.to_json())
            .set(
                "data",
                Json::Arr(self.data.iter().map(DataEntry::to_json).collect()),
            )
    }

    /// Serialize as the canonical protocol document (pretty JSON).
    pub fn to_document(&self) -> String {
        self.to_json().pretty()
    }

    /// Parse and validate a protocol document, migrating old versions
    /// forward (see [`super::migrate`]).
    pub fn parse(text: &str) -> Result<Report, ProtocolError> {
        let v = Json::parse(text).map_err(|e| ProtocolError::Json(e.to_string()))?;
        Report::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Report, ProtocolError> {
        let version = v
            .u64_of("version")
            .ok_or_else(|| schema_err("/version", "missing integer 'version'"))?;
        let v = if version < PROTOCOL_VERSION {
            super::migrate::migrate(v, version)?
        } else if version > PROTOCOL_VERSION {
            return Err(ProtocolError::Version(version));
        } else {
            v.clone()
        };
        let reporter = Reporter::from_json(
            v.get("reporter")
                .ok_or_else(|| schema_err("/reporter", "missing section"))?,
        )?;
        let parameter = match v.get("parameter") {
            None | Some(Json::Null) => Json::obj(),
            Some(p @ Json::Obj(_)) => p.clone(),
            Some(_) => return Err(schema_err("/parameter", "must be an object")),
        };
        let experiment = Experiment::from_json(
            v.get("experiment")
                .ok_or_else(|| schema_err("/experiment", "missing section"))?,
        )?;
        let data_json = v
            .get("data")
            .and_then(Json::as_arr)
            .ok_or_else(|| schema_err("/data", "missing array section"))?;
        let mut data = Vec::with_capacity(data_json.len());
        for (i, entry) in data_json.iter().enumerate() {
            data.push(DataEntry::from_json(entry, i)?);
        }
        Ok(Report {
            reporter,
            parameter,
            experiment,
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn sample_report() -> Report {
        Report {
            reporter: Reporter {
                tool: "exacb".into(),
                tool_version: "0.1.0".into(),
                pipeline_id: 221622,
                ci_job_id: 900001,
                commit: "abc123def456".into(),
                user: "jureap-bot".into(),
                system: "jedi".into(),
                system_version: "2026.1".into(),
                timestamp: "2026-02-03T04:05:06Z".into(),
                seed: 42,
            },
            parameter: Json::obj().set("workload", 6u64).set("intensity", 2.4),
            experiment: Experiment {
                system: "jedi".into(),
                software_version: "stage-2026".into(),
                variant: "large-intensity".into(),
                usecase: "large-workload".into(),
                timestamp: "2026-02-03T04:00:00Z".into(),
            },
            data: vec![DataEntry {
                success: true,
                runtime: 12.5,
                nodes: 4,
                taskspernode: 4,
                threadspertask: 8,
                jobid: 7700123,
                queue: "all".into(),
                metrics: Json::obj().set("tts", 12.5).set("gflops", 830.2),
            }],
        }
    }

    #[test]
    fn roundtrip() {
        let r = sample_report();
        let doc = r.to_document();
        let back = Report::parse(&doc).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn rejects_future_version() {
        let mut j = sample_report().to_json();
        j.insert("version", 99u64);
        let err = Report::from_json(&j).unwrap_err();
        assert!(matches!(err, ProtocolError::Version(99)));
    }

    #[test]
    fn rejects_missing_sections() {
        for missing in ["reporter", "experiment", "data"] {
            let j = sample_report().to_json();
            let pruned = Json::Obj(
                j.as_obj()
                    .unwrap()
                    .iter()
                    .filter(|(k, _)| k != missing)
                    .cloned()
                    .collect(),
            );
            assert!(Report::from_json(&pruned).is_err(), "{missing}");
        }
    }

    #[test]
    fn rejects_bad_data_entries() {
        let mut r = sample_report();
        r.data[0].runtime = -1.0;
        let err = Report::parse(&r.to_document()).unwrap_err();
        assert!(matches!(err, ProtocolError::Schema { .. }));

        let mut r = sample_report();
        r.data[0].nodes = 0;
        assert!(Report::parse(&r.to_document()).is_err());
    }

    #[test]
    fn metrics_accessor() {
        let r = sample_report();
        assert_eq!(r.data[0].metric("gflops"), Some(830.2));
        assert_eq!(r.data[0].metric("missing"), None);
    }

    #[test]
    fn empty_data_is_valid() {
        // "robust against partial or incremental data generation" (§V-B)
        let mut r = sample_report();
        r.data.clear();
        let back = Report::parse(&r.to_document()).unwrap();
        assert!(back.data.is_empty());
    }

    #[test]
    fn experiment_time_parses() {
        let r = sample_report();
        assert_eq!(
            r.experiment.time().unwrap().date_string(),
            "2026-02-03"
        );
    }
}
