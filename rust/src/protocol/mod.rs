//! The exaCB protocol (paper §IV-B, §V-B; DESIGN.md §1 protocol layer):
//! the standardized data model that strongly couples independently-owned
//! benchmarks to the framework.
//!
//! * [`report`] — the document model (`version`/`reporter`/`parameter`/
//!   `experiment`/`data[]`) with parsing + validation.
//! * [`migrate`] — schema-version migrations (old stored reports stay
//!   readable).
//! * [`csv`] — the Table-I `results.csv` contract.
//! * [`provenance`] — the cache hit/miss/invalidated sidecar (`cache.json`)
//!   pipelines attach next to (never inside) recorded reports.
//!
//! Design rule enforced throughout the crate: components never exchange
//! ad-hoc structures — generation and consumption of benchmark data are
//! fully decoupled and may happen at different times on different
//! systems, so everything crosses module boundaries as [`report::Report`]
//! documents.

pub mod csv;
pub mod migrate;
pub mod provenance;
pub mod report;

pub use csv::{csv_honours_contract, results_csv, results_table, BASE_COLUMNS};
pub use provenance::{
    parse_provenance, provenance_document, CacheOutcome, StepProvenance,
};
pub use report::{
    DataEntry, Experiment, ProtocolError, Report, Reporter, PROTOCOL_VERSION,
};

/// Merge several reports that share an experiment context into one
/// document (used when a parameter study produces per-point reports that
/// the post-processing orchestrator wants as a single dataset). The first
/// report's reporter/experiment win; data arrays concatenate; parameters
/// merge key-wise (later reports do not override earlier keys).
pub fn merge(reports: &[Report]) -> Option<Report> {
    let mut iter = reports.iter();
    let mut out = iter.next()?.clone();
    for r in iter {
        out.data.extend(r.data.iter().cloned());
        for (k, v) in r.parameter.as_obj().unwrap_or(&[]) {
            if out.parameter.get(k).is_none() {
                out.parameter.insert(k, v.clone());
            }
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::report::{DataEntry, Report};
    use super::*;
    use crate::util::json::Json;

    fn mk(n_entries: usize, param: (&str, u64)) -> Report {
        let mut r = Report::default();
        r.reporter.tool = "t".into();
        r.reporter.tool_version = "1".into();
        r.reporter.system = "s".into();
        r.reporter.timestamp = "2026-01-01T00:00:00Z".into();
        r.experiment.system = "s".into();
        r.parameter = Json::obj().set(param.0, param.1);
        r.data = (0..n_entries)
            .map(|i| DataEntry {
                success: true,
                runtime: i as f64,
                nodes: 1,
                ..Default::default()
            })
            .collect();
        r
    }

    #[test]
    fn merge_concatenates_data() {
        let merged = merge(&[mk(2, ("a", 1)), mk(3, ("b", 2))]).unwrap();
        assert_eq!(merged.data.len(), 5);
        assert_eq!(merged.parameter.u64_of("a"), Some(1));
        assert_eq!(merged.parameter.u64_of("b"), Some(2));
    }

    #[test]
    fn merge_first_param_wins() {
        let merged = merge(&[mk(1, ("a", 1)), mk(1, ("a", 9))]).unwrap();
        assert_eq!(merged.parameter.u64_of("a"), Some(1));
    }

    #[test]
    fn merge_empty_is_none() {
        assert!(merge(&[]).is_none());
    }
}
