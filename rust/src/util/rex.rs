//! Mini regular-expression engine (substrate; DESIGN.md §2 — no `regex`
//! crate vendored offline).
//!
//! Implements the subset JUBE analysis patterns actually use: literal
//! characters, `.`, escaped characters (`\.` `\d` `\w` `\s`, inside and
//! outside classes), character classes `[a-z0-9.eE+-]` with `^`
//! negation, capturing groups `(...)`, and the quantifiers `?`, `*`,
//! `+` (greedy, backtracking). Matching is unanchored; `captures_last`
//! gives JUBE's "last match wins" semantics.
//!
//! Not supported (compile error, so misuse is loud rather than silently
//! wrong): alternation `|`, counted repeats `{n,m}`, anchors `^` `$`,
//! non-greedy quantifiers, backreferences. Braces that do not form a
//! counted repeat stay literal characters.

/// Compile error with position context.
#[derive(Debug, Clone, PartialEq)]
pub struct RexError {
    pub msg: String,
    pub at: usize,
}

impl std::fmt::Display for RexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "regex error at {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for RexError {}

#[derive(Debug, Clone)]
enum Node {
    Char(char),
    Any,
    Class { neg: bool, ranges: Vec<(char, char)> },
    Group(usize, Vec<Atom>),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Quant {
    One,
    Opt,
    Star,
    Plus,
}

#[derive(Debug, Clone)]
struct Atom {
    node: Node,
    quant: Quant,
}

/// A compiled pattern.
#[derive(Debug, Clone)]
pub struct Rex {
    seq: Vec<Atom>,
    n_groups: usize,
}

/// One match: the overall span plus capture-group spans, over the
/// original text.
#[derive(Debug, Clone)]
pub struct Caps<'t> {
    text: &'t str,
    /// Byte spans: index 0 = whole match, 1.. = groups.
    spans: Vec<Option<(usize, usize)>>,
}

impl<'t> Caps<'t> {
    /// Text of group `i` (0 = whole match), if it participated.
    pub fn get(&self, i: usize) -> Option<&'t str> {
        let (s, e) = (*self.spans.get(i)?)?;
        Some(&self.text[s..e])
    }
}

struct Parser<'p> {
    chars: Vec<char>,
    pos: usize,
    n_groups: usize,
    pattern: &'p str,
}

impl<'p> Parser<'p> {
    fn err(&self, msg: &str) -> RexError {
        let _ = self.pattern;
        RexError {
            msg: msg.to_string(),
            at: self.pos,
        }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    /// Parse a sequence until end-of-pattern or a closing `)`.
    fn seq(&mut self, in_group: bool) -> Result<Vec<Atom>, RexError> {
        let mut out: Vec<Atom> = Vec::new();
        loop {
            let Some(c) = self.peek() else {
                if in_group {
                    return Err(self.err("unclosed group"));
                }
                return Ok(out);
            };
            match c {
                ')' => {
                    if in_group {
                        return Ok(out);
                    }
                    return Err(self.err("unmatched ')'"));
                }
                '|' => return Err(self.err("alternation '|' not supported")),
                '*' | '+' | '?' => return Err(self.err("quantifier without target")),
                _ => {}
            }
            let node = self.atom()?;
            let quant = match self.peek() {
                Some('?') => {
                    self.pos += 1;
                    Quant::Opt
                }
                Some('*') => {
                    self.pos += 1;
                    Quant::Star
                }
                Some('+') => {
                    self.pos += 1;
                    Quant::Plus
                }
                Some('{') if self.counted_repeat_ahead() => {
                    return Err(self.err("counted repeats '{n,m}' not supported"));
                }
                _ => Quant::One,
            };
            out.push(Atom { node, quant });
        }
    }

    /// True when the upcoming `{...}` has the shape of a counted repeat
    /// (`{3}`, `{2,}`, `{2,5}`) — rejected loudly rather than silently
    /// matched as literal braces. A brace with any other content stays a
    /// literal.
    fn counted_repeat_ahead(&self) -> bool {
        let mut i = self.pos + 1; // past '{'
        let mut digits = 0;
        while self.chars.get(i).is_some_and(|c| c.is_ascii_digit()) {
            i += 1;
            digits += 1;
        }
        if digits == 0 {
            return false;
        }
        if self.chars.get(i) == Some(&',') {
            i += 1;
            while self.chars.get(i).is_some_and(|c| c.is_ascii_digit()) {
                i += 1;
            }
        }
        self.chars.get(i) == Some(&'}')
    }

    fn atom(&mut self) -> Result<Node, RexError> {
        let c = self.next().expect("caller checked");
        match c {
            '(' => {
                self.n_groups += 1;
                let idx = self.n_groups;
                let inner = self.seq(true)?;
                match self.next() {
                    Some(')') => Ok(Node::Group(idx, inner)),
                    _ => Err(self.err("unclosed group")),
                }
            }
            '[' => self.class(),
            '.' => Ok(Node::Any),
            '\\' => match self.next() {
                Some('d') => Ok(Node::Class {
                    neg: false,
                    ranges: vec![('0', '9')],
                }),
                Some('w') => Ok(Node::Class {
                    neg: false,
                    ranges: vec![('a', 'z'), ('A', 'Z'), ('0', '9'), ('_', '_')],
                }),
                Some('s') => Ok(Node::Class {
                    neg: false,
                    ranges: vec![(' ', ' '), ('\t', '\t'), ('\n', '\n'), ('\r', '\r')],
                }),
                Some('n') => Ok(Node::Char('\n')),
                Some('t') => Ok(Node::Char('\t')),
                Some(c) => Ok(Node::Char(c)),
                None => Err(self.err("trailing backslash")),
            },
            '^' | '$' => Err(self.err("anchors not supported")),
            c => Ok(Node::Char(c)),
        }
    }

    /// Parse a `[...]` class; the leading `[` is already consumed.
    fn class(&mut self) -> Result<Node, RexError> {
        let mut ranges: Vec<(char, char)> = Vec::new();
        let neg = if self.peek() == Some('^') {
            self.pos += 1;
            true
        } else {
            false
        };
        loop {
            let Some(c) = self.next() else {
                return Err(self.err("unclosed character class"));
            };
            match c {
                ']' => {
                    if ranges.is_empty() {
                        return Err(self.err("empty character class"));
                    }
                    return Ok(Node::Class { neg, ranges });
                }
                '\\' => {
                    let Some(esc) = self.next() else {
                        return Err(self.err("trailing backslash in class"));
                    };
                    match esc {
                        'd' => ranges.push(('0', '9')),
                        'w' => ranges.extend([('a', 'z'), ('A', 'Z'), ('0', '9'), ('_', '_')]),
                        's' => ranges
                            .extend([(' ', ' '), ('\t', '\t'), ('\n', '\n'), ('\r', '\r')]),
                        'n' => ranges.push(('\n', '\n')),
                        't' => ranges.push(('\t', '\t')),
                        c => ranges.push((c, c)),
                    }
                }
                lo => {
                    // range `a-z` when '-' is followed by a non-']' char
                    if self.peek() == Some('-')
                        && self.chars.get(self.pos + 1).is_some_and(|&c| c != ']')
                    {
                        self.pos += 1; // '-'
                        let hi = self.next().expect("checked above");
                        if hi < lo {
                            return Err(self.err("inverted range in class"));
                        }
                        ranges.push((lo, hi));
                    } else {
                        ranges.push((lo, lo));
                    }
                }
            }
        }
    }
}

impl Rex {
    pub fn new(pattern: &str) -> Result<Rex, RexError> {
        let mut p = Parser {
            chars: pattern.chars().collect(),
            pos: 0,
            n_groups: 0,
            pattern,
        };
        let seq = p.seq(false)?;
        Ok(Rex {
            seq,
            n_groups: p.n_groups,
        })
    }

    /// First match anywhere in `text`. Returns capture spans.
    pub fn captures<'t>(&self, text: &'t str) -> Option<Caps<'t>> {
        let chars: Vec<(usize, char)> = text.char_indices().collect();
        self.scan(&chars, text.len(), 0)
            .map(|(spans, _, _)| Caps { text, spans })
    }

    /// Last non-overlapping match in `text` (JUBE: repeated prints
    /// converge, last value wins). The character table is built once and
    /// reused across matches, so a pattern that matches on every line of
    /// a large file stays linear.
    pub fn captures_last<'t>(&self, text: &'t str) -> Option<Caps<'t>> {
        let chars: Vec<(usize, char)> = text.char_indices().collect();
        let mut from = 0usize; // char index
        let mut last = None;
        while from <= chars.len() {
            let Some((spans, start_idx, end_idx)) = self.scan(&chars, text.len(), from) else {
                break;
            };
            // guarantee progress past zero-width matches
            from = if end_idx > start_idx {
                end_idx
            } else {
                start_idx + 1
            };
            last = Some(Caps { text, spans });
        }
        last
    }

    /// Whether the pattern matches anywhere in `text`.
    pub fn is_match(&self, text: &str) -> bool {
        self.captures(text).is_some()
    }

    /// Try each start position from char index `from`; returns
    /// (capture spans, match start char index, match end char index).
    fn scan(
        &self,
        chars: &[(usize, char)],
        text_len: usize,
        from: usize,
    ) -> Option<(Vec<Option<(usize, usize)>>, usize, usize)> {
        for start in from..=chars.len() {
            let mut spans: Spans = vec![None; self.n_groups + 1];
            let end = m_seq(&self.seq, chars, text_len, start, &mut spans, &mut |j, _| {
                Some(j)
            });
            if let Some(end_idx) = end {
                let s = chars.get(start).map(|&(b, _)| b).unwrap_or(text_len);
                let e = chars.get(end_idx).map(|&(b, _)| b).unwrap_or(text_len);
                spans[0] = Some((s, e));
                return Some((spans, start, end_idx));
            }
        }
        None
    }
}

fn class_matches(neg: bool, ranges: &[(char, char)], c: char) -> bool {
    let hit = ranges.iter().any(|&(lo, hi)| c >= lo && c <= hi);
    hit != neg
}

type Spans = Vec<Option<(usize, usize)>>;
/// Continuation: "the rest of the pattern matches from char index j".
type Cont<'c> = &'c mut dyn FnMut(usize, &mut Spans) -> Option<usize>;

/// Single-character node match (no groups); index after the match.
fn m_simple(node: &Node, chars: &[(usize, char)], i: usize) -> Option<usize> {
    match node {
        Node::Char(c) => (chars.get(i)?.1 == *c).then_some(i + 1),
        Node::Any => chars.get(i).map(|_| i + 1),
        Node::Class { neg, ranges } => {
            class_matches(*neg, ranges, chars.get(i)?.1).then_some(i + 1)
        }
        Node::Group(..) => unreachable!("groups go through m_node"),
    }
}

/// Match one node at `i`, then hand the end position to `k`. Written in
/// continuation-passing style so backtracking reaches *into* groups: if
/// the continuation fails, a group retries its inner alternatives (e.g.
/// a shorter `.+`) before giving up — matching the regex crate's
/// semantics. Group spans are unwound when the continuation rejects.
fn m_node(
    node: &Node,
    chars: &[(usize, char)],
    text_len: usize,
    i: usize,
    spans: &mut Spans,
    k: Cont,
) -> Option<usize> {
    match node {
        Node::Group(idx, inner) => {
            let start_b = chars.get(i).map(|&(b, _)| b).unwrap_or(text_len);
            m_seq(inner, chars, text_len, i, spans, &mut |j, sp| {
                let end_b = chars.get(j).map(|&(b, _)| b).unwrap_or(text_len);
                let prev = sp[*idx];
                sp[*idx] = Some((start_b, end_b));
                match k(j, sp) {
                    Some(e) => Some(e),
                    None => {
                        sp[*idx] = prev;
                        None
                    }
                }
            })
        }
        simple => {
            let j = m_simple(simple, chars, i)?;
            k(j, spans)
        }
    }
}

/// Greedy `*`/`+` repetition of `node` with full backtracking: prefer
/// one more repetition (letting the repetition itself backtrack), fall
/// back to stopping here once `min` repetitions are satisfied.
fn m_rep(
    node: &Node,
    min: usize,
    chars: &[(usize, char)],
    text_len: usize,
    i: usize,
    spans: &mut Spans,
    k: Cont,
) -> Option<usize> {
    if matches!(node, Node::Group(..)) {
        let saved = spans.clone();
        let r = m_node(node, chars, text_len, i, spans, &mut |j, sp| {
            if j == i {
                return None; // zero-width repetition would never progress
            }
            m_rep(node, min.saturating_sub(1), chars, text_len, j, sp, k)
        });
        if r.is_some() {
            return r;
        }
        *spans = saved;
        if min == 0 {
            k(i, spans)
        } else {
            None
        }
    } else {
        // single-char node: no inner alternatives, so enumerating the
        // repetition counts longest-first is complete (and keeps the
        // recursion depth bounded by the pattern, not the text)
        let mut ends = vec![i];
        let mut p = i;
        while let Some(j) = m_simple(node, chars, p) {
            if j == p {
                break;
            }
            ends.push(j);
            p = j;
        }
        for reps in (min..ends.len()).rev() {
            if let Some(e) = k(ends[reps], spans) {
                return Some(e);
            }
        }
        None
    }
}

/// Backtracking sequence match starting at char index `i`; calls `cont`
/// with the end position once the whole sequence has matched.
fn m_seq(
    seq: &[Atom],
    chars: &[(usize, char)],
    text_len: usize,
    i: usize,
    spans: &mut Spans,
    cont: Cont,
) -> Option<usize> {
    let Some((first, rest)) = seq.split_first() else {
        return cont(i, spans);
    };
    match first.quant {
        Quant::One => m_node(&first.node, chars, text_len, i, spans, &mut |j, sp| {
            m_seq(rest, chars, text_len, j, sp, cont)
        }),
        Quant::Opt => {
            // snapshot so a failed present-branch leaves no stale group
            // spans behind (a group that did not participate must read
            // as None, matching the regex crate)
            let saved = spans.clone();
            let r = m_node(&first.node, chars, text_len, i, spans, &mut |j, sp| {
                m_seq(rest, chars, text_len, j, sp, cont)
            });
            if r.is_some() {
                return r;
            }
            *spans = saved;
            m_seq(rest, chars, text_len, i, spans, cont)
        }
        Quant::Star | Quant::Plus => {
            let min = if first.quant == Quant::Plus { 1 } else { 0 };
            m_rep(&first.node, min, chars, text_len, i, spans, &mut |j, sp| {
                m_seq(rest, chars, text_len, j, sp, cont)
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group1(pattern: &str, text: &str) -> Option<String> {
        let re = Rex::new(pattern).unwrap();
        let caps = re.captures_last(text)?;
        caps.get(1)
            .or_else(|| caps.get(0))
            .map(str::to_string)
    }

    #[test]
    fn literal_and_capture() {
        assert_eq!(
            group1("time: ([0-9.eE+-]+)", "setup done\ntime: 12.5\n"),
            Some("12.5".into())
        );
        assert_eq!(group1("x", "an x marks the spot"), Some("x".into()));
        assert_eq!(group1("zz", "no match here"), None);
    }

    #[test]
    fn last_match_wins() {
        assert_eq!(group1("t=([0-9]+)", "t=1\nt=2\nt=3"), Some("3".into()));
        assert_eq!(
            group1("time: ([0-9.eE+-]+)", "time: 1.0\ntime: 2.5e-3\n"),
            Some("2.5e-3".into())
        );
    }

    #[test]
    fn classes_and_ranges() {
        let re = Rex::new("[a-f0-9]+").unwrap();
        assert_eq!(re.captures("zz deadbeef!").unwrap().get(0), Some("deadbeef"));
        let re = Rex::new("[^ ]+").unwrap();
        assert_eq!(re.captures("  word rest").unwrap().get(0), Some("word"));
        // '-' at class end is a literal
        let re = Rex::new("[0-9+-]+").unwrap();
        assert_eq!(re.captures("x -12+3 y").unwrap().get(0), Some("-12+3"));
    }

    #[test]
    fn quantifiers() {
        assert_eq!(group1("ab?c", "ac abc"), Some("abc".into()));
        assert_eq!(group1("ab*c", "abbbc"), Some("abbbc".into()));
        let re = Rex::new("a+").unwrap();
        assert_eq!(re.captures("baaad").unwrap().get(0), Some("aaa"));
        // greedy with backtracking: the '+' must give back one 'b'
        let re = Rex::new("ab+b").unwrap();
        assert_eq!(re.captures("abbb").unwrap().get(0), Some("abbb"));
    }

    #[test]
    fn dot_and_escapes() {
        assert_eq!(group1("a.c", "abc"), Some("abc".into()));
        assert_eq!(group1(r"1\.5", "x1.5y"), Some("1.5".into()));
        assert_eq!(group1(r"\d+", "abc 456"), Some("456".into()));
        assert_eq!(group1(r"\w+", "  hi_9 "), Some("hi_9".into()));
    }

    #[test]
    fn invalid_patterns_rejected() {
        assert!(Rex::new("([").is_err());
        assert!(Rex::new("(abc").is_err());
        assert!(Rex::new("abc)").is_err());
        assert!(Rex::new("[abc").is_err());
        assert!(Rex::new("*x").is_err());
        assert!(Rex::new("a|b").is_err());
        assert!(Rex::new("\\").is_err());
        // counted repeats are loudly rejected, literal braces are fine
        assert!(Rex::new("a{3}").is_err());
        assert!(Rex::new("a{2,}").is_err());
        assert!(Rex::new("a{2,5}").is_err());
        assert_eq!(Rex::new("a{x}").unwrap().captures("za{x}b").unwrap().get(0), Some("a{x}"));
        assert_eq!(Rex::new("{3}").unwrap().captures("{3}").unwrap().get(0), Some("{3}"));
    }

    #[test]
    fn class_escapes_expand() {
        assert_eq!(group1(r"mem: ([\d.]+)", "mem: 12.5 MB"), Some("12.5".into()));
        assert_eq!(group1(r"[\w]+", "  a_b9! "), Some("a_b9".into()));
        let re = Rex::new(r"[^\s]+").unwrap();
        assert_eq!(re.captures("  token rest").unwrap().get(0), Some("token"));
    }

    #[test]
    fn backtracks_into_groups() {
        // the inner `.+` must give back " MB" for the literal tail
        assert_eq!(group1("mem: (.+) MB", "mem: 12.5 MB"), Some("12.5".into()));
        // group followed by a char the greedy class would also eat
        assert_eq!(group1("([0-9]+)4", "1234"), Some("123".into()));
        // nested group backtracking
        let re = Rex::new("a(b(c+))cd").unwrap();
        let caps = re.captures("abcccd").unwrap();
        assert_eq!(caps.get(0), Some("abcccd"));
        assert_eq!(caps.get(1), Some("bcc"));
        assert_eq!(caps.get(2), Some("cc"));
    }

    #[test]
    fn failed_backtracking_branch_leaves_no_stale_spans() {
        // the optional group matches greedily, then the overall match
        // backtracks to the group-absent branch: group 1 must read None
        let re = Rex::new("(ab)?a").unwrap();
        let caps = re.captures("ab a").unwrap();
        assert_eq!(caps.get(0), Some("a"));
        assert_eq!(caps.get(1), None);
        // star over a group that ends up with zero reps
        let re = Rex::new("(xy)*x").unwrap();
        let caps = re.captures("xyz x").unwrap();
        assert_eq!(caps.get(0), Some("x"));
        assert_eq!(caps.get(1), None);
    }

    #[test]
    fn groups_nested_in_sequence() {
        let re = Rex::new("nodes=([0-9]+) tasks=([0-9]+)").unwrap();
        let caps = re.captures("run nodes=32 tasks=4 done").unwrap();
        assert_eq!(caps.get(1), Some("32"));
        assert_eq!(caps.get(2), Some("4"));
        assert_eq!(caps.get(0), Some("nodes=32 tasks=4"));
    }

    #[test]
    fn unicode_text_is_safe() {
        assert_eq!(group1("[0-9]+", "π≈3 — 14159?"), Some("14159".into()));
        assert_eq!(group1("m.p", "map möp"), Some("möp".into()));
    }

    #[test]
    fn scientific_float_pattern() {
        // the exact pattern every seed benchmark definition uses
        let re = Rex::new("time: ([0-9.eE+-]+)").unwrap();
        for (text, want) in [
            ("time: 123.456", "123.456"),
            ("time: 1.2e-07", "1.2e-07"),
            ("time: 9E+4", "9E+4"),
        ] {
            assert_eq!(re.captures_last(text).unwrap().get(1), Some(want));
        }
    }
}
