//! Tiny property-testing harness (substrate; DESIGN.md §2 — no `proptest`
//! vendored offline).
//!
//! Provides seeded random-input property checks with greedy shrinking for
//! the coordinator invariants called out in DESIGN.md §7. Usage:
//!
//! ```no_run
//! use exacb::prop_assert;
//! use exacb::util::prop::{check, Gen};
//! check("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     prop_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use super::prng::Prng;

/// Property failure: message plus the inputs drawn so far (for replay).
#[derive(Debug, Clone)]
pub struct PropFail {
    pub msg: String,
}

pub type PropResult = Result<(), PropFail>;

/// Input generator handed to each property execution. Records every draw
/// so failures can be replayed and shrunk by seed.
pub struct Gen {
    rng: Prng,
    pub draws: Vec<i128>,
    /// When replaying a shrunk case, draws come from here instead.
    replay: Option<Vec<i128>>,
    replay_at: usize,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen {
            rng: Prng::new(seed),
            draws: Vec::new(),
            replay: None,
            replay_at: 0,
        }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut Prng) -> i128) -> i128 {
        if let Some(replay) = &self.replay {
            if self.replay_at < replay.len() {
                let v = replay[self.replay_at];
                self.replay_at += 1;
                self.draws.push(v);
                return v;
            }
        }
        let v = fresh(&mut self.rng);
        self.draws.push(v);
        v
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = self.draw(|r| r.range_u64(lo, hi) as i128);
        (v.clamp(lo as i128, hi as i128)) as u64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.draw(|r| (lo + r.below((hi - lo + 1) as u64) as i64) as i128);
        v.clamp(lo as i128, hi as i128) as i64
    }

    /// f64 in [lo, hi) with 3 decimal places (keeps shrinking sane).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let milli = self.draw(|r| (r.range_f64(lo, hi) * 1000.0).round() as i128);
        (milli as f64 / 1000.0).clamp(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.u64(0, 1) == 1
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.usize(0, items.len() - 1);
        &items[i]
    }

    /// Vector with length in [0, max_len], elements via `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Lowercase identifier of length 1..=n.
    pub fn ident(&mut self, n: usize) -> String {
        let len = self.usize(1, n);
        (0..len)
            .map(|_| (b'a' + self.u64(0, 25) as u8) as char)
            .collect()
    }
}

/// Check `prop` over `cases` random inputs; panics with the shrunk
/// counterexample on failure. Seed is fixed per property name so CI is
/// deterministic; override with EXACB_PROP_SEED.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let seed = std::env::var("EXACB_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for case in 0..cases {
        let mut g = Gen::new(seed.wrapping_add(case as u64));
        if let Err(fail) = prop(&mut g) {
            let (draws, fail) = shrink(&g.draws, fail, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  {}\n  shrunk draws: {:?}",
                fail.msg, draws
            );
        }
    }
}

/// Greedy shrink: try to reduce each recorded draw toward zero while the
/// property still fails; returns the smallest failing draw vector found.
fn shrink(
    draws: &[i128],
    orig: PropFail,
    prop: &impl Fn(&mut Gen) -> PropResult,
) -> (Vec<i128>, PropFail) {
    let mut best = draws.to_vec();
    let mut best_fail = orig;
    let mut improved = true;
    let mut budget = 500usize;
    while improved && budget > 0 {
        improved = false;
        'outer: for i in 0..best.len() {
            // `best` may have been replaced by a shorter draw vector in a
            // previous iteration of the while loop; re-check bounds.
            if i >= best.len() || best[i] == 0 {
                continue;
            }
            for cand in [0, best[i] / 2, best[i] - best[i].signum()] {
                if cand == best[i] {
                    continue;
                }
                budget = budget.saturating_sub(1);
                let mut trial = best.clone();
                trial[i] = cand;
                let mut g = Gen::new(0);
                g.replay = Some(trial.clone());
                if let Err(f) = prop(&mut g) {
                    best = g.draws.clone();
                    best_fail = f;
                    improved = true;
                    break 'outer;
                }
                if budget == 0 {
                    break 'outer;
                }
            }
        }
    }
    (best, best_fail)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Assert inside a property body.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err($crate::util::prop::PropFail { msg: format!($($fmt)*) });
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 100, |g| {
            let a = g.u64(0, 1_000_000);
            let b = g.u64(0, 1_000_000);
            prop_assert!(a + b == b + a, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_shrunk_case() {
        check("always-fails", 10, |g| {
            let a = g.u64(0, 100);
            prop_assert!(a > 1000, "a={a} is not > 1000");
            Ok(())
        });
    }

    #[test]
    fn shrinker_reduces_to_boundary() {
        // Find the minimal failing input for "x < 50" by hand-driving shrink.
        let prop = |g: &mut Gen| {
            let x = g.u64(0, 1000);
            prop_assert!(x < 50, "x={x}");
            Ok(())
        };
        // locate a failing case first
        let mut failing = None;
        for seed in 0..100 {
            let mut g = Gen::new(seed);
            if prop(&mut g).is_err() {
                failing = Some(g.draws.clone());
                break;
            }
        }
        let draws = failing.expect("should find a failing case");
        let (shrunk, _) = shrink(
            &draws,
            PropFail { msg: String::new() },
            &prop,
        );
        assert_eq!(shrunk, vec![50]);
    }

    #[test]
    fn gen_vec_and_ident() {
        let mut g = Gen::new(1);
        let v = g.vec(10, |g| g.u64(0, 5));
        assert!(v.len() <= 10);
        let id = g.ident(8);
        assert!(!id.is_empty() && id.len() <= 8);
        assert!(id.chars().all(|c| c.is_ascii_lowercase()));
    }
}
