//! TOML-subset parser (substrate; DESIGN.md §15).
//!
//! The BYOB definition layer (`crate::defs`) stores benchmarks,
//! machines, and engines as `*.toml` files. No TOML crate is vendored
//! (the build is offline and dependency-free), so — mirroring
//! [`super::yamlite`] and [`super::rex`] — we parse the subset those
//! definition files actually use into the [`Json`] value model:
//!
//! * `[table]` headers with dotted paths, `[[array.of.tables]]`
//!   headers (a later `[a.b]` descends into the *last* `[[a]]` element,
//!   standard TOML semantics),
//! * `key = value` pairs with bare or quoted keys,
//! * values: basic `"…"` strings (with `\" \\ \n \t \r` escapes),
//!   literal `'…'` strings, integers (with `_` separators), floats
//!   (correctly-rounded via `f64::from_str`, so shortest-round-trip
//!   decimals re-parse to identical bits), booleans, arrays (including
//!   multi-line), and inline tables `{k = v, …}`,
//! * `#` comments and blank lines.
//!
//! Not supported (by design): dates, multi-line strings, dotted keys in
//! key position, and table re-opening. Duplicate keys and duplicate
//! table headers are **rejected with a line-numbered error** — a
//! silently shadowed key in a benchmark definition is a
//! wrong-measurement bug, not a convenience.

use super::json::Json;
use std::cell::Cell;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub msg: String,
    pub line: usize,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

thread_local! {
    /// Total successful `parse` calls on this thread — the observable
    /// `benches/perf_defs.rs` pins to prove warm campaign days never
    /// re-parse definition files.
    static PARSE_CALLS: Cell<u64> = const { Cell::new(0) };
}

/// Number of completed `parse` calls on this thread since start.
pub fn parse_count() -> u64 {
    PARSE_CALLS.with(|c| c.get())
}

fn err(msg: impl Into<String>, line: usize) -> TomlError {
    TomlError {
        msg: msg.into(),
        line,
    }
}

/// One logical line: physical lines joined until brackets balance.
struct Logical {
    text: String,
    no: usize, // 1-based number of the first physical line
}

pub fn parse(src: &str) -> Result<Json, TomlError> {
    let logicals = preprocess(src)?;
    let mut root = Json::obj();
    // current table path; empty = root
    let mut path: Vec<String> = Vec::new();
    for l in &logicals {
        let t = l.text.trim();
        if let Some(body) = t.strip_prefix("[[") {
            let body = body
                .strip_suffix("]]")
                .ok_or_else(|| err("array-of-tables header must end with ']]'", l.no))?;
            path = split_path(body, l.no)?;
            open_array_element(&mut root, &path, l.no)?;
        } else if let Some(body) = t.strip_prefix('[') {
            let body = body
                .strip_suffix(']')
                .ok_or_else(|| err("table header must end with ']'", l.no))?;
            path = split_path(body, l.no)?;
            open_table(&mut root, &path, l.no)?;
        } else {
            let (key, rest) = parse_key(t, l.no)?;
            let rest = rest.trim_start();
            let rest = rest
                .strip_prefix('=')
                .ok_or_else(|| err(format!("expected '=' after key '{key}'"), l.no))?;
            let mut p = Cursor::new(rest, l.no);
            let value = p.value()?;
            p.expect_end()?;
            let table = navigate(&mut root, &path, l.no)?;
            insert_unique(table, key, value, l.no)?;
        }
    }
    PARSE_CALLS.with(|c| c.set(c.get() + 1));
    Ok(root)
}

/// Strip comments, drop blanks, and join physical lines until `[`/`{`
/// brackets balance (multi-line arrays and inline tables).
fn preprocess(src: &str) -> Result<Vec<Logical>, TomlError> {
    let mut out: Vec<Logical> = Vec::new();
    let mut pending: Option<Logical> = None;
    let mut depth = 0i32;
    for (i, raw) in src.lines().enumerate() {
        let no = i + 1;
        let stripped = strip_comment(raw, no)?;
        let t = stripped.trim();
        if t.is_empty() && pending.is_none() {
            continue;
        }
        let header = pending.is_none() && t.starts_with('[');
        if let Some(l) = pending.as_mut() {
            l.text.push(' ');
            l.text.push_str(t);
        } else {
            pending = Some(Logical {
                text: t.to_string(),
                no,
            });
        }
        // table headers balance on their own line; everything else
        // contributes bracket depth (multi-line arrays/inline tables)
        if !header {
            depth += bracket_delta(t, no)?;
        }
        if depth < 0 {
            return Err(err("unbalanced ']' or '}'", no));
        }
        if depth == 0 {
            out.push(pending.take().expect("set above"));
        }
    }
    if let Some(l) = pending {
        return Err(err("unterminated array or inline table", l.no));
    }
    Ok(out)
}

/// Net bracket depth change of a line, ignoring brackets inside strings.
fn bracket_delta(t: &str, no: usize) -> Result<i32, TomlError> {
    let mut depth = 0i32;
    let mut chars = t.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                loop {
                    match chars.next() {
                        Some('\\') => {
                            chars.next();
                        }
                        Some('"') => break,
                        Some(_) => {}
                        None => return Err(err("unterminated string", no)),
                    }
                }
            }
            '\'' => loop {
                match chars.next() {
                    Some('\'') => break,
                    Some(_) => {}
                    None => return Err(err("unterminated literal string", no)),
                }
            },
            '[' | '{' => depth += 1,
            ']' | '}' => depth -= 1,
            _ => {}
        }
    }
    Ok(depth)
}

/// Strip a `#` comment, respecting both string syntaxes.
fn strip_comment(line: &str, no: usize) -> Result<String, TomlError> {
    let mut out = String::new();
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '#' => break,
            '"' => {
                out.push(c);
                loop {
                    match chars.next() {
                        Some('\\') => {
                            out.push('\\');
                            match chars.next() {
                                Some(e) => out.push(e),
                                None => return Err(err("unterminated string", no)),
                            }
                        }
                        Some('"') => {
                            out.push('"');
                            break;
                        }
                        Some(x) => out.push(x),
                        None => return Err(err("unterminated string", no)),
                    }
                }
            }
            '\'' => {
                out.push(c);
                loop {
                    match chars.next() {
                        Some('\'') => {
                            out.push('\'');
                            break;
                        }
                        Some(x) => out.push(x),
                        None => return Err(err("unterminated literal string", no)),
                    }
                }
            }
            _ => out.push(c),
        }
    }
    Ok(out)
}

fn is_bare_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

/// Split a dotted header path `a.b.c` into segments (bare keys only).
fn split_path(body: &str, no: usize) -> Result<Vec<String>, TomlError> {
    let mut segs = Vec::new();
    for seg in body.split('.') {
        let seg = seg.trim();
        if seg.is_empty() || !seg.chars().all(is_bare_key_char) {
            return Err(err(format!("invalid table path '{body}'"), no));
        }
        segs.push(seg.to_string());
    }
    Ok(segs)
}

/// Parse a (bare or quoted) key; returns (key, remainder).
fn parse_key(t: &str, no: usize) -> Result<(String, &str), TomlError> {
    if let Some(rest) = t.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| err("unterminated quoted key", no))?;
        return Ok((rest[..end].to_string(), &rest[end + 1..]));
    }
    let end = t
        .find(|c: char| !is_bare_key_char(c))
        .unwrap_or(t.len());
    if end == 0 {
        return Err(err(format!("expected a key, got '{t}'"), no));
    }
    Ok((t[..end].to_string(), &t[end..]))
}

fn child_mut<'a>(obj: &'a mut Json, key: &str) -> Option<&'a mut Json> {
    match obj {
        Json::Obj(pairs) => pairs
            .iter_mut()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v),
        _ => None,
    }
}

fn insert_unique(obj: &mut Json, key: String, value: Json, no: usize) -> Result<(), TomlError> {
    match obj {
        Json::Obj(pairs) => {
            if pairs.iter().any(|(k, _)| *k == key) {
                return Err(err(format!("duplicate key '{key}'"), no));
            }
            pairs.push((key, value));
            Ok(())
        }
        _ => Err(err(format!("cannot insert key '{key}' into a non-table"), no)),
    }
}

/// Walk `path` from the root, descending into the last element of any
/// array-of-tables on the way; intermediate tables are created.
fn navigate<'a>(root: &'a mut Json, path: &[String], no: usize) -> Result<&'a mut Json, TomlError> {
    let mut cur = root;
    for seg in path {
        if child_mut(cur, seg).is_none() {
            insert_unique(cur, seg.clone(), Json::obj(), no)?;
        }
        let next = child_mut(cur, seg).expect("inserted above");
        cur = match next {
            Json::Arr(items) => items
                .last_mut()
                .ok_or_else(|| err(format!("'{seg}' is an empty array of tables"), no))?,
            Json::Obj(_) => next,
            _ => return Err(err(format!("'{seg}' is not a table"), no)),
        };
    }
    Ok(cur)
}

/// `[a.b.c]`: create the table at the end of the path; redefining an
/// existing table is rejected (duplicate-table error).
fn open_table(root: &mut Json, path: &[String], no: usize) -> Result<(), TomlError> {
    let (last, parents) = path.split_last().ok_or_else(|| err("empty table path", no))?;
    let parent = navigate(root, parents, no)?;
    if child_mut(parent, last).is_some() {
        return Err(err(format!("duplicate table [{}]", path.join(".")), no));
    }
    insert_unique(parent, last.clone(), Json::obj(), no)
}

/// `[[a.b]]`: append a fresh element to the array of tables at the path.
fn open_array_element(root: &mut Json, path: &[String], no: usize) -> Result<(), TomlError> {
    let (last, parents) = path.split_last().ok_or_else(|| err("empty table path", no))?;
    let parent = navigate(root, parents, no)?;
    if child_mut(parent, last).is_none() {
        insert_unique(parent, last.clone(), Json::Arr(Vec::new()), no)?;
    }
    match child_mut(parent, last).expect("inserted above") {
        Json::Arr(items) => {
            items.push(Json::obj());
            Ok(())
        }
        _ => Err(err(format!("[[{}]] conflicts with an existing key", path.join(".")), no)),
    }
}

/// Recursive-descent value parser over one logical line.
struct Cursor<'a> {
    rest: &'a str,
    no: usize,
}

impl<'a> Cursor<'a> {
    fn new(rest: &'a str, no: usize) -> Cursor<'a> {
        Cursor { rest, no }
    }

    fn skip_ws(&mut self) {
        self.rest = self.rest.trim_start();
    }

    fn expect_end(&mut self) -> Result<(), TomlError> {
        self.skip_ws();
        if self.rest.is_empty() {
            Ok(())
        } else {
            Err(err(format!("trailing content '{}'", self.rest), self.no))
        }
    }

    fn eat(&mut self, c: char) -> bool {
        if self.rest.starts_with(c) {
            self.rest = &self.rest[c.len_utf8()..];
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json, TomlError> {
        self.skip_ws();
        if self.rest.starts_with('"') {
            return self.basic_string();
        }
        if self.rest.starts_with('\'') {
            return self.literal_string();
        }
        if self.rest.starts_with('[') {
            return self.array();
        }
        if self.rest.starts_with('{') {
            return self.inline_table();
        }
        self.scalar()
    }

    fn basic_string(&mut self) -> Result<Json, TomlError> {
        self.eat('"');
        let mut out = String::new();
        let mut chars = self.rest.char_indices();
        loop {
            match chars.next() {
                Some((i, '"')) => {
                    self.rest = &self.rest[i + 1..];
                    return Ok(Json::Str(out));
                }
                Some((_, '\\')) => match chars.next() {
                    Some((_, '"')) => out.push('"'),
                    Some((_, '\\')) => out.push('\\'),
                    Some((_, 'n')) => out.push('\n'),
                    Some((_, 't')) => out.push('\t'),
                    Some((_, 'r')) => out.push('\r'),
                    Some((_, e)) => return Err(err(format!("bad escape '\\{e}'"), self.no)),
                    None => return Err(err("unterminated string", self.no)),
                },
                Some((_, c)) => out.push(c),
                None => return Err(err("unterminated string", self.no)),
            }
        }
    }

    fn literal_string(&mut self) -> Result<Json, TomlError> {
        self.eat('\'');
        match self.rest.find('\'') {
            Some(end) => {
                let s = self.rest[..end].to_string();
                self.rest = &self.rest[end + 1..];
                Ok(Json::Str(s))
            }
            None => Err(err("unterminated literal string", self.no)),
        }
    }

    fn array(&mut self) -> Result<Json, TomlError> {
        self.eat('[');
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            if self.eat(']') {
                return Ok(Json::Arr(items));
            }
            items.push(self.value()?);
            self.skip_ws();
            if !self.eat(',') && !self.rest.starts_with(']') {
                return Err(err("expected ',' or ']' in array", self.no));
            }
        }
    }

    fn inline_table(&mut self) -> Result<Json, TomlError> {
        self.eat('{');
        let mut obj = Json::obj();
        loop {
            self.skip_ws();
            if self.eat('}') {
                return Ok(obj);
            }
            let (key, rest) = parse_key(self.rest, self.no)?;
            self.rest = rest;
            self.skip_ws();
            if !self.eat('=') {
                return Err(err(format!("expected '=' after key '{key}'"), self.no));
            }
            let value = self.value()?;
            insert_unique(&mut obj, key, value, self.no)?;
            self.skip_ws();
            if !self.eat(',') && !self.rest.starts_with('}') {
                return Err(err("expected ',' or '}' in inline table", self.no));
            }
        }
    }

    fn scalar(&mut self) -> Result<Json, TomlError> {
        let end = self
            .rest
            .find(|c: char| matches!(c, ',' | ']' | '}') || c.is_whitespace())
            .unwrap_or(self.rest.len());
        let tok = &self.rest[..end];
        self.rest = &self.rest[end..];
        match tok {
            "true" => return Ok(Json::Bool(true)),
            "false" => return Ok(Json::Bool(false)),
            "" => return Err(err("expected a value", self.no)),
            _ => {}
        }
        let digits: String = tok.chars().filter(|c| *c != '_').collect();
        let looks_float = digits.contains(['.', 'e', 'E']);
        if looks_float {
            if let Ok(f) = digits.parse::<f64>() {
                if f.is_finite() {
                    return Ok(Json::Num(f));
                }
            }
        } else if let Ok(n) = digits.parse::<i64>() {
            return Ok(Json::Num(n as f64));
        }
        Err(err(format!("unsupported value '{tok}'"), self.no))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_tables_and_arrays() {
        let src = r#"
# a benchmark definition
title = "exaCB defs"
count = 42
big = 5_888
ratio = 2.5
tiny = 1.5e-3
on = true
off = false

[owner]
name = 'JSC'
queues = ["all", "devel"]

[owner.nested]
depth = 2
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.str_of("title"), Some("exaCB defs"));
        assert_eq!(v.u64_of("count"), Some(42));
        assert_eq!(v.u64_of("big"), Some(5888));
        assert_eq!(v.f64_of("ratio"), Some(2.5));
        assert_eq!(v.f64_of("tiny"), Some(1.5e-3));
        assert_eq!(v.bool_of("on"), Some(true));
        assert_eq!(v.bool_of("off"), Some(false));
        assert_eq!(v.pointer("/owner/name").unwrap().as_str(), Some("JSC"));
        assert_eq!(v.pointer("/owner/queues/1").unwrap().as_str(), Some("devel"));
        assert_eq!(v.pointer("/owner/nested/depth").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn arrays_of_tables_with_subtables() {
        // the exact shape benchmarks/*.toml uses: a later [app.x] header
        // attaches to the *last* [[app]] element
        let src = r#"
[[app]]
name = "climate-01"

[app.parameters]
steps = 100

[[app]]
name = "cfd-02"

[app.parameters]
steps = 250
"#;
        let v = parse(src).unwrap();
        let apps = v.get("app").unwrap().as_arr().unwrap();
        assert_eq!(apps.len(), 2);
        assert_eq!(apps[0].str_of("name"), Some("climate-01"));
        assert_eq!(
            apps[0].pointer("/parameters/steps").unwrap().as_u64(),
            Some(100)
        );
        assert_eq!(
            apps[1].pointer("/parameters/steps").unwrap().as_u64(),
            Some(250)
        );
    }

    #[test]
    fn inline_tables_and_multiline_arrays() {
        let src = "
link = { name = \"IB-NDR400\", bw_gbs = 48.0 }
record = [
  \"tts\",      # primary
  \"gflops_rate\",
]
grid = [
  [1, 2],
  [3, 4],
]
";
        let v = parse(src).unwrap();
        assert_eq!(v.pointer("/link/name").unwrap().as_str(), Some("IB-NDR400"));
        assert_eq!(v.pointer("/link/bw_gbs").unwrap().as_f64(), Some(48.0));
        assert_eq!(v.pointer("/record/1").unwrap().as_str(), Some("gflops_rate"));
        assert_eq!(v.pointer("/grid/1/0").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn floats_round_trip_bit_exact() {
        // shortest round-trip decimals (what both render() and the
        // Python generator emit) must re-parse to identical bits
        for x in [0.855f64, 254164.60293018, 0.0523, 1.0 / 3.0, 5e-5] {
            let src = format!("x = {x:?}\n");
            let v = parse(&src).unwrap();
            assert_eq!(v.f64_of("x").unwrap().to_bits(), x.to_bits(), "{src}");
        }
    }

    #[test]
    fn duplicate_keys_rejected_with_line_numbers() {
        let e = parse("a = 1\nb = 2\na = 3\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("duplicate key 'a'"), "{e}");
        // in a named table
        let e = parse("[t]\nx = 1\nx = 2\n").unwrap_err();
        assert_eq!(e.line, 3);
        // in an inline table
        let e = parse("a = 1\nt = { x = 1, x = 2 }\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("duplicate key 'x'"), "{e}");
    }

    #[test]
    fn duplicate_tables_rejected() {
        let e = parse("[t]\na = 1\n[t]\nb = 2\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("duplicate table [t]"), "{e}");
    }

    #[test]
    fn comments_respect_strings() {
        let v = parse("a = \"x # kept\" # dropped\nb = '# kept too'\n").unwrap();
        assert_eq!(v.str_of("a"), Some("x # kept"));
        assert_eq!(v.str_of("b"), Some("# kept too"));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#"s = "a\"b\\c\nd""#).unwrap();
        assert_eq!(v.str_of("s"), Some("a\"b\\c\nd"));
        assert!(parse(r#"s = "\q""#).is_err()); // unknown escape
    }

    #[test]
    fn quoted_keys() {
        let v = parse("\"dotted.key\" = 1\n").unwrap();
        assert_eq!(v.u64_of("dotted.key"), Some(1));
    }

    #[test]
    fn negative_numbers() {
        let v = parse("a = -4\nb = -0.5\n").unwrap();
        assert_eq!(v.f64_of("a"), Some(-4.0));
        assert_eq!(v.f64_of("b"), Some(-0.5));
    }

    #[test]
    fn malformed_input_is_loud() {
        assert!(parse("a\n").is_err()); // no '='
        assert!(parse("a = \n").is_err()); // no value
        assert!(parse("a = [1, 2\n").is_err()); // unterminated array
        assert!(parse("[t\n").is_err()); // bad header
        assert!(parse("a = 2026-01-01\n").is_err()); // dates unsupported
        assert!(parse("a = \"unterminated\n").is_err());
    }

    #[test]
    fn parse_counter_increments_per_successful_parse() {
        let before = parse_count();
        parse("a = 1\n").unwrap();
        parse("b = 2\n").unwrap();
        let _ = parse("broken =\n");
        assert_eq!(parse_count(), before + 2);
    }

    #[test]
    fn empty_doc_is_empty_table() {
        assert_eq!(parse("\n# only a comment\n").unwrap(), Json::obj());
    }
}
