//! YAML-subset parser (substrate; DESIGN.md §2).
//!
//! exaCB front-ends are YAML files: `.gitlab-ci.yml`-style pipeline
//! configs (§II-C, §V-A) and JUBE-style benchmark scripts (§II-B). No
//! YAML crate is vendored, so we parse the subset those files actually
//! use into the [`Json`] value model:
//!
//! * block mappings + sequences via indentation,
//! * inline (flow) lists `[a, b]` and maps `{k: v}`,
//! * scalars: unquoted / single- / double-quoted strings, ints, floats,
//!   booleans, null,
//! * `|` literal block scalars (for multi-line shell steps),
//! * `#` comments and blank lines.
//!
//! Not supported (by design): anchors/aliases, tags, multi-document
//! streams, folded `>` scalars, flow nesting beyond one level of quotes.

use super::json::Json;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct YamlError {
    pub msg: String,
    pub line: usize,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

/// A pre-processed source line.
struct Line {
    indent: usize,
    text: String, // content without indentation
    no: usize,    // 1-based source line number
}

pub fn parse(src: &str) -> Result<Json, YamlError> {
    let lines = preprocess(src)?;
    if lines.is_empty() {
        return Ok(Json::Null);
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(YamlError {
            msg: "unconsumed trailing content (inconsistent indentation?)".into(),
            line: lines[pos].no,
        });
    }
    Ok(v)
}

fn preprocess(src: &str) -> Result<Vec<Line>, YamlError> {
    let mut out = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        let no = i + 1;
        if raw.trim() == "---" && out.is_empty() {
            continue; // leading document marker
        }
        let without_comment = strip_comment(raw);
        let trimmed_end = without_comment.trim_end();
        if trimmed_end.trim().is_empty() {
            continue;
        }
        if trimmed_end.contains('\t') {
            return Err(YamlError {
                msg: "tabs are not allowed in indentation".into(),
                line: no,
            });
        }
        let indent = trimmed_end.len() - trimmed_end.trim_start().len();
        out.push(Line {
            indent,
            text: trimmed_end.trim_start().to_string(),
            no,
        });
    }
    Ok(out)
}

/// Strip a trailing `#` comment, respecting quotes.
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'#' if !in_single && !in_double => {
                // `#` begins a comment only at start or after whitespace
                if i == 0 || bytes[i - 1] == b' ' {
                    return &line[..i];
                }
            }
            _ => {}
        }
    }
    line
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let line = &lines[*pos];
    if line.text.starts_with("- ") || line.text == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let no = line.no;
        let rest = line.text[1..].trim_start().to_string();
        *pos += 1;
        if rest.is_empty() {
            // nested block on following lines
            if *pos < lines.len() && lines[*pos].indent > indent {
                let inner_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, inner_indent)?);
            } else {
                items.push(Json::Null);
            }
        } else if let Some((key, val)) = split_key(&rest) {
            // "- key: value" — an inline mapping whose further keys sit at
            // indent + 2 (the column of `key`).
            let item_indent = indent + 2;
            let mut pairs = Vec::new();
            push_mapping_entry(lines, pos, item_indent, key, val, no, &mut pairs)?;
            while *pos < lines.len()
                && lines[*pos].indent == item_indent
                && !lines[*pos].text.starts_with("- ")
            {
                let l = &lines[*pos];
                let lno = l.no;
                let (k, v) = split_key(&l.text).ok_or(YamlError {
                    msg: format!("expected 'key: value', got '{}'", l.text),
                    line: lno,
                })?;
                *pos += 1;
                push_mapping_entry(lines, pos, item_indent, k, v, lno, &mut pairs)?;
            }
            items.push(Json::Obj(pairs));
        } else {
            items.push(scalar(&rest, no)?);
        }
    }
    Ok(Json::Arr(items))
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut pairs = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || line.text.starts_with("- ") || line.text == "-" {
            break;
        }
        let no = line.no;
        let (key, val) = split_key(&line.text).ok_or(YamlError {
            msg: format!("expected 'key: value', got '{}'", line.text),
            line: no,
        })?;
        *pos += 1;
        push_mapping_entry(lines, pos, indent, key, val, no, &mut pairs)?;
    }
    Ok(Json::Obj(pairs))
}

fn push_mapping_entry(
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    key: String,
    val: String,
    no: usize,
    pairs: &mut Vec<(String, Json)>,
) -> Result<(), YamlError> {
    let value = if val.is_empty() {
        // nested block or empty value
        if *pos < lines.len() && lines[*pos].indent > indent {
            let inner = lines[*pos].indent;
            parse_block(lines, pos, inner)?
        } else if *pos < lines.len()
            && lines[*pos].indent == indent
            && (lines[*pos].text.starts_with("- ") || lines[*pos].text == "-")
        {
            // sequences are commonly written at the same indent as the key
            parse_sequence(lines, pos, indent)?
        } else {
            Json::Null
        }
    } else if val == "|" || val == "|-" {
        parse_literal_block(lines, pos, indent, val == "|")?
    } else {
        scalar(&val, no)?
    };
    if pairs.iter().any(|(k, _)| *k == key) {
        // a silently shadowed key in a benchmark definition is a
        // wrong-measurement bug, not a convenience (DESIGN.md §15)
        return Err(YamlError {
            msg: format!("duplicate mapping key '{key}'"),
            line: no,
        });
    }
    pairs.push((key, value));
    Ok(())
}

fn parse_literal_block(
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    keep_newline: bool,
) -> Result<Json, YamlError> {
    // Literal blocks lose inner blank lines in `preprocess`; acceptable for
    // shell steps. All lines deeper than `indent` belong to the block.
    let mut body = Vec::new();
    let mut block_indent = None;
    while *pos < lines.len() && lines[*pos].indent > indent {
        let l = &lines[*pos];
        let bi = *block_indent.get_or_insert(l.indent);
        let extra = l.indent.saturating_sub(bi);
        body.push(format!("{}{}", " ".repeat(extra), l.text));
        *pos += 1;
    }
    let mut text = body.join("\n");
    if keep_newline && !text.is_empty() {
        text.push('\n');
    }
    Ok(Json::Str(text))
}

/// Split `key: value` (value may be empty). Returns None when the line is
/// not a mapping entry. Respects quoted keys.
fn split_key(text: &str) -> Option<(String, String)> {
    let bytes = text.as_bytes();
    let (key, rest_at) = if bytes[0] == b'"' || bytes[0] == b'\'' {
        let q = bytes[0];
        let end = text[1..].find(q as char)? + 1;
        (text[1..end].to_string(), end + 1)
    } else {
        let mut idx = None;
        for (i, &b) in bytes.iter().enumerate() {
            if b == b':' && (i + 1 == bytes.len() || bytes[i + 1] == b' ') {
                idx = Some(i);
                break;
            }
        }
        let i = idx?;
        (text[..i].trim().to_string(), i)
    };
    let after = text[rest_at..].trim_start();
    if !after.starts_with(':') {
        return None;
    }
    Some((key, after[1..].trim().to_string()))
}

fn scalar(text: &str, line: usize) -> Result<Json, YamlError> {
    let t = text.trim();
    if t.starts_with('[') {
        return flow_seq(t, line);
    }
    if t.starts_with('{') {
        return flow_map(t, line);
    }
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        // reuse the JSON string parser for escapes
        return Json::parse(t).map_err(|e| YamlError {
            msg: e.msg,
            line,
        });
    }
    if t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2 {
        return Ok(Json::Str(t[1..t.len() - 1].replace("''", "'")));
    }
    Ok(plain_scalar(t))
}

fn plain_scalar(t: &str) -> Json {
    match t {
        "null" | "~" | "" => return Json::Null,
        "true" | "True" => return Json::Bool(true),
        "false" | "False" => return Json::Bool(false),
        _ => {}
    }
    // ints/floats; anything else is a string (no octal/hex/sexagesimal)
    if let Ok(n) = t.parse::<i64>() {
        return Json::Num(n as f64);
    }
    if looks_numeric(t) {
        if let Ok(f) = t.parse::<f64>() {
            return Json::Num(f);
        }
    }
    Json::Str(t.to_string())
}

fn looks_numeric(t: &str) -> bool {
    let mut chars = t.chars();
    let first = match chars.next() {
        Some(c) => c,
        None => return false,
    };
    (first.is_ascii_digit() || first == '-' || first == '+' || first == '.')
        && t.chars()
            .all(|c| c.is_ascii_digit() || matches!(c, '.' | 'e' | 'E' | '+' | '-'))
}

/// Split a flow body on top-level commas (depth-aware, quote-aware).
fn split_flow(body: &str, line: usize) -> Result<Vec<String>, YamlError> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut in_single = false;
    let mut in_double = false;
    let mut cur = String::new();
    for c in body.chars() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '[' | '{' if !in_single && !in_double => depth += 1,
            ']' | '}' if !in_single && !in_double => depth -= 1,
            ',' if depth == 0 && !in_single && !in_double => {
                parts.push(cur.trim().to_string());
                cur.clear();
                continue;
            }
            _ => {}
        }
        cur.push(c);
    }
    if depth != 0 || in_single || in_double {
        return Err(YamlError {
            msg: "unbalanced flow collection".into(),
            line,
        });
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    Ok(parts)
}

fn flow_seq(t: &str, line: usize) -> Result<Json, YamlError> {
    if !t.ends_with(']') {
        return Err(YamlError {
            msg: "flow sequence must end with ']'".into(),
            line,
        });
    }
    let body = &t[1..t.len() - 1];
    if body.trim().is_empty() {
        return Ok(Json::Arr(vec![]));
    }
    let mut items = Vec::new();
    for part in split_flow(body, line)? {
        items.push(scalar(&part, line)?);
    }
    Ok(Json::Arr(items))
}

fn flow_map(t: &str, line: usize) -> Result<Json, YamlError> {
    if !t.ends_with('}') {
        return Err(YamlError {
            msg: "flow mapping must end with '}'".into(),
            line,
        });
    }
    let body = &t[1..t.len() - 1];
    if body.trim().is_empty() {
        return Ok(Json::obj());
    }
    let mut pairs = Vec::new();
    for part in split_flow(body, line)? {
        let (k, v) = split_key(&part).ok_or(YamlError {
            msg: format!("expected 'key: value' in flow mapping, got '{part}'"),
            line,
        })?;
        if pairs.iter().any(|(seen, _)| *seen == k) {
            return Err(YamlError {
                msg: format!("duplicate mapping key '{k}'"),
                line,
            });
        }
        pairs.push((k, scalar(&v, line)?));
    }
    Ok(Json::Obj(pairs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ci_example_parses() {
        // The execution-orchestrator invocation from §V-A.1 of the paper.
        let src = r#"
component: execution@v3
inputs:
  prefix: "jureca.single"
  # Benchmark specification
  usecase: "bigproblem"
  variant: "single"
  jube_file: "benchmark/jube/shell.yml"
  machine: "jureca"
  queue: "dc-gpu"
  project: "cexalab"
  budget: "exalab"
  fixture: .setup
  record: "true"
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.str_of("component"), Some("execution@v3"));
        let inputs = v.get("inputs").unwrap();
        assert_eq!(inputs.str_of("machine"), Some("jureca"));
        assert_eq!(inputs.str_of("fixture"), Some(".setup"));
        assert_eq!(inputs.str_of("record"), Some("true"));
    }

    #[test]
    fn paper_timeseries_example_parses() {
        let src = r#"
component: time-series@v3
inputs:
  prefix: "jupiter.benchmark.stream.cuda"
  pipeline: []
  data_labels: [ "Copy BW [MBytes/sec]", "Mul BW [MBytes/sec]" ]
  ylabel: [ "Bandwidth / MB/s" ]
  time_span: [ "2026-01-01", "2026-04-01" ]
"#;
        let v = parse(src).unwrap();
        let inputs = v.get("inputs").unwrap();
        assert_eq!(inputs.get("pipeline").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(
            inputs.pointer("/data_labels/0").unwrap().as_str().unwrap(),
            "Copy BW [MBytes/sec]"
        );
        assert_eq!(
            inputs.pointer("/time_span/1").unwrap().as_str().unwrap(),
            "2026-04-01"
        );
    }

    #[test]
    fn include_list_of_components() {
        let src = r#"
include:
  - component: example/jube@v3.2
    inputs:
      prefix: "jedi.strong.tiny"
      variant: "large-intensity"
"#;
        let v = parse(src).unwrap();
        let first = v.pointer("/include/0").unwrap();
        assert_eq!(first.str_of("component"), Some("example/jube@v3.2"));
        assert_eq!(
            first.pointer("/inputs/variant").unwrap().as_str().unwrap(),
            "large-intensity"
        );
    }

    #[test]
    fn sequences_nested_and_scalars() {
        let src = r#"
params:
  - name: nodes
    values: [1, 2, 4, 8]
  - name: tag
    values:
      - a
      - b
count: 3
ratio: 2.5
flag: true
empty: ~
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.pointer("/params/0/values/3").unwrap().as_u64(), Some(8));
        assert_eq!(
            v.pointer("/params/1/values/1").unwrap().as_str(),
            Some("b")
        );
        assert_eq!(v.u64_of("count"), Some(3));
        assert_eq!(v.f64_of("ratio"), Some(2.5));
        assert_eq!(v.bool_of("flag"), Some(true));
        assert!(v.get("empty").unwrap().is_null());
    }

    #[test]
    fn literal_block() {
        let src = "run: |\n  echo hello\n  logmap --workload 6\nafter: 1\n";
        let v = parse(src).unwrap();
        assert_eq!(
            v.str_of("run"),
            Some("echo hello\nlogmap --workload 6\n")
        );
        assert_eq!(v.u64_of("after"), Some(1));
    }

    #[test]
    fn comments_and_quotes() {
        let src = r#"
a: "value # not comment"  # real comment
b: 'single # also kept'
c: plain  # stripped
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.str_of("a"), Some("value # not comment"));
        assert_eq!(v.str_of("b"), Some("single # also kept"));
        assert_eq!(v.str_of("c"), Some("plain"));
    }

    #[test]
    fn flow_map_value() {
        let v = parse("env: {UCX_RNDV_THRESH: 65536, MODE: eager}\n").unwrap();
        assert_eq!(v.pointer("/env/UCX_RNDV_THRESH").unwrap().as_u64(), Some(65536));
        assert_eq!(v.pointer("/env/MODE").unwrap().as_str(), Some("eager"));
    }

    #[test]
    fn colon_in_value_kept() {
        let v = parse("cmd: export UCX_RNDV_THRESH=intra:65536,inter:65536\n").unwrap();
        assert_eq!(
            v.str_of("cmd"),
            Some("export UCX_RNDV_THRESH=intra:65536,inter:65536")
        );
    }

    #[test]
    fn tabs_rejected() {
        assert!(parse("a:\n\tb: 1\n").is_err());
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("\n# only a comment\n").unwrap(), Json::Null);
    }

    #[test]
    fn duplicate_mapping_keys_rejected_with_line_number() {
        let e = parse("a: 1\nb: 2\na: 3\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("duplicate mapping key 'a'"), "{e}");
        // nested mapping
        let e = parse("top:\n  x: 1\n  y: 2\n  x: 3\n").unwrap_err();
        assert_eq!(e.line, 4);
        // inline "- key: value" sequence items
        let e = parse("seq:\n  - name: a\n    name: b\n").unwrap_err();
        assert_eq!(e.line, 3);
        // flow mapping
        let e = parse("env: {A: 1, A: 2}\n").unwrap_err();
        assert!(e.msg.contains("duplicate mapping key 'A'"), "{e}");
        // same key in *different* mappings is fine (sequence items)
        let v = parse("seq:\n  - name: a\n  - name: b\n").unwrap();
        assert_eq!(v.pointer("/seq/1/name").unwrap().as_str(), Some("b"));
    }

    #[test]
    fn version_like_strings_stay_strings() {
        let v = parse("ver: 3.2.1\nrange: 1-4\n").unwrap();
        assert_eq!(v.str_of("ver"), Some("3.2.1"));
        assert_eq!(v.str_of("range"), Some("1-4"));
    }
}
