//! Plot rendering: ASCII (terminal) and SVG (files) line/scatter charts.
//!
//! The post-processing orchestrators (paper §V-A.2) emit comparison,
//! scalability, time-series and energy plots; this module is their
//! rendering back end. Supports multiple named series, log axes (Fig. 6
//! uses log-x message sizes, Fig. 5 log-log scaling), shaded guide bands
//! (the "80% scaling regime" bands in Fig. 5), and vertical markers (the
//! measurement-scope bars in Fig. 8).

/// One named data series.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str, points: Vec<(f64, f64)>) -> Series {
        Series {
            name: name.to_string(),
            points,
        }
    }
}

/// A shaded y-band between two reference curves (e.g. ideal scaling and
/// 80%-of-ideal), given as point lists sharing the x grid.
#[derive(Debug, Clone)]
pub struct Band {
    pub name: String,
    pub upper: Vec<(f64, f64)>,
    pub lower: Vec<(f64, f64)>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Linear,
    Log,
}

#[derive(Debug, Clone)]
pub struct Plot {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub xscale: Scale,
    pub yscale: Scale,
    pub series: Vec<Series>,
    pub bands: Vec<Band>,
    /// Vertical markers (x positions), e.g. measurement-scope bars.
    pub vmarks: Vec<(f64, String)>,
}

impl Plot {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Plot {
        Plot {
            title: title.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            xscale: Scale::Linear,
            yscale: Scale::Linear,
            series: Vec::new(),
            bands: Vec::new(),
            vmarks: Vec::new(),
        }
    }

    pub fn logx(mut self) -> Plot {
        self.xscale = Scale::Log;
        self
    }

    pub fn logy(mut self) -> Plot {
        self.yscale = Scale::Log;
        self
    }

    pub fn add(&mut self, s: Series) {
        self.series.push(s);
    }

    pub fn add_band(&mut self, b: Band) {
        self.bands.push(b);
    }

    pub fn add_vmark(&mut self, x: f64, label: &str) {
        self.vmarks.push((x, label.to_string()));
    }

    fn tx(&self, x: f64) -> f64 {
        match self.xscale {
            Scale::Linear => x,
            Scale::Log => x.max(1e-300).log10(),
        }
    }

    fn ty(&self, y: f64) -> f64 {
        match self.yscale {
            Scale::Linear => y,
            Scale::Log => y.max(1e-300).log10(),
        }
    }

    fn extent(&self) -> Option<(f64, f64, f64, f64)> {
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for s in &self.series {
            pts.extend(s.points.iter().map(|&(x, y)| (self.tx(x), self.ty(y))));
        }
        for b in &self.bands {
            pts.extend(b.upper.iter().map(|&(x, y)| (self.tx(x), self.ty(y))));
            pts.extend(b.lower.iter().map(|&(x, y)| (self.tx(x), self.ty(y))));
        }
        if pts.is_empty() {
            return None;
        }
        let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
        for (x, y) in pts {
            if x.is_finite() && y.is_finite() {
                x0 = x0.min(x);
                x1 = x1.max(x);
                y0 = y0.min(y);
                y1 = y1.max(y);
            }
        }
        if x1 <= x0 {
            x1 = x0 + 1.0;
        }
        if y1 <= y0 {
            y1 = y0 + 1.0;
        }
        // 5% y headroom so curves don't hug the frame
        let pad = (y1 - y0) * 0.05;
        Some((x0, x1, y0 - pad, y1 + pad))
    }

    /// Render an ASCII chart of the given size (interior plotting area).
    pub fn render_ascii(&self, width: usize, height: usize) -> String {
        const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&', '$', '~'];
        let (x0, x1, y0, y1) = match self.extent() {
            Some(e) => e,
            None => return format!("{} (no data)\n", self.title),
        };
        let mut grid = vec![vec![' '; width]; height];
        // bands first (shaded with '.')
        for b in &self.bands {
            for (&(ux, uy), &(_, ly)) in b.upper.iter().zip(&b.lower) {
                let cx = ((self.tx(ux) - x0) / (x1 - x0) * (width - 1) as f64).round();
                if !(0.0..width as f64).contains(&cx) {
                    continue;
                }
                let cy_hi = ((self.ty(uy) - y0) / (y1 - y0) * (height - 1) as f64).round();
                let cy_lo = ((self.ty(ly) - y0) / (y1 - y0) * (height - 1) as f64).round();
                let (a, bnd) = (cy_lo.min(cy_hi) as usize, cy_lo.max(cy_hi) as usize);
                for cy in a..=bnd.min(height - 1) {
                    let r = height - 1 - cy;
                    if grid[r][cx as usize] == ' ' {
                        grid[r][cx as usize] = '.';
                    }
                }
            }
        }
        // vertical markers
        for (x, _) in &self.vmarks {
            let cx = ((self.tx(*x) - x0) / (x1 - x0) * (width - 1) as f64).round();
            if (0.0..width as f64).contains(&cx) {
                for row in grid.iter_mut() {
                    if row[cx as usize] == ' ' {
                        row[cx as usize] = '|';
                    }
                }
            }
        }
        // series
        for (si, s) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in &s.points {
                let (px, py) = (self.tx(x), self.ty(y));
                if !px.is_finite() || !py.is_finite() {
                    continue;
                }
                let cx = ((px - x0) / (x1 - x0) * (width - 1) as f64).round() as i64;
                let cy = ((py - y0) / (y1 - y0) * (height - 1) as f64).round() as i64;
                if (0..width as i64).contains(&cx) && (0..height as i64).contains(&cy) {
                    grid[height - 1 - cy as usize][cx as usize] = mark;
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        let ylab = |v: f64| -> f64 {
            match self.yscale {
                Scale::Linear => v,
                Scale::Log => 10f64.powf(v),
            }
        };
        for (i, row) in grid.iter().enumerate() {
            let yv = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
            let label = if i == 0 || i == height - 1 || i == height / 2 {
                format!("{:>10.3} |", ylab(yv))
            } else {
                format!("{:>10} |", "")
            };
            out.push_str(&label);
            out.push_str(&row.iter().collect::<String>());
            out.push('\n');
        }
        out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
        let xl = match self.xscale {
            Scale::Linear => (x0, x1),
            Scale::Log => (10f64.powf(x0), 10f64.powf(x1)),
        };
        out.push_str(&format!(
            "{:>10}  {:<.3}{:>pad$.3}   ({})\n",
            "",
            xl.0,
            xl.1,
            self.xlabel,
            pad = width.saturating_sub(8)
        ));
        for (si, s) in self.series.iter().enumerate() {
            out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
        }
        out
    }

    /// Render an SVG chart (800x500).
    pub fn render_svg(&self) -> String {
        const COLORS: &[&str] = &[
            "#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b",
            "#e377c2", "#7f7f7f", "#bcbd22", "#17becf",
        ];
        let (w, h) = (800.0, 500.0);
        let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 60.0);
        let (pw, ph) = (w - ml - mr, h - mt - mb);
        let (x0, x1, y0, y1) = match self.extent() {
            Some(e) => e,
            None => (0.0, 1.0, 0.0, 1.0),
        };
        let px = |x: f64| ml + (self.tx(x) - x0) / (x1 - x0) * pw;
        let py = |y: f64| mt + ph - (self.ty(y) - y0) / (y1 - y0) * ph;
        let mut svg = String::new();
        svg.push_str(&format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}">"#
        ));
        svg.push_str(r#"<rect width="100%" height="100%" fill="white"/>"#);
        svg.push_str(&format!(
            r#"<text x="{}" y="24" font-size="16" text-anchor="middle" font-family="sans-serif">{}</text>"#,
            w / 2.0,
            xml_escape(&self.title)
        ));
        // frame
        svg.push_str(&format!(
            r##"<rect x="{ml}" y="{mt}" width="{pw}" height="{ph}" fill="none" stroke="#333"/>"##
        ));
        // bands
        for b in &self.bands {
            if b.upper.is_empty() {
                continue;
            }
            let mut d = String::from("M");
            for &(x, y) in &b.upper {
                d.push_str(&format!("{:.1},{:.1} L", px(x), py(y)));
            }
            for &(x, y) in b.lower.iter().rev() {
                d.push_str(&format!("{:.1},{:.1} L", px(x), py(y)));
            }
            d.pop();
            d.push('Z');
            svg.push_str(&format!(
                r##"<path d="{d}" fill="#88aadd" opacity="0.25" stroke="none"/>"##
            ));
        }
        // vmarks
        for (x, label) in &self.vmarks {
            let cx = px(*x);
            svg.push_str(&format!(
                r#"<line x1="{cx:.1}" y1="{mt}" x2="{cx:.1}" y2="{:.1}" stroke="black" stroke-width="2"/>"#,
                mt + ph
            ));
            svg.push_str(&format!(
                r#"<text x="{:.1}" y="{:.1}" font-size="11" font-family="sans-serif">{}</text>"#,
                cx + 4.0,
                mt + 14.0,
                xml_escape(label)
            ));
        }
        // axis ticks (5 each)
        for i in 0..=4 {
            let fx = x0 + (x1 - x0) * i as f64 / 4.0;
            let vx = match self.xscale {
                Scale::Linear => fx,
                Scale::Log => 10f64.powf(fx),
            };
            let cx = ml + pw * i as f64 / 4.0;
            svg.push_str(&format!(
                r#"<text x="{cx:.1}" y="{:.1}" font-size="11" text-anchor="middle" font-family="sans-serif">{}</text>"#,
                mt + ph + 18.0,
                fmt_tick(vx)
            ));
            let fy = y0 + (y1 - y0) * i as f64 / 4.0;
            let vy = match self.yscale {
                Scale::Linear => fy,
                Scale::Log => 10f64.powf(fy),
            };
            let cy = mt + ph - ph * i as f64 / 4.0;
            svg.push_str(&format!(
                r#"<text x="{:.1}" y="{cy:.1}" font-size="11" text-anchor="end" font-family="sans-serif">{}</text>"#,
                ml - 6.0,
                fmt_tick(vy)
            ));
        }
        svg.push_str(&format!(
            r#"<text x="{:.1}" y="{:.1}" font-size="13" text-anchor="middle" font-family="sans-serif">{}</text>"#,
            ml + pw / 2.0,
            h - 16.0,
            xml_escape(&self.xlabel)
        ));
        svg.push_str(&format!(
            r#"<text x="16" y="{:.1}" font-size="13" text-anchor="middle" font-family="sans-serif" transform="rotate(-90 16 {:.1})">{}</text>"#,
            mt + ph / 2.0,
            mt + ph / 2.0,
            xml_escape(&self.ylabel)
        ));
        // series
        for (si, s) in self.series.iter().enumerate() {
            let color = COLORS[si % COLORS.len()];
            if s.points.len() > 1 {
                let mut d = String::from("M");
                for &(x, y) in &s.points {
                    d.push_str(&format!("{:.1},{:.1} L", px(x), py(y)));
                }
                d.pop();
                svg.push_str(&format!(
                    r#"<path d="{d}" fill="none" stroke="{color}" stroke-width="1.8"/>"#
                ));
            }
            for &(x, y) in &s.points {
                svg.push_str(&format!(
                    r#"<circle cx="{:.1}" cy="{:.1}" r="3" fill="{color}"/>"#,
                    px(x),
                    py(y)
                ));
            }
            // legend
            let ly = mt + 16.0 + 16.0 * si as f64;
            svg.push_str(&format!(
                r#"<rect x="{:.1}" y="{:.1}" width="10" height="10" fill="{color}"/>"#,
                ml + 8.0,
                ly - 9.0
            ));
            svg.push_str(&format!(
                r#"<text x="{:.1}" y="{ly:.1}" font-size="12" font-family="sans-serif">{}</text>"#,
                ml + 22.0,
                xml_escape(&s.name)
            ));
        }
        svg.push_str("</svg>");
        svg
    }
}

fn fmt_tick(v: f64) -> String {
    let a = v.abs();
    if a >= 1e6 || (a > 0.0 && a < 1e-3) {
        format!("{v:.1e}")
    } else if a >= 100.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plot_with_data() -> Plot {
        let mut p = Plot::new("t", "x", "y");
        p.add(Series::new(
            "a",
            vec![(1.0, 1.0), (2.0, 4.0), (3.0, 9.0)],
        ));
        p
    }

    #[test]
    fn ascii_contains_marks_and_legend() {
        let r = plot_with_data().render_ascii(40, 10);
        assert!(r.contains('*'));
        assert!(r.contains("a"));
        assert!(r.lines().count() > 10);
    }

    #[test]
    fn svg_is_wellformed_enough() {
        let svg = plot_with_data().render_svg();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<circle").count(), 3);
        assert!(svg.contains("path"));
    }

    #[test]
    fn log_axes_transform() {
        let mut p = Plot::new("t", "x", "y").logx().logy();
        p.add(Series::new("s", vec![(1.0, 10.0), (100.0, 1000.0)]));
        let (x0, x1, _, _) = p.extent().unwrap();
        assert!((x0 - 0.0).abs() < 1e-9);
        assert!((x1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_plot_renders() {
        let p = Plot::new("nothing", "x", "y");
        assert!(p.render_ascii(20, 5).contains("no data"));
        assert!(p.render_svg().contains("</svg>"));
    }

    #[test]
    fn bands_and_vmarks_render() {
        let mut p = plot_with_data();
        p.add_band(Band {
            name: "80%".into(),
            upper: vec![(1.0, 2.0), (3.0, 10.0)],
            lower: vec![(1.0, 1.0), (3.0, 8.0)],
        });
        p.add_vmark(2.0, "scope");
        let ascii = p.render_ascii(40, 10);
        assert!(ascii.contains('|'));
        let svg = p.render_svg();
        assert!(svg.contains("opacity=\"0.25\""));
        assert!(svg.contains("scope"));
    }
}
