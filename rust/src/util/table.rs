//! Aligned ASCII tables and CSV emission (Table I of the paper and every
//! harness `results.csv`).

/// A simple column-ordered table of string cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Table {
        Table {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row width {} != column count {}",
            row.len(),
            self.columns.len()
        );
        self.rows.push(row);
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    /// All values of one column.
    pub fn column(&self, name: &str) -> Option<Vec<&str>> {
        let i = self.col_index(name)?;
        Some(self.rows.iter().map(|r| r[i].as_str()).collect())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// RFC-4180-ish CSV (quotes fields containing `,` `"` or newline).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        emit_csv_row(&mut out, &self.columns);
        for row in &self.rows {
            emit_csv_row(&mut out, row);
        }
        out
    }

    /// Parse CSV produced by `to_csv` (quoted fields supported).
    pub fn from_csv(text: &str) -> Option<Table> {
        let mut rows = parse_csv(text);
        if rows.is_empty() {
            return None;
        }
        let columns = rows.remove(0);
        let width = columns.len();
        if rows.iter().any(|r| r.len() != width) {
            return None;
        }
        Some(Table { columns, rows })
    }

    /// Fixed-width ASCII rendering with a header rule.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cell, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Push a labelled placeholder row: `label` in the first column,
    /// `-` padding to the table's width. For "empty but meaningful"
    /// tables — a world with no completed pipelines should read as
    /// such, not render as a bare header.
    pub fn push_placeholder(&mut self, label: &str) {
        let mut row = vec![label.to_string()];
        row.resize(self.columns.len(), "-".to_string());
        self.rows.push(row);
    }

    /// Sort rows by a column, numerically when possible.
    pub fn sort_by_column(&mut self, name: &str) {
        if let Some(i) = self.col_index(name) {
            self.rows.sort_by(|a, b| {
                match (a[i].parse::<f64>(), b[i].parse::<f64>()) {
                    (Ok(x), Ok(y)) => x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal),
                    _ => a[i].cmp(&b[i]),
                }
            });
        }
    }
}

#[cfg(test)]
mod placeholder_tests {
    use super::*;

    #[test]
    fn placeholder_pads_to_table_width() {
        let mut t = Table::new(&["a", "b", "c"]);
        t.push_placeholder("(empty)");
        assert_eq!(t.rows, vec![vec!["(empty)", "-", "-"]]);
        // renders and round-trips like any other row
        assert!(t.render().contains("(empty)"));
        assert_eq!(Table::from_csv(&t.to_csv()).unwrap().rows, t.rows);
    }
}

fn emit_csv_row(out: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        // Quote `\r` as well as `\n`: the parser strips bare carriage
        // returns, so an unquoted one would not survive a round-trip.
        // A lone empty cell must be quoted too, or its row serializes
        // to a blank line and the parser discards it.
        let lone_empty = cells.len() == 1 && cell.is_empty();
        if cell.contains(',')
            || cell.contains('"')
            || cell.contains('\n')
            || cell.contains('\r')
            || lone_empty
        {
            out.push('"');
            out.push_str(&cell.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

fn parse_csv(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    // True until the current line sees any syntax (quote, comma, or
    // field character). Distinguishes a genuinely blank line (skipped)
    // from a quoted empty cell `""` (a real one-cell row).
    let mut blank_line = true;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' if chars.peek() == Some(&'"') => {
                    chars.next();
                    field.push('"');
                }
                '"' => in_quotes = false,
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    in_quotes = true;
                    blank_line = false;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                    blank_line = false;
                }
                '\n' => {
                    if blank_line {
                        row.clear();
                        field.clear();
                    } else {
                        row.push(std::mem::take(&mut field));
                        rows.push(std::mem::take(&mut row));
                    }
                    blank_line = true;
                }
                '\r' => {}
                c => {
                    field.push(c);
                    blank_line = false;
                }
            }
        }
    }
    if !blank_line {
        row.push(field);
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(&["system", "nodes", "runtime"]);
        t.push_row(vec!["jedi".into(), "4".into(), "12.5".into()]);
        t.push_row(vec!["jureca".into(), "2".into(), "30.1".into()]);
        t
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let back = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(back.columns, t.columns);
        assert_eq!(back.rows, t.rows);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(&["a"]);
        t.push_row(vec!["x,\"y\"\nz".into()]);
        let back = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(back.rows[0][0], "x,\"y\"\nz");
    }

    #[test]
    fn csv_roundtrip_quote_comma_space() {
        // The hostile case from harness output: a cell holding `", "`.
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec!["x\", \"y".into(), "plain".into()]);
        let back = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(back.rows, t.rows);
    }

    #[test]
    fn csv_roundtrip_carriage_return() {
        let mut t = Table::new(&["a"]);
        t.push_row(vec!["line1\r\nline2".into()]);
        t.push_row(vec!["bare\rcr".into()]);
        let back = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(back.rows, t.rows);
    }

    #[test]
    fn csv_roundtrip_lone_empty_cell() {
        let mut t = Table::new(&["only"]);
        t.push_row(vec!["".into()]);
        t.push_row(vec!["x".into()]);
        let back = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(back.rows, t.rows);
    }

    #[test]
    fn from_csv_still_skips_blank_lines() {
        let t = Table::from_csv("a,b\n1,2\n\n3,4\n").unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn render_aligns() {
        let r = sample().render();
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[0].starts_with("system"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn column_access_and_sort() {
        let mut t = sample();
        t.sort_by_column("runtime");
        assert_eq!(t.rows[0][0], "jedi");
        assert_eq!(t.column("nodes").unwrap(), vec!["4", "2"]);
        assert!(t.column("missing").is_none());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = sample();
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn from_csv_rejects_ragged() {
        assert!(Table::from_csv("a,b\n1\n").is_none());
    }
}
