//! Deterministic PRNG (substrate; DESIGN.md §2 — no `rand` crate vendored).
//!
//! splitmix64 for seeding, xoshiro256** as the workhorse generator. The
//! simulators (cluster noise, scheduler arrival jitter, portfolio
//! generation) must be reproducible from a seed recorded in the protocol
//! `Reporter` section, so determinism is a feature, not a shortcut.

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Prng {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. one per simulated day/app).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// Log-normal multiplicative noise around 1.0 (runtime jitter model).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (self.gauss() * sigma).exp()
    }

    /// Exponential with rate lambda (arrival gaps).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut p = Prng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[p.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut p = Prng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| p.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_u64_inclusive() {
        let mut p = Prng::new(13);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = p.range_u64(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }
}
