//! Shared substrates: JSON, YAML-subset, PRNG, property testing, tables,
//! plots, simulated time, statistics. See DESIGN.md §2 for why these are
//! in-repo rather than external crates (offline vendored build).

pub mod json;
pub mod plot;
pub mod prng;
pub mod prop;
pub mod rex;
pub mod stats;
pub mod table;
pub mod timeutil;
pub mod tomlite;
pub mod yamlite;

/// fnv1a-64 content hash — stable IDs for store objects and job names.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Short hex digest (12 chars) of arbitrary content.
pub fn short_hash(bytes: &[u8]) -> String {
    // Two passes with different salts to widen to 96 bits.
    let a = fnv1a(bytes);
    let mut salted = bytes.to_vec();
    salted.push(0x5a);
    let b = fnv1a(&salted);
    format!("{:016x}{:08x}", a, (b & 0xffff_ffff) as u32)[..12].to_string()
}

/// Wide hex digest (32 chars, 128 bits from two salted fnv1a-64 passes).
/// Used for execution-cache keys, where collisions would silently replay
/// the wrong result — 48 bits (`short_hash`) is enough for store object
/// ids but not for a cache addressing a whole campaign's step space.
pub fn wide_hash(bytes: &[u8]) -> String {
    let a = fnv1a(bytes);
    let mut salted = Vec::with_capacity(bytes.len() + 1);
    salted.push(0xa5);
    salted.extend_from_slice(bytes);
    let b = fnv1a(&salted);
    format!("{a:016x}{b:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_stable_and_distinct() {
        assert_eq!(short_hash(b"abc"), short_hash(b"abc"));
        assert_ne!(short_hash(b"abc"), short_hash(b"abd"));
        assert_eq!(short_hash(b"abc").len(), 12);
    }

    #[test]
    fn wide_hash_is_stable_and_wide() {
        assert_eq!(wide_hash(b"abc"), wide_hash(b"abc"));
        assert_ne!(wide_hash(b"abc"), wide_hash(b"abd"));
        assert_eq!(wide_hash(b"").len(), 32);
        // the two halves are independent passes, not a repeat
        let h = wide_hash(b"abc");
        assert_ne!(&h[..16], &h[16..]);
    }
}
